"""Per-kernel validation: shape/dtype sweeps, interpret=True vs the pure-jnp
oracle in ref.py, plus VMEM working-set assertions for the BlockSpecs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.attn_stream import attn_stream, attn_stream_vmem_bytes
from repro.kernels.ffn_act import ffn_act, ffn_vmem_bytes
from repro.kernels.fused_norm import fused_norm
from repro.kernels.qkv_proj import qkv_proj

jax.config.update("jax_platform_name", "cpu")

V5E_VMEM = 128 * 2 ** 20


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# FUSED_ATTN_STREAM
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,H,Hkv,S,L,D", [
    (1, 4, 4, 128, 128, 64),      # MHA square
    (2, 8, 2, 128, 128, 64),      # GQA 4:1
    (1, 4, 1, 256, 256, 128),     # MQA (paligemma-style)
    (1, 2, 2, 128, 256, 64),      # cached prefix (L > S)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_attn_stream_matches_ref(B, H, Hkv, S, L, D, dtype, causal):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, S, D), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, L, D), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, L, D), dtype)
    out = attn_stream(q, k, v, causal=causal, block_q=64, block_k=64,
                      interpret=True)
    want = ref.attn_stream_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


def test_attn_stream_blocks_sweep():
    B, H, S, D = 1, 2, 256, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, H, S, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, H, S, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, H, S, D), jnp.float32)
    want = ref.attn_stream_ref(q, k, v, causal=True)
    for bq, bk in [(32, 32), (64, 128), (128, 64), (256, 256)]:
        out = attn_stream(q, k, v, causal=True, block_q=bq, block_k=bk,
                          interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("S,L,causal", [
    (200, 200, True),     # ragged square (not a 128-multiple)
    (200, 200, False),
    (72, 200, True),      # ragged cached-prefix (both non-multiples)
    (130, 384, True),     # ragged S over an aligned L
])
def test_attn_stream_ragged_shapes(S, L, causal):
    """Regression: lengths that aren't block multiples used to hard-assert;
    they now pad to the grid, mask the phantom keys, and slice the output."""
    B, H, Hkv, D = 1, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    q = jax.random.normal(ks[0], (B, H, S, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Hkv, L, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Hkv, L, D), jnp.float32)
    out = attn_stream(q, k, v, causal=causal, interpret=True)
    want = ref.attn_stream_ref(q, k, v, causal=causal)
    assert out.shape == (B, H, S, D)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_attn_stream_causal_s_gt_l_raises():
    """Regression: S > L with causal=True made q_offset negative, leaving
    early queries with zero attendable keys; now an explicit error."""
    B, H, S, L, D = 1, 2, 160, 128, 64
    ks = jax.random.split(jax.random.PRNGKey(12), 3)
    q = jax.random.normal(ks[0], (B, H, S, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, H, L, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, H, L, D), jnp.float32)
    with pytest.raises(ValueError, match="S <= L"):
        attn_stream(q, k, v, causal=True, interpret=True)
    # non-causal S > L stays legal: every key is visible to every query
    out = attn_stream(q, k, v, causal=False, interpret=True)
    want = ref.attn_stream_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_attn_stream_fully_masked_blocks_skipped():
    """Blocks entirely above the causal diagonal are pl.when-skipped; with
    small k-blocks most of the grid is dead and the result must stay exact
    (no reliance on exp underflow zeroing whole-NEG_INF blocks)."""
    B, H, S, D = 1, 2, 256, 64
    ks = jax.random.split(jax.random.PRNGKey(13), 3)
    q = jax.random.normal(ks[0], (B, H, S, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, H, S, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, H, S, D), jnp.float32)
    out = attn_stream(q, k, v, causal=True, block_q=32, block_k=32,
                      interpret=True)
    want = ref.attn_stream_ref(q, k, v, causal=True)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_attn_vmem_budget():
    # production tile choice fits v5e VMEM with generous headroom
    assert attn_stream_vmem_bytes(128, 128, 256) < V5E_VMEM // 8
    # the estimate must charge the in-kernel f32 copies of the q/k/v
    # tiles (cast before the dots), not just the HBM-dtype tiles
    bq = bk = 128
    D = 256
    tiles_bf16 = (bq * D + 2 * bk * D) * 2
    casts_f32 = (bq * D + 2 * bk * D) * 4
    assert attn_stream_vmem_bytes(bq, bk, D) >= tiles_bf16 + casts_f32


# ---------------------------------------------------------------------------
# FUSED_FFN_ACT
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind,gated", [
    ("silu_gated", True), ("gelu", False), ("relu2", False),
    ("gelu_gated", True),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ffn_act_matches_ref(kind, gated, dtype):
    M, D, F = 128, 64, 256
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    x = jax.random.normal(ks[0], (M, D), dtype)
    w1 = jax.random.normal(ks[1], (D, F), dtype) * 0.1
    wg = jax.random.normal(ks[2], (D, F), dtype) * 0.1 if gated else None
    w2 = jax.random.normal(ks[3], (F, D), dtype) * 0.1
    out = ffn_act(x, w1, wg, w2, kind, block_m=64, block_f=64,
                  interpret=True)
    want = ref.ffn_act_ref(x, w1, wg, w2, kind)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


def test_ffn_shapes_sweep():
    for M, D, F, bm, bf in [(64, 32, 128, 32, 32), (256, 128, 512, 128, 256),
                            (128, 96, 192, 64, 96)]:
        ks = jax.random.split(jax.random.PRNGKey(M + F), 3)
        x = jax.random.normal(ks[0], (M, D), jnp.float32)
        w1 = jax.random.normal(ks[1], (D, F), jnp.float32) * 0.1
        w2 = jax.random.normal(ks[2], (F, D), jnp.float32) * 0.1
        out = ffn_act(x, w1, None, w2, "gelu", block_m=bm, block_f=bf,
                      interpret=True)
        want = ref.ffn_act_ref(x, w1, None, w2, "gelu")
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=3e-5, atol=3e-5)


def test_ffn_vmem_budget():
    # granite-scale tiles: D=2048, block_f=512
    assert ffn_vmem_bytes(128, 512, 2048) < V5E_VMEM // 4


# ---------------------------------------------------------------------------
# FUSED_QKV_PROJ
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("use_bias", [True, False])
def test_qkv_proj_matches_ref(dtype, use_bias):
    M, D, N = 128, 64, 384
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    x = jax.random.normal(ks[0], (M, D), dtype)
    w = jax.random.normal(ks[1], (D, N), dtype) * 0.1
    b = jax.random.normal(ks[2], (N,), dtype) if use_bias else None
    out = qkv_proj(x, w, b, block_m=64, block_n=128, interpret=True)
    want = ref.qkv_proj_ref(x, w, b)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


def test_qkv_model_layout_roundtrip():
    """ops.qkv_proj splits concat output back into per-head Q/K/V."""
    B, S, D, H, Hkv, Dh = 2, 16, 64, 8, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    x = jax.random.normal(ks[0], (B, S, D), jnp.float32)
    wq = jax.random.normal(ks[1], (D, H, Dh), jnp.float32) * 0.1
    wk = jax.random.normal(ks[2], (D, Hkv, Dh), jnp.float32) * 0.1
    wv = jax.random.normal(ks[3], (D, Hkv, Dh), jnp.float32) * 0.1
    q, k, v = ops.qkv_proj(x, wq, wk, wv)
    np.testing.assert_allclose(
        np.asarray(q), np.asarray(jnp.einsum("bsd,dhk->bshk", x, wq)),
        rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(
        np.asarray(v), np.asarray(jnp.einsum("bsd,dhk->bshk", x, wv)),
        rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# FUSED_NORM
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind", ["rms", "layer"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_norm_matches_ref(kind, dtype):
    M, D = 256, 128
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    x = jax.random.normal(ks[0], (M, D), dtype)
    s = jax.random.normal(ks[1], (D,), dtype)
    b = jax.random.normal(ks[2], (D,), dtype) if kind == "layer" else None
    out = fused_norm(x, s, b, kind, block_m=64, interpret=True)
    want = ref.fused_norm_ref(x, s, b, kind)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


# ---------------------------------------------------------------------------
# FUSED_FFN_ACT with int8 "RRAM-stored" weights
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind", ["gelu", "relu2"])
def test_ffn_act_int8_matches_dequant_ref(kind):
    from repro.kernels.ffn_act import ffn_act_int8
    M, D, F = 128, 64, 256
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    w1q = jax.random.randint(ks[0], (D, F), -127, 128, jnp.int32) \
        .astype(jnp.int8)
    w1s = jax.random.uniform(ks[1], (F,), minval=1e-3, maxval=2e-3)
    w2q = jax.random.randint(ks[2], (F, D), -127, 128, jnp.int32) \
        .astype(jnp.int8)
    w2s = jax.random.uniform(ks[3], (D,), minval=1e-3, maxval=2e-3)
    x = jax.random.normal(jax.random.PRNGKey(8), (M, D), jnp.float32)
    out = ffn_act_int8(x, w1q, w1s, w2q, w2s, kind, block_m=64,
                       block_f=64, interpret=True)
    w1 = w1q.astype(jnp.float32) * w1s
    w2 = w2q.astype(jnp.float32) * w2s
    want = ref.ffn_act_ref(x, w1, None, w2, kind)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
