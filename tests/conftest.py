"""Shared serving-test fixtures and helpers.

The serving suites (tests/test_serving*.py) all need the same plumbing:
tiny float32 reduced-config models, reproducible request streams, a mesh
over whatever devices the process has, token-parity helpers against the
single-request `generate` oracle, and the forced-fake-device environment
for multi-device subprocess checks. It lives here ONCE; the test files
import the plain helpers (this directory is on sys.path both under
pytest's rootdir mode and when a test file runs as a script) or take the
pytest fixtures wrapping them.

`build_model` memoizes (arch, kv_policy, hot_window) -> (cfg, model,
params): params are functional and never mutated, so sharing one
initialization across every test in the session is a pure speedup.
"""

from __future__ import annotations

import functools
import os
import pathlib

import numpy as np
import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent


# ---------------------------------------------------------------------------
# tiny-model configs
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def build_model(arch: str = "granite-3-2b", kv_policy: str = "tiered",
                hot_window: int = 8):
    """(cfg, model, params) for a reduced float32 config — the shared
    serving-test model. Memoized per (arch, kv_policy, hot_window);
    treat the returned params as read-only (every repro op is
    functional, so they are)."""
    import jax

    from repro.configs.base import get_config
    from repro.models import Model

    cfg = get_config(arch, reduced=True).replace(
        param_dtype="float32", compute_dtype="float32", remat="none",
        kv_policy=kv_policy, kv_hot_window=hot_window)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


# ---------------------------------------------------------------------------
# request streams
# ---------------------------------------------------------------------------
def make_requests(cfg, specs, seed: int = 0, priorities=None):
    """Reproducible text requests from (prompt_len, gen_len) ``specs``;
    ``priorities`` is an optional per-request priority list."""
    from repro.serving import Request

    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    tokens=rng.integers(0, cfg.vocab_size, p)
                    .astype(np.int32),
                    max_new_tokens=g,
                    priority=0 if priorities is None else priorities[i])
            for i, (p, g) in enumerate(specs)]


def generated(done):
    """Token streams of finished requests in rid order — the shape every
    parity assertion compares."""
    return [r.generated for r in sorted(done, key=lambda r: r.rid)]


def oracle_tokens(model, params, req):
    """Single-request reference decode for ``req`` via `generate` (the
    sequential per-request oracle every engine run must match
    token-for-token)."""
    from repro.launch.serve import generate

    batch = {"tokens": req.tokens[None]}
    if req.patches is not None:
        batch["patches"] = req.patches[None]
    toks, _ = generate(model, params, batch, req.prompt_len,
                       req.max_new_tokens)
    return toks[0].tolist()


# ---------------------------------------------------------------------------
# device / mesh plumbing
# ---------------------------------------------------------------------------
def make_mesh():
    """Mesh over every visible device: (1, 1) locally; on a forced
    multi-device host platform, slots shard over 'data' and the cold
    kv_seq over 'model'."""
    import jax

    from repro.launch.mesh import make_local_mesh

    n = jax.device_count()
    if n == 1:
        return make_local_mesh()
    m = 2 if n % 2 == 0 else 1
    return jax.make_mesh((n // m, m), ("data", "model"))


def forced_device_env(n: int = 8) -> dict:
    """Environment for a subprocess with ``n`` fake CPU devices (XLA
    flags must be set before jax initializes, so an in-process re-init
    is impossible)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n}")
    env["JAX_PLATFORM_NAME"] = "cpu"
    env["PYTHONPATH"] = (str(REPO / "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    return env


# ---------------------------------------------------------------------------
# fixture wrappers
# ---------------------------------------------------------------------------
@pytest.fixture(scope="session")
def tiny_model():
    return build_model


@pytest.fixture
def request_factory():
    return make_requests


@pytest.fixture
def mesh_factory():
    return make_mesh
