"""End-to-end system behaviour: training convergence, checkpoint/restart
equivalence, fault-tolerant loop recovery, data-pipeline determinism,
simulator paper-claim validation.

Models come from the shared `tests/conftest.py` `build_model` cache (one
init per reduced config for the whole session; kv_policy/hot_window only
shape the decode cache, which training never touches), so this suite no
longer pays its own model builds."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import build_model

from repro.checkpoint import CheckpointManager
from repro.configs.base import get_config
from repro.data import DataConfig, SyntheticPipeline
from repro.launch.steps import make_train_step
from repro.optim import AdamWConfig, adamw_init
from repro.runtime.fault import FaultPolicy, FaultTolerantLoop

jax.config.update("jax_platform_name", "cpu")


def _setup(arch="granite-3-2b", steps=20):
    cfg, model, params = build_model(arch)
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=steps)
    pipe = SyntheticPipeline(cfg, DataConfig(4, 32, seed=0))
    state = adamw_init(params, opt_cfg)
    step_fn = jax.jit(make_train_step(model, opt_cfg))
    return cfg, model, pipe, state, step_fn


def test_training_reduces_loss():
    _, _, pipe, state, step_fn = _setup()
    losses = []
    for t in range(20):
        state, metrics = step_fn(state, pipe.host_slice(t))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.8, losses


def test_microbatched_step_matches_plain():
    """Gradient accumulation must be numerically consistent with the
    full-batch step."""
    cfg, model, pipe, state, _ = _setup()
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=20)
    plain = jax.jit(make_train_step(model, opt_cfg, microbatches=1))
    micro = jax.jit(make_train_step(model, opt_cfg, microbatches=2))
    b = pipe.host_slice(0)
    s1, m1 = plain(state, b)
    # same cached params: microbatching must match from identical init
    state2 = adamw_init(build_model()[2], opt_cfg)
    s2, m2 = micro(state2, b)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-4)
    l1 = jax.tree.leaves(s1.params)[0]
    l2 = jax.tree.leaves(s2.params)[0]
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=1e-3, atol=1e-5)


def test_checkpoint_restart_exact_resume(tmp_path):
    """Crash after step k and restart must reproduce the uninterrupted
    run exactly (atomic checkpoint + seekable pipeline)."""
    _, _, pipe, state0, step_fn = _setup()
    ckpt = CheckpointManager(tmp_path / "ck", keep=2)

    state = state0
    for t in range(10):
        state, _ = step_fn(state, pipe.host_slice(t))
        if t == 4:
            ckpt.save(state, t)
    ref_leaf = np.asarray(jax.tree.leaves(state.params)[0])

    state2, step = ckpt.restore(state0)
    assert step == 4
    for t in range(step + 1, 10):
        state2, _ = step_fn(state2, pipe.host_slice(t))
    leaf2 = np.asarray(jax.tree.leaves(state2.params)[0])
    np.testing.assert_array_equal(ref_leaf, leaf2)


def test_checkpoint_integrity_detection(tmp_path):
    _, _, pipe, state, step_fn = _setup()
    ckpt = CheckpointManager(tmp_path / "ck", keep=2)
    ckpt.save(state, 0)
    leaf = next((tmp_path / "ck" / "step_0000000000").glob("leaf_*.npy"))
    data = bytearray(leaf.read_bytes())
    data[-1] ^= 0xFF
    leaf.write_bytes(bytes(data))
    with pytest.raises(IOError):
        ckpt.restore(state)


def test_checkpoint_keep_n_retention(tmp_path):
    _, _, _, state, _ = _setup()
    ckpt = CheckpointManager(tmp_path / "ck", keep=2)
    for s in (1, 2, 3, 4):
        ckpt.save(state, s)
    assert ckpt.all_steps() == [3, 4]


def test_fault_loop_recovers_from_transient_failures(tmp_path):
    _, _, pipe, state, step_fn = _setup()
    ckpt = CheckpointManager(tmp_path / "ck", keep=2)
    fails = {"n": 0}

    def flaky_step(state, batch):
        if fails["n"] < 2:
            fails["n"] += 1
            raise RuntimeError("simulated host fault")
        return step_fn(state, batch)

    loop = FaultTolerantLoop(flaky_step, ckpt,
                             FaultPolicy(checkpoint_every=5,
                                         max_retries_per_step=3))
    state, end = loop.run(state, pipe.host_slice, 0, 8)
    assert end == 8
    assert fails["n"] == 2
    assert ckpt.latest_step() is not None


def test_elastic_remesh_shapes():
    from repro.runtime.fault import shrink_mesh_axes
    assert shrink_mesh_axes(2) == ((2, 16, 16), ("pod", "data", "model"))
    assert shrink_mesh_axes(1) == ((16, 16), ("data", "model"))


def test_data_pipeline_deterministic_and_host_sharded():
    cfg = get_config("granite-3-2b", reduced=True)
    a = SyntheticPipeline(cfg, DataConfig(8, 16, seed=3), 0, 2)
    b = SyntheticPipeline(cfg, DataConfig(8, 16, seed=3), 0, 2)
    c = SyntheticPipeline(cfg, DataConfig(8, 16, seed=3), 1, 2)
    np.testing.assert_array_equal(np.asarray(a.host_slice(7)["tokens"]),
                                  np.asarray(b.host_slice(7)["tokens"]))
    assert not np.array_equal(np.asarray(a.host_slice(7)["tokens"]),
                              np.asarray(c.host_slice(7)["tokens"]))
    assert a.local_batch == 4


def test_simulator_reproduces_paper_trends():
    """The headline reproduction: speedup/energy vs Jetson in/near the
    paper's bands, DRAM-only ablation direction + magnitude."""
    from repro.configs.base import PAPER_MODELS
    from repro.simulator import CHIME, DRAM_ONLY, JETSON_ORIN_NX, simulate
    sp, do = [], []
    for m in PAPER_MODELS:
        cfg = get_config(m)
        c = simulate(cfg, CHIME)
        j = simulate(cfg, JETSON_ORIN_NX)
        d = simulate(cfg, DRAM_ONLY)
        sp.append(j.total_s / c.total_s)
        do.append(d.total_s / c.total_s)
        assert c.tps > 100, (m, c.tps)
    mean_sp = sum(sp) / len(sp)
    # paper: ~41x arithmetic-mean speedup, 31-54x across models
    assert 25 < mean_sp < 60, sp
    # paper: 2.38-2.49x heterogeneous-vs-DRAM-only speedup
    assert all(1.5 < x < 3.5 for x in do), do


def test_int8_grad_compression_pipeline():
    """Compressed cross-pod gradient exchange preserves update direction."""
    from repro.core.quant import compress_grad, decompress_grad
    g = jax.random.normal(jax.random.PRNGKey(0), (512,)) * 1e-2
    q, s = compress_grad(g)
    assert q.dtype == jnp.int8
    back = decompress_grad(q, s)
    cos = float(jnp.sum(back * g)
                / (jnp.linalg.norm(back) * jnp.linalg.norm(g)))
    assert cos > 0.999
