"""Serving-level contracts for the fused paged-decode attention path.

The fused kernel (`kernels/paged_decode.py`) is opt-in behind
``fused_decode`` / ``REPRO_SERVE_FUSED_DECODE``; the unfused two-segment
merge stays the parity oracle. Held here:

  * engine token streams are IDENTICAL fused vs unfused (tiered + flat,
    local + sharded) — with the sparse read off the kernel is an exact
    (f32-associativity) twin and greedy argmax never flips;
  * MLA-only architectures resolve the knob to off (the fused path is
    GQA-only) and keep serving byte-identically;
  * knob resolution: explicit arg > cfg flag > env var, sparse read
    gated on fused;
  * the telemetry TierLedger reconciles BIT-for-bit with
    `simulated_efficiency` on drained fused and fused+sparse runs, and
    the sparse run books skipped bytes.
"""

import jax
import pytest
from conftest import build_model as _model
from conftest import generated as _generated
from conftest import make_mesh as _mesh
from conftest import make_requests as _requests

from repro.serving import (Engine, LocalBackend, ShardedBackend,
                           simulated_efficiency)
from repro.serving.telemetry import Telemetry

jax.config.update("jax_platform_name", "cpu")

SPECS = [(16, 6), (13, 6), (8, 4)]


def _run(backend, cfg, specs=SPECS, seed=3, telemetry=None):
    eng = Engine(backend, telemetry=telemetry)
    done = eng.run(_requests(cfg, specs, seed=seed), max_steps=300)
    return _generated(done), done


# ---------------------------------------------------------------------------
# token parity, local
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kv_policy", ["tiered", "flat"])
def test_fused_matches_unfused_local(kv_policy):
    cfg, model, params = _model(kv_policy=kv_policy)
    base, _ = _run(LocalBackend(model, params, 2, 24,
                                fused_decode=False), cfg)
    be = LocalBackend(model, params, 2, 24, fused_decode=True)
    assert be.fused_decode and be.model.cfg.fused_decode
    fused, _ = _run(be, cfg)
    assert fused == base


def test_fused_matches_unfused_ragged_slots():
    """Slots at different context depths share the vmapped kernel: mixed
    prompt lengths + slot recycling through 2 slots."""
    cfg, model, params = _model()
    specs = [(16, 8), (5, 8), (13, 4), (8, 6)]
    base, _ = _run(LocalBackend(model, params, 2, 24), cfg, specs)
    fused, _ = _run(LocalBackend(model, params, 2, 24,
                                 fused_decode=True), cfg, specs)
    assert fused == base


def test_mla_arch_resolves_knob_off_and_serves_identically():
    cfg, model, params = _model("deepseek-v2-lite")
    be = LocalBackend(model, params, 2, 24, fused_decode=True,
                      sparse_read=0.1)
    assert not be.fused_decode          # GQA-only: knob stays truthful
    assert be.sparse_read_tau == 0.0    # sparse gated on fused
    fused, _ = _run(be, cfg)
    base, _ = _run(LocalBackend(model, params, 2, 24), cfg)
    assert fused == base


# ---------------------------------------------------------------------------
# token parity, sharded
# ---------------------------------------------------------------------------
def test_fused_matches_unfused_sharded():
    """Fused sharded == unfused local on whatever devices this process
    has (1 locally, 8 in the CI multi-device job)."""
    cfg, model, params = _model()
    base, _ = _run(LocalBackend(model, params, 4, 24), cfg)
    be = ShardedBackend(model, params, 4, 24, mesh=_mesh(),
                        fused_decode=True)
    assert be.fused_decode
    fused, _ = _run(be, cfg)
    assert fused == base
    assert Engine(be).endurance_report()["write_once_ok"]


# ---------------------------------------------------------------------------
# knob resolution
# ---------------------------------------------------------------------------
def test_env_knobs_resolve(monkeypatch):
    cfg, model, params = _model()
    monkeypatch.setenv("REPRO_SERVE_FUSED_DECODE", "1")
    monkeypatch.setenv("REPRO_SERVE_SPARSE_READ", "0.01")
    be = LocalBackend(model, params, 2, 24)
    assert be.fused_decode and be.sparse_read_tau == 0.01
    assert be.model.cfg.sparse_read_tau == 0.01
    # explicit arg beats the env
    be_off = LocalBackend(model, params, 2, 24, fused_decode=False)
    assert not be_off.fused_decode and be_off.sparse_read_tau == 0.0
    # garbage env value must not wedge startup
    monkeypatch.setenv("REPRO_SERVE_SPARSE_READ", "not-a-float")
    assert LocalBackend(model, params, 2, 24).sparse_read_tau == 0.0


def test_cfg_flag_resolves_without_env():
    cfg, model, params = _model()
    from repro.models import Model
    m2 = Model(cfg.replace(fused_decode=True, sparse_read_tau=1e-3))
    be = LocalBackend(m2, params, 2, 24)
    assert be.fused_decode and be.sparse_read_tau == 1e-3


# ---------------------------------------------------------------------------
# ledger reconciliation
# ---------------------------------------------------------------------------
def _reconcile(fused, tau=0.0):
    cfg, model, params = _model()
    be = LocalBackend(model, params, 2, 24, fused_decode=fused,
                      sparse_read=tau)
    tel = Telemetry()
    _, done = _run(be, cfg, telemetry=tel)
    sim = simulated_efficiency(cfg, done,
                               fused_decode=be.fused_decode,
                               sparse_read_tau=be.sparse_read_tau)
    led = tel.ledger.totals()
    return led, sim


def test_ledger_reconciles_bit_for_bit_fused():
    led, sim = _reconcile(fused=True)
    assert led["sim_energy_j"] == sim["sim_energy_j"]
    assert led["sim_total_s"] == sim["sim_total_s"]
    assert sim["sim_fused_decode"] and sim["sim_sparse_read_tau"] == 0.0
    assert led["sparse_skipped_bytes"] == 0.0


def test_ledger_reconciles_bit_for_bit_sparse():
    led, sim = _reconcile(fused=True, tau=1e-3)
    assert led["sim_energy_j"] == sim["sim_energy_j"]
    assert led["sim_total_s"] == sim["sim_total_s"]
    assert led["sparse_skipped_bytes"] > 0.0
    led_f, sim_f = _reconcile(fused=True)
    # the priced skip fraction makes the sparse run strictly cheaper
    assert led["sim_energy_j"] < led_f["sim_energy_j"]


def test_fused_and_unfused_price_differently_but_both_reconcile():
    led_u, sim_u = _reconcile(fused=False)
    led_f, sim_f = _reconcile(fused=True)
    assert led_u["sim_energy_j"] == sim_u["sim_energy_j"]
    assert not sim_u["sim_fused_decode"]
    # fused moves the cold bytes to the RRAM domain: totals must differ
    assert led_f["sim_energy_j"] != led_u["sim_energy_j"]
    assert led_f["sim_energy_split_j"]["rram"] \
        > led_u["sim_energy_split_j"]["rram"]
