"""RRAM weight streaming (layer-granular weight pool) + the overlap /
pricing bugfixes that ride with it.

Held here:

* STREAMING IS BIT-EXACT — a backend streaming per-layer weight slices
  from the simulated RRAM tier (``weight_stream``) produces EXACTLY the
  token streams of the resident-weight run, on GQA, MLA(+MoE), RWKV6
  and hybrid-Mamba2, local and sharded, whole-prompt and chunked. The
  streamed scan carries the current layer's params through the carry
  (the prefetch double buffer) but computes the same values in the same
  order, so the resident run stays the parity oracle.
* KNOBS ARE TRUTHFUL — explicit arg > cfg flag > REPRO_SERVE_WEIGHT_STREAM,
  and the resolved knob is 0 whenever nothing would actually stream
  (window >= every unit's repeats, scan_layers off).
* THE SPLIT MATH IS THE PAPER'S — `weight_stream_split` keeps
  embeddings/head/shared-attention and a `stream_window_repeats` DRAM
  window resident while full per-layer slices live in RRAM.
* LEDGER RECONCILES — the telemetry TierLedger totals match
  `simulated_efficiency` BIT-for-bit on a drained streamed run, and the
  weight_stream domain books real bytes/energy.
* ADMISSION CHARGES WEIGHTS — the DRAM gate sees the resident weight
  working set: a nemotron-4-340b resident config is denied under a
  DRAM budget a fraction of its param bytes ("dram_weights") while its
  streamed twin is admissible; end-to-end, the reduced config decodes
  under a budget only the streamed working set fits.
* SATELLITE FIXES — `compressed_pod_allreduce` quantizes every pod onto
  the pmax-shared int8 grid (the old mean-of-scales dequant is shown
  wrong on mismatched pod magnitudes), `unrolled_scan` at unroll=1
  lowers identically to a plain `lax.scan` (and the cfg-driven unroll
  keeps token parity), and `_kernel_time_energy` honors
  ``weight_dtype_bytes`` (int8 weights price half the bf16 bytes).
"""

import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import build_model as _model
from conftest import forced_device_env
from conftest import generated as _generated
from conftest import make_mesh as _mesh
from conftest import make_requests as _requests
from conftest import oracle_tokens

from repro.configs.base import get_config
from repro.models import Model
from repro.models.counting import (count_params, layer_weight_elems,
                                   param_dtype_bytes, stream_window_repeats,
                                   streamed_unit_indices, weight_stream_split,
                                   weight_units)
from repro.runtime.overlap import compressed_pod_allreduce, unrolled_scan
from repro.serving import (CapacityBudget, Engine, FCFSScheduler,
                           LocalBackend, ShardedBackend,
                           simulated_efficiency)
from repro.serving.telemetry import Telemetry
from repro.simulator import chime_sim
from repro.simulator.hardware import CHIME

jax.config.update("jax_platform_name", "cpu")

# per-arch serving shapes (recurrent archs keep their chunk grid and
# need the longer max_len — same cases the spill/chunked suites use)
CASES = {
    "granite-3-2b": dict(specs=[(16, 6), (13, 6), (8, 4)],
                         max_len=24, chunk=5),
    "deepseek-v2-lite": dict(specs=[(16, 6), (13, 6), (8, 4)],
                             max_len=24, chunk=5),
    "rwkv6-7b": dict(specs=[(40, 6), (35, 4)], max_len=48, chunk=32),
    "zamba2-1.2b": dict(specs=[(40, 6), (24, 4)], max_len=48, chunk=16),
}
ARCHS = list(CASES)


def _run(backend, cfg, specs, seed=3, telemetry=None, chunk=None,
         scheduler=None):
    eng = Engine(backend, scheduler=scheduler, chunk_tokens=chunk,
                 telemetry=telemetry)
    done = eng.run(_requests(cfg, specs, seed=seed), max_steps=400)
    return _generated(done), done


# ---------------------------------------------------------------------------
# token parity: streamed == resident (the resident run is the oracle)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ARCHS)
def test_streamed_matches_resident_local(arch):
    case = CASES[arch]
    cfg, model, params = _model(arch)
    base, _ = _run(LocalBackend(model, params, 2, case["max_len"],
                                weight_stream=0), cfg, case["specs"])
    be = LocalBackend(model, params, 2, case["max_len"], weight_stream=1)
    assert be.weight_stream == 1
    assert be.model.cfg.weight_stream_layers == 1
    assert be.model.streamed_units()     # something actually streams
    streamed, _ = _run(be, cfg, case["specs"])
    assert streamed == base
    # chunked prefill drains through the same streamed scan
    chunked, _ = _run(be, cfg, case["specs"], chunk=case["chunk"])
    assert chunked == base


@pytest.mark.parametrize("arch", ["granite-3-2b", "zamba2-1.2b"])
def test_streamed_matches_resident_sharded(arch):
    """Streamed sharded == resident local on whatever devices this
    process has (1 locally, 8 in the CI multi-device job)."""
    case = CASES[arch]
    cfg, model, params = _model(arch)
    base, _ = _run(LocalBackend(model, params, 2, case["max_len"],
                                weight_stream=0), cfg, case["specs"])
    be = ShardedBackend(model, params, 2, case["max_len"], mesh=_mesh(),
                        weight_stream=1)
    assert be.weight_stream == 1
    streamed, _ = _run(be, cfg, case["specs"])
    assert streamed == base
    chunked, _ = _run(be, cfg, case["specs"], chunk=case["chunk"])
    assert chunked == base


# ---------------------------------------------------------------------------
# knob resolution: explicit arg > cfg flag > env, and always truthful
# ---------------------------------------------------------------------------
def test_env_knob_resolves(monkeypatch):
    cfg, model, params = _model()
    monkeypatch.setenv("REPRO_SERVE_WEIGHT_STREAM", "1")
    be = LocalBackend(model, params, 2, 24)
    assert be.weight_stream == 1
    assert be.model.cfg.weight_stream_layers == 1
    # explicit arg beats the env
    be_off = LocalBackend(model, params, 2, 24, weight_stream=0)
    assert be_off.weight_stream == 0
    assert be_off.model.cfg.weight_stream_layers == 0
    # garbage env value must not wedge startup
    monkeypatch.setenv("REPRO_SERVE_WEIGHT_STREAM", "not-an-int")
    assert LocalBackend(model, params, 2, 24).weight_stream == 0


def test_cfg_flag_resolves_without_env():
    cfg, model, params = _model()
    m2 = Model(cfg.replace(weight_stream_layers=1))
    be = LocalBackend(m2, params, 2, 24)
    assert be.weight_stream == 1
    assert be.model.streamed_units()


def test_knob_resolves_off_when_nothing_streams():
    cfg, model, params = _model()
    # window deeper than every unit's repeat count: whole model already
    # fits the DRAM window, so the knob must resolve off — and the
    # weight split must put every byte in DRAM
    be = LocalBackend(model, params, 2, 24, weight_stream=999)
    assert be.weight_stream == 0
    dram, rram = be.weight_bytes()
    assert rram == 0
    assert dram == count_params(cfg) * param_dtype_bytes(cfg)
    # unscanned layers cannot stream
    m2 = Model(cfg.replace(scan_layers=False, weight_stream_layers=1))
    assert LocalBackend(m2, params, 2, 24).weight_stream == 0


# ---------------------------------------------------------------------------
# the working-set split math
# ---------------------------------------------------------------------------
def test_weight_stream_split_hand_math():
    cfg = get_config("nemotron-4-340b", reduced=True).replace(
        weight_stream_layers=1)
    units = weight_units(cfg)
    assert len(units) == 1
    mixer, mlp, d_ff, r = units[0]
    assert r == 3 and streamed_unit_indices(cfg) == (0,)
    ib = param_dtype_bytes(cfg)
    lb = layer_weight_elems(cfg, mixer, mlp, d_ff) * ib
    total = count_params(cfg) * ib
    win = stream_window_repeats(cfg, r)
    assert win == 2                       # double-buffer floor beats W=1
    dram, rram = weight_stream_split(cfg)
    assert dram == total - (r - win) * lb
    assert rram == r * lb
    assert weight_stream_split(cfg.replace(weight_stream_layers=0)) \
        == (total, 0)


def test_shared_attention_units_never_stream():
    cfg = get_config("zamba2-1.2b", reduced=True).replace(
        weight_stream_layers=1)
    mixers = [m for (m, _, _, _) in weight_units(cfg)]
    assert mixers == ["mamba2", "attn_shared", "mamba2", "attn_shared"]
    # only the per-layer-parameterized mamba2 units stream; the single
    # shared attention weight set stays DRAM-resident
    assert streamed_unit_indices(cfg) == (0, 2)


# ---------------------------------------------------------------------------
# ledger reconciliation + weight-stream pricing
# ---------------------------------------------------------------------------
def _reconcile(weight_stream):
    cfg, model, params = _model()
    be = LocalBackend(model, params, 2, 24, weight_stream=weight_stream)
    tel = Telemetry()
    _, done = _run(be, cfg, CASES["granite-3-2b"]["specs"], telemetry=tel)
    # the RESOLVED cfg: per-layer streamed flags are baked into
    # `cost_layers(cfg)`, so pricing must see the backend's view
    sim_cfg, _ = be.sim_context()
    sim = simulated_efficiency(sim_cfg, done,
                               weight_stream=bool(be.weight_stream))
    return tel.ledger.totals(), sim


def test_ledger_reconciles_bit_for_bit_streamed():
    led, sim = _reconcile(weight_stream=1)
    assert led["sim_energy_j"] == sim["sim_energy_j"]
    assert led["sim_total_s"] == sim["sim_total_s"]
    assert sim["sim_weight_stream"]
    assert led["weight_stream_bytes"] > 0.0
    assert sim["sim_energy_split_j"].get("weight_stream", 0.0) > 0.0


def test_streaming_prices_strictly_above_resident():
    led_r, sim_r = _reconcile(weight_stream=0)
    led_s, sim_s = _reconcile(weight_stream=1)
    assert led_r["sim_energy_j"] == sim_r["sim_energy_j"]
    assert not sim_r["sim_weight_stream"]
    assert led_r["weight_stream_bytes"] == 0.0
    # re-reading streamed layer slices every step costs real energy
    assert led_s["sim_energy_j"] > led_r["sim_energy_j"]


def test_kernel_pricing_honors_weight_dtype_bytes():
    """Satellite fix: `_kernel_time_energy` used to IGNORE its
    ``weight_dtype_bytes`` argument and price every kernel's static
    bf16 byte counts verbatim. int8 weights must price exactly half
    the bytes (time and byte-energy both), f32 exactly double."""
    dom = CHIME.domains["rram"]
    t2, e2 = chime_sim._kernel_time_energy(dom, 0.0, 4096.0,
                                           CHIME.compute_pj_flop,
                                           weight_dtype_bytes=2.0)
    t1, e1 = chime_sim._kernel_time_energy(dom, 0.0, 4096.0,
                                           CHIME.compute_pj_flop,
                                           weight_dtype_bytes=1.0)
    t4, e4 = chime_sim._kernel_time_energy(dom, 0.0, 4096.0,
                                           CHIME.compute_pj_flop,
                                           weight_dtype_bytes=4.0)
    assert (t1, e1) == (t2 / 2, e2 / 2)
    assert (t4, e4) == (t2 * 2, e2 * 2)
    assert t2 > 0 and e2 > 0


def test_streamed_layer_bytes_follow_param_dtype():
    cfg = get_config("nemotron-4-340b", reduced=True).replace(
        weight_stream_layers=1)
    lay = chime_sim.cost_layers(cfg)[0]
    assert lay["streamed"]
    raw = chime_sim._layer_weight_raw_bytes(lay)
    assert raw > 0
    cfg_i8 = cfg.replace(param_dtype="int8")
    cfg_f32 = cfg.replace(param_dtype="float32")
    assert chime_sim.layer_stream_bytes(cfg_i8, lay) == raw / 2
    assert chime_sim.layer_stream_bytes(cfg_f32, lay) == raw * 2
    term_i8 = chime_sim.weight_stream_layer_terms(cfg_i8, CHIME, lay,
                                                  hide_s=0.0)[0]
    term_f32 = chime_sim.weight_stream_layer_terms(cfg_f32, CHIME, lay,
                                                   hide_s=0.0)[0]
    assert term_i8.domain == "weight_stream"
    assert term_i8.bytes_moved == raw / 2
    assert term_f32.bytes_moved == raw * 2


# ---------------------------------------------------------------------------
# DRAM admission charges the weight working set
# ---------------------------------------------------------------------------
def test_full_nemotron_admission_analytic():
    """The acceptance scenario in pure host arithmetic (the full 340B
    config is never initialized): under a DRAM budget that fits only a
    fraction of the param bytes, the resident model can never admit
    anything ("dram_weights") while the streamed working set leaves
    real KV headroom."""
    cfg = get_config("nemotron-4-340b")
    total = count_params(cfg) * param_dtype_bytes(cfg)
    assert total > 500e9                  # ~340B bf16 params
    cfg_s = cfg.replace(weight_stream_layers=1)
    dram_w, rram_w = weight_stream_split(cfg_s)
    assert dram_w + rram_w > total        # window slices double-counted
    budget = CapacityBudget(dram_bytes=0.1 * total,
                            rram_bytes=rram_w + 2**34)
    hot, cold = 2**20, 2**20              # nominal per-slot KV
    assert dram_w < budget.dram_bytes < total
    # resident: the weights alone overflow DRAM — nothing ever admits
    assert budget.deny_reason(0, hot, cold, weight_bytes=total) \
        == "dram_weights"
    assert budget.max_concurrent(hot, cold, weight_bytes=total) == 0
    # streamed: the working set leaves headroom for real concurrency
    assert budget.deny_reason(0, hot, cold, weight_bytes=dram_w) is None
    assert budget.max_concurrent(hot, cold, weight_bytes=dram_w) >= 1
    # the byte-charging (paged) gate agrees
    assert budget.deny_reason_bytes(hot, cold, weight_bytes=total) \
        == "dram_weights"
    assert budget.deny_reason_bytes(hot, cold, weight_bytes=dram_w) is None


def test_streamed_decodes_under_budget_that_denies_resident():
    """End-to-end on the reduced nemotron config: a DRAM budget of
    exactly the resident weight bytes leaves the resident engine zero
    KV headroom (construction refuses — nothing could ever be admitted)
    while the streamed twin's smaller working set serves to completion
    with oracle-exact tokens."""
    cfg, model, params = _model("nemotron-4-340b")
    be_res = LocalBackend(model, params, 2, 24, weight_stream=0)
    be_str = LocalBackend(model, params, 2, 24, weight_stream=1)
    wb_res = be_res.weight_bytes()[0]
    dram_w = be_str.weight_bytes()[0]
    hot_b, cold_b = be_res.slot_kv_bytes()
    assert dram_w + hot_b <= wb_res       # the budget can split them
    budget = CapacityBudget(float(wb_res), 1e15)

    def sched():
        return FCFSScheduler(budget, hot_b, cold_b)

    with pytest.raises(ValueError, match="weight working set"):
        Engine(be_res, scheduler=sched(), charge_weights=True)
    reqs = _requests(cfg, [(8, 4), (6, 4)], seed=7)
    eng = Engine(be_str, scheduler=sched(), charge_weights=True)
    assert eng.charge_weights and eng.scheduler.weight_bytes == dram_w
    done = eng.run(reqs, max_steps=200)
    assert len(done) == len(reqs)
    for req in sorted(done, key=lambda r: r.rid):
        assert req.generated == oracle_tokens(model, params, req)


def test_charge_weights_env_knob(monkeypatch):
    cfg, model, params = _model()
    be = LocalBackend(model, params, 2, 24, weight_stream=0)
    # default: no streaming -> legacy KV-only accounting
    assert not Engine(be).charge_weights
    monkeypatch.setenv("REPRO_SERVE_CHARGE_WEIGHTS", "1")
    eng = Engine(be)
    assert eng.charge_weights
    assert eng.scheduler.weight_bytes == be.weight_bytes()[0]
    # explicit arg beats the env
    assert not Engine(be, charge_weights=False).charge_weights
    monkeypatch.delenv("REPRO_SERVE_CHARGE_WEIGHTS")
    # streaming backends charge by default
    be_s = LocalBackend(model, params, 2, 24, weight_stream=1)
    assert Engine(be_s).charge_weights


# ---------------------------------------------------------------------------
# satellite: compressed_pod_allreduce quantizes onto the SHARED scale
# ---------------------------------------------------------------------------
# pod 0 carries tiny grads, pod 1 large ones, on DISJOINT elements: the
# old per-pod-scale + mean-scale dequant inflates pod 0's payload by
# ~scale_1/scale_0, a catastrophic error the shared pmax grid cannot make
_G0 = np.array([1e-4, 0.0, 5e-5, -1e-4], np.float32)
_G1 = np.array([0.0, 1.27, 0.0, -0.13], np.float32)


def _buggy_mean_scale(g0, g1):
    """The pre-fix math: each pod quantizes on its OWN grid, the int32
    payload sum is dequantized with the mean of the scales."""
    def scale(g):
        m = np.abs(g).max()
        return m / 127.0 if m > 0 else 1.0
    s0, s1 = scale(g0), scale(g1)
    q0 = np.clip(np.round(g0 / s0), -127, 127)
    q1 = np.clip(np.round(g1 / s1), -127, 127)
    return (q0 + q1) * ((s0 + s1) / 2.0) / 2.0


def _check_pod_allreduce():
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    devs = jax.devices()[:2]
    mesh = Mesh(np.array(devs), ("pod",))
    sharding = NamedSharding(mesh, P(*([None] * _G0.ndim)))
    arr = jax.make_array_from_single_device_arrays(
        _G0.shape, sharding,
        [jax.device_put(jnp.asarray(_G0), devs[0]),
         jax.device_put(jnp.asarray(_G1), devs[1])])
    out = np.asarray(compressed_pod_allreduce({"w": arr}, mesh)["w"])
    expected = (_G0 + _G1) / 2.0
    shared = np.abs(np.concatenate([_G0, _G1])).max() / 127.0
    # each pod's round error is <= shared/2; the mean of 2 pods too
    np.testing.assert_allclose(out, expected, atol=shared / 2 + 1e-7)
    # the regression: mean-of-scales dequant is catastrophically wrong
    # on these magnitudes (pod 0's payload inflated ~s1/s0 ~ 6000x)
    buggy_err = np.abs(_buggy_mean_scale(_G0, _G1) - expected).max()
    assert buggy_err > 10 * shared, buggy_err


def test_pod_allreduce_shared_scale_regression():
    if jax.device_count() >= 2:
        _check_pod_allreduce()
        return
    from conftest import REPO
    proc = subprocess.run(
        [sys.executable, __file__, "--pod-allreduce-selfcheck"],
        cwd=REPO, env=forced_device_env(2), capture_output=True,
        text=True, timeout=600)
    assert proc.returncode == 0, (
        f"pod allreduce selfcheck failed:\n{proc.stdout}\n{proc.stderr}")
    assert "POD ALLREDUCE OK" in proc.stdout


def test_pod_allreduce_passthrough_without_pod_axis():
    grads = {"w": jnp.ones((2, 2))}
    assert compressed_pod_allreduce(grads, _mesh()) is grads


# ---------------------------------------------------------------------------
# satellite: unrolled_scan is wired and unroll=1 is a plain scan
# ---------------------------------------------------------------------------
def _scan_body(c, x):
    return c + x, c * x


def test_unrolled_scan_unroll1_lowers_identically():
    xs = jnp.arange(6, dtype=jnp.float32)
    c0 = jnp.float32(1.0)

    def helper(c, x):
        return unrolled_scan(_scan_body, c, x, unroll=1)

    def plain(c, x):
        return jax.lax.scan(_scan_body, c, x)

    t_h = jax.jit(helper).lower(c0, xs).as_text().replace("helper", "f")
    t_p = jax.jit(plain).lower(c0, xs).as_text().replace("plain", "f")
    assert t_h == t_p
    # ...and unroll=2 actually changes the lowering (the scheduler
    # window exists), while computing the same values
    def helper2(c, x):
        return unrolled_scan(_scan_body, c, x, unroll=2)

    t_h2 = jax.jit(helper2).lower(c0, xs).as_text().replace("helper2", "f")
    assert t_h2 != t_p
    a = jax.jit(helper)(c0, xs)
    b = jax.jit(helper2)(c0, xs)
    assert a[0] == b[0]
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


def test_cfg_scan_unroll_keeps_token_parity():
    """`_run_unit` now routes its layer scan through `unrolled_scan`
    with the cfg-driven unroll factor; any unroll must serve the same
    tokens."""
    case = CASES["granite-3-2b"]
    cfg, model, params = _model()
    assert cfg.scan_unroll == 1
    base, _ = _run(LocalBackend(model, params, 2, case["max_len"]),
                   cfg, case["specs"])
    m2 = Model(cfg.replace(scan_unroll=2))
    unrolled, _ = _run(LocalBackend(m2, params, 2, case["max_len"]),
                       cfg, case["specs"])
    assert unrolled == base
    # streamed scan under an explicit unroll stays on the oracle too
    m3 = Model(cfg.replace(scan_unroll=3, weight_stream_layers=1))
    streamed, _ = _run(LocalBackend(m3, params, 2, case["max_len"]),
                       cfg, case["specs"])
    assert streamed == base


# ---------------------------------------------------------------------------
# subprocess entry point
# ---------------------------------------------------------------------------
if __name__ == "__main__":
    if "--pod-allreduce-selfcheck" in sys.argv:
        assert jax.device_count() >= 2, jax.device_count()
        _check_pod_allreduce()
        print("POD ALLREDUCE OK")
