"""Two-cut-point dataflow audit against real lowered HLO on a multi-device
mesh. Runs in a subprocess because the 8-device host platform must be
configured before jax initializes (the rest of the suite sees 1 device)."""

import json
import os
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import json
import jax
from repro.configs.base import get_config
from repro.core.dataflow import count_collectives, lower_single_layer_hlo

mesh = jax.make_mesh((2, 8), ("data", "model"))
out = {}
for arch in ("granite-3-2b", "rwkv6-7b"):
    cfg = get_config(arch, reduced=True).replace(
        param_dtype="float32", compute_dtype="bfloat16")
    # widen so dims divide the 8-way model axis
    cfg = cfg.replace(d_model=128, d_ff=256, num_heads=8, num_kv_heads=8,
                      head_dim=16, vocab_size=256)
    hlo = lower_single_layer_hlo(cfg, mesh, batch=4, seq=32)
    out[arch] = count_collectives(hlo)
print("RESULT:" + json.dumps(out))
"""


@pytest.mark.slow
def test_per_layer_collective_budget():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT:")][0]
    res = json.loads(line[len("RESULT:"):])
    for arch, counts in res.items():
        total = sum(counts.values())
        # one-layer forward: the TP reductions at exactly the two cut
        # points (AttnOut partial-sum, FFNOut partial-sum) plus the
        # sharded-embedding gather. The CHIME fusion discipline means no
        # other collective fires inside a layer.
        assert total <= 5, (arch, counts)
        assert counts.get("all-reduce", 0) >= 2, (arch, counts)
