"""Hypothesis property tests on serving-scheduler invariants.

Over random request streams (lengths, priorities), random byte budgets
and random knob settings (token budget, chunk cap, oversubscription,
spill lanes, idle-offload threshold), a simulated engine loop drives
`FCFSScheduler.plan` and checks on every step that the scheduler:

* never plans prefill past the per-step token budget (decode slots,
  including restored ones, take one token each; chunk_unit=1 so no
  grid-rounding slack applies);
* never admits past the DRAM/RRAM gating — the oversubscribed DRAM gate,
  the spill-lane backing of overflow residents, and the RRAM budget
  (resident cold tiers + occupied spill-lane images);
* preserves FCFS admission order within a priority class;
* only evicts running victims that a strictly higher-priority waiter
  outranks, and only into free lanes; only restores what it spilled;
* offload legality: spills at most ONE victim per plan (eviction OR
  idle offload), only offloads runners resident >= the idle threshold,
  never offloads a request it also restores or admits in the same plan,
  and conserves lanes (parked images never exceed spill_lanes);
* with preemption out of the picture (uniform priorities, no
  oversubscription), drains every request (liveness) — with AND without
  idle offload enabled;
* idle-threshold monotonicity: from one identical planning state, a
  larger idle_offload_steps never offloads more than a smaller one.

The scheduler-loop tests are host-only (no jax, no model — thousands of
scheduler steps per second); the int8 spill-codec round-trip suite at
the bottom imports jax to hold `core.quant`'s compress/decompress to the
documented error bound over random shapes and scales.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.serving import CapacityBudget, FCFSScheduler, Request  # noqa: E402

HOT, COLD = 100, 40
SLOT = HOT + COLD


def _req(rid, plen, gen, prio):
    return Request(rid=rid, tokens=np.zeros(plen, np.int32),
                   max_new_tokens=gen, priority=prio)


@st.composite
def scenarios(draw):
    n_req = draw(st.integers(1, 9))
    reqs = [(draw(st.integers(1, 24)), draw(st.integers(1, 5)),
             draw(st.integers(0, 2))) for _ in range(n_req)]
    dram_slots = draw(st.integers(1, 5))
    rram_slots = draw(st.integers(2, 12))
    num_slots = draw(st.integers(1, 6))
    token_budget = draw(st.one_of(st.none(), st.integers(1, 20)))
    chunk_tokens = draw(st.one_of(st.none(), st.integers(1, 8)))
    oversubscribe = draw(st.sampled_from([None, 1.0, 1.5, 2.0]))
    spill_lanes = draw(st.integers(0, 4))
    idle_offload = draw(st.one_of(st.none(), st.integers(1, 4)))
    return (reqs, dram_slots, rram_slots, num_slots, token_budget,
            chunk_tokens, oversubscribe, spill_lanes, idle_offload)


def _drive(reqs, dram_slots, rram_slots, num_slots, token_budget,
           chunk_tokens, oversubscribe, spill_lanes, idle_offload=None,
           max_steps=80):
    """Simulated engine loop; returns (admitted_log, finished, state)."""
    dram_bytes = HOT * dram_slots
    rram_bytes = COLD * rram_slots + SLOT * spill_lanes
    sched = FCFSScheduler(CapacityBudget(dram_bytes, rram_bytes),
                          HOT, COLD, token_budget=token_budget,
                          chunk_tokens=chunk_tokens,
                          oversubscribe=oversubscribe,
                          spill_lanes=spill_lanes,
                          idle_offload_steps=idle_offload)
    requests = [_req(i, p, g, pr) for i, (p, g, pr) in enumerate(reqs)]
    for r in requests:
        sched.submit(r)
    active: list = []          # (req, remaining_gen) decoding
    inflight = None            # (req, next_pos)
    free_slots = num_slots
    spilled: dict = {}         # rid -> remaining_gen
    admitted_log: list = []
    finished: list = []
    offload_events = 0
    factor = oversubscribe or 1.0

    def gates_ok(residents, n_spilled):
        assert residents * HOT <= dram_bytes * factor + 1e-9
        base = dram_bytes // HOT
        overflow = residents - base
        if overflow > 0:
            assert overflow + n_spilled <= spill_lanes
        assert residents * COLD + n_spilled * SLOT <= rram_bytes + 1e-9

    for _ in range(max_steps):
        decode_before = len(active)
        running = tuple(r for r, _ in active)
        plan = sched.plan(
            active_slots=len(active) + (1 if inflight else 0),
            decode_slots=decode_before,
            free_slots=free_slots,
            inflight=inflight,
            chunk_unit=1,
            running=running,
            free_lanes=spill_lanes - len(spilled))

        # ---- spills: at most ONE victim per plan (preemption OR idle
        # offload), only running victims, only into free lanes ----------
        assert len(plan.evictions) + len(plan.offloads) <= 1, \
            "more than one victim in a single plan"
        for r in plan.offloads:
            assert idle_offload is not None, "offload with the knob off"
            assert r.resident_steps >= idle_offload, \
                "offloaded a runner inside its time slice"
            assert not any(r is o for o in plan.restores), \
                "offloaded a request restored in the same plan"
            assert not any(r is c.req for c in plan.chunks), \
                "offloaded a request admitted in the same plan"
            offload_events += 1
        for r in plan.evictions + plan.offloads:
            assert any(rr is r for rr, _ in active), "evicted non-runner"
            assert len(spilled) < spill_lanes, "evicted without a lane"
            gen = next(g for rr, g in active if rr is r)
            active = [(rr, g) for rr, g in active if rr is not r]
            spilled[r.rid] = gen
            free_slots += 1
        assert len(spilled) <= spill_lanes, "lane conservation violated"
        # ---- restores: only what was spilled -------------------------
        for r in plan.restores:
            assert r.rid in spilled, "restored a never-spilled request"
            assert free_slots > 0
            r.resident_steps = 0
            active.append((r, spilled.pop(r.rid)))
            free_slots -= 1

        # ---- token budget: chunks fit what decode leaves -------------
        eff_decode = len(active)
        if token_budget is not None:
            assert plan.prefill_tokens <= max(0,
                                              token_budget - eff_decode), \
                (plan.prefill_tokens, token_budget, eff_decode)

        # ---- chunks ---------------------------------------------------
        for c in plan.chunks:
            if c.admit:
                assert inflight is None, "second prompt while one in flight"
                assert free_slots > 0
                admitted_log.append(c.req)
                free_slots -= 1
                inflight = (c.req, 0)
                gates_ok(len(active) + 1, len(spilled))
            r, p = inflight
            assert c.req is r and c.start == p
            assert c.length >= 1
            inflight = None if c.commit else (r, p + c.length)
            if c.commit:
                assert p + c.length == r.prompt_len
                r.resident_steps = 0
                if r.max_new_tokens == 1:
                    finished.append(r)
                    free_slots += 1
                else:
                    active.append((r, r.max_new_tokens - 1))

        assert free_slots >= 0
        # slot conservation: occupied + free is exactly the pool
        assert len(active) + (1 if inflight else 0) + free_slots \
            == num_slots
        # ---- decode ---------------------------------------------------
        if plan.decode and active:
            nxt = []
            for r, g in active:
                g -= 1
                r.resident_steps += 1
                if g <= 0:
                    finished.append(r)
                    free_slots += 1
                else:
                    nxt.append((r, g))
            active = nxt
        if not (active or inflight or spilled or sched.pending):
            break
    return admitted_log, finished, (active, inflight, spilled, sched,
                                    offload_events)


@settings(max_examples=60, deadline=None)
@given(scenarios())
def test_scheduler_invariants_over_random_streams(sc):
    (reqs, dram_slots, rram_slots, num_slots, token_budget,
     chunk_tokens, oversubscribe, spill_lanes, idle_offload) = sc
    admitted, finished, _ = _drive(reqs, dram_slots, rram_slots,
                                   num_slots, token_budget, chunk_tokens,
                                   oversubscribe, spill_lanes,
                                   idle_offload)
    # FCFS within a priority class: rids are submission-ordered
    for prio in {pr for _, _, pr in reqs}:
        rids = [r.rid for r in admitted if r.priority == prio]
        assert rids == sorted(rids), "FCFS violated within a class"
    # nothing admitted twice, nothing invented
    assert len({r.rid for r in admitted}) == len(admitted)
    assert len({r.rid for r in finished}) == len(finished)


@settings(max_examples=40, deadline=None)
@given(scenarios())
def test_scheduler_drains_uniform_priority_streams(sc):
    """Liveness: no priorities, no oversubscription -> every submitted
    request finishes (FCFS cannot wedge while one resident fits)."""
    (reqs, dram_slots, rram_slots, num_slots, token_budget,
     chunk_tokens, _, _, _) = sc
    reqs = [(p, g, 0) for p, g, _ in reqs]
    _, finished, (active, inflight, spilled, sched, _) = _drive(
        reqs, dram_slots, rram_slots, num_slots, token_budget,
        chunk_tokens, None, 0,
        max_steps=40 + sum(p + g for p, g, _ in reqs) * 2)
    assert not (active or inflight or spilled or sched.pending)
    assert len(finished) == len(reqs)


@settings(max_examples=40, deadline=None)
@given(scenarios())
def test_scheduler_drains_with_idle_offload(sc):
    """Liveness under idle offload: equal-priority rotation through the
    RRAM lanes is time slicing, not starvation — every request still
    finishes, because a resident must decode idle_offload_steps (>= 1)
    tokens before it can be parked again."""
    (reqs, dram_slots, rram_slots, num_slots, token_budget,
     chunk_tokens, _, spill_lanes, idle_offload) = sc
    reqs = [(p, g, 0) for p, g, _ in reqs]
    _, finished, (active, inflight, spilled, sched, offloads) = _drive(
        reqs, dram_slots, rram_slots, num_slots, token_budget,
        chunk_tokens, None, spill_lanes, idle_offload or 1,
        max_steps=80 + sum(p + g for p, g, _ in reqs) * 6)
    assert not (active or inflight or spilled or sched.pending)
    assert len(finished) == len(reqs)


@settings(max_examples=60, deadline=None)
@given(scenarios(), st.integers(1, 3), st.integers(1, 4))
def test_idle_threshold_monotone_in_a_fixed_planning_state(sc, n_lo, dn):
    """From one identical planning state, raising idle_offload_steps can
    only shrink the offload set: every runner eligible at N + dn is
    eligible at N, and the rest of the plan inputs are equal."""
    (reqs, dram_slots, rram_slots, num_slots, token_budget,
     chunk_tokens, oversubscribe, spill_lanes, _) = sc
    spill_lanes = max(spill_lanes, 1)
    n_hi = n_lo + dn

    def _one_plan(threshold):
        dram_bytes = HOT * dram_slots
        rram_bytes = COLD * rram_slots + SLOT * spill_lanes
        sched = FCFSScheduler(CapacityBudget(dram_bytes, rram_bytes),
                              HOT, COLD, token_budget=token_budget,
                              chunk_tokens=chunk_tokens,
                              oversubscribe=oversubscribe,
                              spill_lanes=spill_lanes,
                              idle_offload_steps=threshold)
        running = []
        for i, (p, g, pr) in enumerate(reqs[1:]):
            r = _req(100 + i, p, g, pr)
            r.admit_seq = i
            r.resident_steps = p % 5        # deterministic residencies
            running.append(r)
        waiter = _req(0, *reqs[0])
        sched.submit(waiter)
        n_run = min(len(running), num_slots)
        running = running[:n_run]
        plan = sched.plan(active_slots=n_run, decode_slots=n_run,
                          free_slots=max(num_slots - n_run, 0),
                          inflight=None, running=tuple(running),
                          free_lanes=spill_lanes)
        return len(plan.offloads)

    assert _one_plan(n_hi) <= _one_plan(n_lo)


# ---------------------------------------------------------------------------
# int8 spill-codec round trip: |x - decode(encode(x))| <= rowmax / 254
# elementwise over random shapes and scales (core/quant.py contract).
# ---------------------------------------------------------------------------
@st.composite
def codec_arrays(draw):
    ndim = draw(st.integers(1, 4))
    shape = tuple(draw(st.integers(1, 6)) for _ in range(ndim - 1)) \
        + (draw(st.integers(1, 16)),)
    seed = draw(st.integers(0, 2 ** 31 - 1))
    log_scale = draw(st.floats(-4.0, 4.0))
    kind = draw(st.sampled_from(["normal", "uniform", "sparse", "zeros"]))
    return shape, seed, log_scale, kind


@settings(max_examples=60, deadline=None)
@given(codec_arrays())
def test_int8_spill_codec_round_trip_bound(arr):
    from repro.core.quant import (compress_spill_hot, decompress_spill_hot,
                                  spill_codec_bound)
    shape, seed, log_scale, kind = arr
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(shape).astype(np.float32) * 10.0 ** log_scale
    if kind == "uniform":
        x = rng.uniform(-1, 1, shape).astype(np.float32) \
            * 10.0 ** log_scale
    elif kind == "sparse":
        x = x * (rng.uniform(size=shape) < 0.3)
    elif kind == "zeros":
        x = np.zeros(shape, np.float32)
    q, scale = compress_spill_hot(x)
    assert np.asarray(q).dtype == np.int8
    assert np.asarray(scale).shape == shape[:-1] + (1,)
    back = np.asarray(decompress_spill_hot(q, scale, np.float32))
    bound = np.asarray(spill_codec_bound(x))
    # a hair of float32 slack on top of the analytic rowmax/254 bound
    assert np.all(np.abs(x - back) <= bound * (1 + 1e-4) + 1e-30), (
        np.max(np.abs(x - back) - bound), shape, kind)
    # all-zero rows reconstruct exactly
    rowmax = np.max(np.abs(x), axis=-1, keepdims=True)
    assert np.all(np.where(rowmax == 0, back == 0, True))
