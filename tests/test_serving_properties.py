"""Hypothesis property tests on serving-scheduler invariants.

Over random request streams (lengths, priorities), random byte budgets
and random knob settings (token budget, chunk cap, oversubscription,
spill lanes), a simulated engine loop drives `FCFSScheduler.plan` and
checks on every step that the scheduler:

* never plans prefill past the per-step token budget (decode slots,
  including restored ones, take one token each; chunk_unit=1 so no
  grid-rounding slack applies);
* never admits past the DRAM/RRAM gating — the oversubscribed DRAM gate,
  the spill-lane backing of overflow residents, and the RRAM budget
  (resident cold tiers + occupied spill-lane images);
* preserves FCFS admission order within a priority class;
* only evicts running victims that a strictly higher-priority waiter
  outranks, and only into free lanes; only restores what it spilled;
* with preemption out of the picture (uniform priorities, no
  oversubscription), drains every request (liveness).

Host-only: no jax, no model — thousands of scheduler steps per second.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.serving import CapacityBudget, FCFSScheduler, Request  # noqa: E402

HOT, COLD = 100, 40
SLOT = HOT + COLD


def _req(rid, plen, gen, prio):
    return Request(rid=rid, tokens=np.zeros(plen, np.int32),
                   max_new_tokens=gen, priority=prio)


@st.composite
def scenarios(draw):
    n_req = draw(st.integers(1, 9))
    reqs = [(draw(st.integers(1, 24)), draw(st.integers(1, 5)),
             draw(st.integers(0, 2))) for _ in range(n_req)]
    dram_slots = draw(st.integers(1, 5))
    rram_slots = draw(st.integers(2, 12))
    num_slots = draw(st.integers(1, 6))
    token_budget = draw(st.one_of(st.none(), st.integers(1, 20)))
    chunk_tokens = draw(st.one_of(st.none(), st.integers(1, 8)))
    oversubscribe = draw(st.sampled_from([None, 1.0, 1.5, 2.0]))
    spill_lanes = draw(st.integers(0, 4))
    return (reqs, dram_slots, rram_slots, num_slots, token_budget,
            chunk_tokens, oversubscribe, spill_lanes)


def _drive(reqs, dram_slots, rram_slots, num_slots, token_budget,
           chunk_tokens, oversubscribe, spill_lanes, max_steps=80):
    """Simulated engine loop; returns (admitted_log, finished, state)."""
    dram_bytes = HOT * dram_slots
    rram_bytes = COLD * rram_slots + SLOT * spill_lanes
    sched = FCFSScheduler(CapacityBudget(dram_bytes, rram_bytes),
                          HOT, COLD, token_budget=token_budget,
                          chunk_tokens=chunk_tokens,
                          oversubscribe=oversubscribe,
                          spill_lanes=spill_lanes)
    requests = [_req(i, p, g, pr) for i, (p, g, pr) in enumerate(reqs)]
    for r in requests:
        sched.submit(r)
    active: list = []          # (req, remaining_gen) decoding
    inflight = None            # (req, next_pos)
    free_slots = num_slots
    spilled: dict = {}         # rid -> remaining_gen
    admitted_log: list = []
    finished: list = []
    factor = oversubscribe or 1.0

    def gates_ok(residents, n_spilled):
        assert residents * HOT <= dram_bytes * factor + 1e-9
        base = dram_bytes // HOT
        overflow = residents - base
        if overflow > 0:
            assert overflow + n_spilled <= spill_lanes
        assert residents * COLD + n_spilled * SLOT <= rram_bytes + 1e-9

    for _ in range(max_steps):
        decode_before = len(active)
        running = tuple(r for r, _ in active)
        plan = sched.plan(
            active_slots=len(active) + (1 if inflight else 0),
            decode_slots=decode_before,
            free_slots=free_slots,
            inflight=inflight,
            chunk_unit=1,
            running=running,
            free_lanes=spill_lanes - len(spilled))

        # ---- evictions: only running victims, only into free lanes ----
        for r in plan.evictions:
            assert any(rr is r for rr, _ in active), "evicted non-runner"
            assert len(spilled) < spill_lanes, "evicted without a lane"
            gen = next(g for rr, g in active if rr is r)
            active = [(rr, g) for rr, g in active if rr is not r]
            spilled[r.rid] = gen
            free_slots += 1
        # ---- restores: only what was spilled -------------------------
        for r in plan.restores:
            assert r.rid in spilled, "restored a never-spilled request"
            assert free_slots > 0
            active.append((r, spilled.pop(r.rid)))
            free_slots -= 1

        # ---- token budget: chunks fit what decode leaves -------------
        eff_decode = len(active)
        if token_budget is not None:
            assert plan.prefill_tokens <= max(0,
                                              token_budget - eff_decode), \
                (plan.prefill_tokens, token_budget, eff_decode)

        # ---- chunks ---------------------------------------------------
        for c in plan.chunks:
            if c.admit:
                assert inflight is None, "second prompt while one in flight"
                assert free_slots > 0
                admitted_log.append(c.req)
                free_slots -= 1
                inflight = (c.req, 0)
                gates_ok(len(active) + 1, len(spilled))
            r, p = inflight
            assert c.req is r and c.start == p
            assert c.length >= 1
            inflight = None if c.commit else (r, p + c.length)
            if c.commit:
                assert p + c.length == r.prompt_len
                if r.max_new_tokens == 1:
                    finished.append(r)
                    free_slots += 1
                else:
                    active.append((r, r.max_new_tokens - 1))

        assert free_slots >= 0
        # slot conservation: occupied + free is exactly the pool
        assert len(active) + (1 if inflight else 0) + free_slots \
            == num_slots
        # ---- decode ---------------------------------------------------
        if plan.decode and active:
            nxt = []
            for r, g in active:
                g -= 1
                if g <= 0:
                    finished.append(r)
                    free_slots += 1
                else:
                    nxt.append((r, g))
            active = nxt
        if not (active or inflight or spilled or sched.pending):
            break
    return admitted_log, finished, (active, inflight, spilled, sched)


@settings(max_examples=60, deadline=None)
@given(scenarios())
def test_scheduler_invariants_over_random_streams(sc):
    (reqs, dram_slots, rram_slots, num_slots, token_budget,
     chunk_tokens, oversubscribe, spill_lanes) = sc
    admitted, finished, _ = _drive(reqs, dram_slots, rram_slots,
                                   num_slots, token_budget, chunk_tokens,
                                   oversubscribe, spill_lanes)
    # FCFS within a priority class: rids are submission-ordered
    for prio in {pr for _, _, pr in reqs}:
        rids = [r.rid for r in admitted if r.priority == prio]
        assert rids == sorted(rids), "FCFS violated within a class"
    # nothing admitted twice, nothing invented
    assert len({r.rid for r in admitted}) == len(admitted)
    assert len({r.rid for r in finished}) == len(finished)


@settings(max_examples=40, deadline=None)
@given(scenarios())
def test_scheduler_drains_uniform_priority_streams(sc):
    """Liveness: no priorities, no oversubscription -> every submitted
    request finishes (FCFS cannot wedge while one resident fits)."""
    (reqs, dram_slots, rram_slots, num_slots, token_budget,
     chunk_tokens, _, _) = sc
    reqs = [(p, g, 0) for p, g, _ in reqs]
    _, finished, (active, inflight, spilled, sched) = _drive(
        reqs, dram_slots, rram_slots, num_slots, token_budget,
        chunk_tokens, None, 0,
        max_steps=40 + sum(p + g for p, g, _ in reqs) * 2)
    assert not (active or inflight or spilled or sched.pending)
    assert len(finished) == len(reqs)
