"""CHIME core invariants: mapping-plan audit (two cut points), KV tier
endurance (write-once cold tier), quantization round-trips, tiered-vs-flat
decode agreement bounds."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ASSIGNED_ARCHS, get_config
from repro.core import kv_tiers as KT
from repro.core import quant
from repro.core.planner import plan_for

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# mapping framework
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_mapping_plan_two_cut_points(arch):
    plan = plan_for(get_config(arch))
    plan.audit()
    for lp in plan.layers:
        assert len(lp.cut_points) <= 2
        # every FFN-ish op is in the RRAM domain, attention in DRAM
        for p in lp.placements:
            if p.op in ("ffn", "moe_ffn", "channel_mix"):
                assert p.domain == "rram"
            if p.op in ("attention", "qkv_proj", "mla_attention"):
                assert p.domain == "dram"


def test_mapping_plan_applicability_notes():
    rwkv = plan_for(get_config("rwkv6-7b"))
    assert not rwkv.kv_tiering
    assert any("attention-free" in n for n in rwkv.notes)
    hubert = plan_for(get_config("hubert-xlarge"))
    assert not hubert.kv_tiering
    zamba = plan_for(get_config("zamba2-1.2b"))
    assert zamba.kv_tiering  # shared attention blocks do cache


def test_cross_domain_traffic_is_activation_only():
    cfg = get_config("granite-3-2b")
    plan = plan_for(cfg)
    per_tok = plan.cross_domain_bytes_per_token(cfg)
    # 40 layers x 2 cuts x d_model x 2B
    assert per_tok == 40 * 2 * cfg.d_model * 2
    # orders of magnitude below the FFN weight bytes it avoids moving
    ffn_bytes = 40 * 3 * cfg.d_model * cfg.d_ff * 2
    assert per_tok < ffn_bytes / 1000


# ---------------------------------------------------------------------------
# KV tiering (T2)
# ---------------------------------------------------------------------------
def test_tiered_append_write_once_endurance():
    B, L, W = 1, 64, 8
    inner = (2, 4)
    cache = KT.init_tiered(B, L, inner, hot_window=W)
    for pos in range(32):
        new = jnp.full((B, 1) + inner, float(pos), jnp.bfloat16)
        cache = KT.tiered_append(cache, new, jnp.asarray(pos))
    rep = KT.endurance_report(cache)
    # every cold block written at most once per slot: with ENDURANCE_BLOCK
    # 128 > L all evictions land in block 0, 24 evictions = 24 slot writes
    assert int(rep["total_cold_writes"]) == 32 - W
    # slot-level: each cold position was written exactly once => max writes
    # per block equals number of distinct positions evicted into it
    assert int(rep["max_writes_per_block"]) == 32 - W


def test_tiered_read_recovers_values():
    B, L, W = 1, 32, 4
    inner = (1, 8)
    cache = KT.init_tiered(B, L, inner, hot_window=W)
    vals = {}
    for pos in range(16):
        v = jax.random.normal(jax.random.PRNGKey(pos), (B, 1) + inner)
        vals[pos] = np.asarray(v, np.float32)
        cache = KT.tiered_append(cache, v.astype(jnp.bfloat16),
                                 jnp.asarray(pos))
    values, valid = KT.tiered_read(cache, jnp.asarray(15))
    positions = KT.combined_positions(cache, jnp.asarray(15))
    values = np.asarray(values, np.float32)
    valid = np.asarray(valid)
    positions = np.asarray(positions)
    seen = set()
    for i in range(values.shape[1]):
        if not valid[i]:
            continue
        p = int(positions[i])
        assert 0 <= p <= 15
        seen.add(p)
        tol = 0.02 if i < L else 0.01   # cold tier is int8-quantized
        np.testing.assert_allclose(values[:, i], vals[p][:, 0],
                                   rtol=tol, atol=tol * 4)
    assert seen == set(range(16))  # every position attendable exactly once


def test_tiered_from_full_matches_append_path():
    """Prefill (one-shot) and decode (incremental) construction agree."""
    B, S, L, W = 1, 16, 24, 4
    inner = (2, 4)
    full = jax.random.normal(jax.random.PRNGKey(0), (B, S) + inner)
    c1 = KT.tiered_from_full(full.astype(jnp.bfloat16), W, S, L)
    c2 = KT.init_tiered(B, L, inner, hot_window=W)
    for pos in range(S):
        c2 = KT.tiered_append(c2, full[:, pos:pos + 1].astype(jnp.bfloat16),
                              jnp.asarray(pos))
    v1, m1 = KT.tiered_read(c1, jnp.asarray(S - 1))
    v2, m2 = KT.tiered_read(c2, jnp.asarray(S - 1))
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))
    np.testing.assert_allclose(
        np.asarray(v1, np.float32)[:, np.asarray(m1)],
        np.asarray(v2, np.float32)[:, np.asarray(m2)], rtol=0.03, atol=0.1)


# ---------------------------------------------------------------------------
# quantization ("RRAM" storage)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bits", [8])
def test_blockwise_quant_roundtrip(bits):
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 256)) * 0.3
    q = quant.quantize(w, bits=bits, block=64)
    back = quant.dequantize(q, jnp.float32)
    err = np.abs(np.asarray(back) - np.asarray(w)).max()
    # worst case: half a quantization step of the largest block
    step = np.abs(np.asarray(w)).max() / (2 ** (bits - 1) - 1)
    assert err <= step


def test_grad_compression_roundtrip():
    g = jax.random.normal(jax.random.PRNGKey(2), (1024,)) * 1e-3
    q, s = quant.compress_grad(g)
    back = quant.decompress_grad(q, s)
    rel = np.abs(np.asarray(back - g)).max() / np.abs(np.asarray(g)).max()
    assert rel < 0.01


def test_int8_ffn_store_preserves_quality():
    """core/fusion int8 weight store: output close to bf16 path."""
    from repro.core.fusion import apply_ffn, place_ffn_weights_int8
    from repro.models.layers import ParamBuilder, init_mlp
    cfg = get_config("granite-3-2b", reduced=True).replace(
        param_dtype="float32", compute_dtype="float32")
    b = ParamBuilder(jax.random.PRNGKey(3), jnp.float32)
    mb = b.scope("mlp")
    init_mlp(mb, cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 8, cfg.d_model))
    ref_out = apply_ffn(b.params["mlp"], cfg, x, None)
    q_params = place_ffn_weights_int8({"mlp": b.params["mlp"]})
    q_out = apply_ffn(q_params["mlp"], cfg, x, None)
    cos = np.sum(np.asarray(ref_out) * np.asarray(q_out)) / (
        np.linalg.norm(np.asarray(ref_out))
        * np.linalg.norm(np.asarray(q_out)) + 1e-9)
    assert cos > 0.999
