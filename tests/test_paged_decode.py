"""Differential-oracle suite for the fused paged-decode attention kernel
(`kernels/paged_decode.py`) against the unfused two-segment merge
(`models/attention.attend_tiered` / `attend_flat`) it replaces.

All kernels run in interpret mode on CPU (the wrappers auto-detect), so
this file exercises the EXACT grid/BlockSpec/scalar-prefetch program CI
ships. Covered contracts:

  * fused == unfused across GQA group sizes, flat and tiered stores,
    ragged per-slot lengths, and permuted physical page tables;
  * in-kernel int8 dequant honors the `spill_codec_bound` codec contract
    (the kernel reads the same `hot_q`/`hot_scale`-style arrays PR 5's
    spill codec writes);
  * SLIM-style sparse read: tau = 0 and no-skip tau are bit-identical to
    exact; a forced-skip workload drifts less than the documented
    `n_cold * tau * (max|v| + max|out|)` gate.
"""

import math
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kv_tiers as KT
from repro.core.quant import spill_codec_bound
from repro.kernels import ops
from repro.kernels import paged_decode as PD
from repro.models import attention as A

jax.config.update("jax_platform_name", "cpu")

CFG = types.SimpleNamespace(attn_scores_dtype="float32")


def _stores(rng, B, max_len, Hkv, D, W, lengths):
    """Per-slot tiered K/V stores from fully-materialized ragged
    sequences — the exact layout `kv_tiers.tiered_from_full` writes in
    the serving prefill path. Returns (k_full, v_full, k_store, v_store)
    with the store stacked over the (possibly ragged) batch."""
    k_full = rng.standard_normal((B, max_len, Hkv, D)).astype(np.float32)
    v_full = rng.standard_normal((B, max_len, Hkv, D)).astype(np.float32)
    ks, vs = [], []
    for b in range(B):
        ks.append(KT.tiered_from_full(jnp.asarray(k_full[b:b + 1]), W,
                                      lengths[b] + 1, max_len))
        vs.append(KT.tiered_from_full(jnp.asarray(v_full[b:b + 1]), W,
                                      lengths[b] + 1, max_len))
    cat = lambda ts: jax.tree.map(  # noqa: E731
        lambda *xs: jnp.concatenate(xs, axis=0), *ts)
    return k_full, v_full, cat(ks), cat(vs)


def _oracle_tiered(q, k_store, v_store, lengths):
    """Per-slot unfused reference (attend_tiered is scalar-pos)."""
    outs = [A.attend_tiered(CFG, q[b:b + 1],
                            jax.tree.map(lambda x: x[b:b + 1], k_store),
                            jax.tree.map(lambda x: x[b:b + 1], v_store),
                            jnp.int32(lengths[b]))
            for b in range(q.shape[0])]
    return jnp.concatenate(outs, axis=0)


def _kernel_tiered(q, k_store, v_store, lengths, *, block_k, tau=0.0,
                   table=None):
    """Direct kernel call in store-native layout; identity table unless
    a permuted one is supplied."""
    B, _, H, D = q.shape
    Hkv = k_store["hot"].shape[2]
    G = H // Hkv
    W = KT.hot_window_of(k_store)
    max_len = k_store["cold_q"].shape[1]
    if table is None:
        table = jnp.stack([KT.cold_page_table(jnp.int32(lengths[b]), W,
                                              max_len, block_k)
                           for b in range(B)])
    qr = q[:, 0].reshape(B, Hkv, G, D)
    o = PD.paged_decode_tiered(
        qr, k_store["hot"], v_store["hot"],
        k_store["cold_q"], k_store["cold_scale"],
        v_store["cold_q"], v_store["cold_scale"],
        jnp.asarray(lengths, jnp.int32), table,
        scale=D ** -0.5, block_k=block_k, tau=tau)
    return o.reshape(B, H, D)[:, None]


# ---------------------------------------------------------------------------
# fused == unfused: GQA group sizes x tiered/flat x ragged lengths
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("G", [1, 2, 4])
def test_tiered_matches_oracle_gqa(G):
    rng = np.random.default_rng(0)
    B, Hkv, D, W, max_len = 2, 2, 64, 8, 48
    lengths = [47, 47]
    _, _, k_store, v_store = _stores(rng, B, max_len, Hkv, D, W, lengths)
    q = jnp.asarray(rng.standard_normal((B, 1, Hkv * G, D)), jnp.float32)
    got = _kernel_tiered(q, k_store, v_store, lengths, block_k=16)
    want = _oracle_tiered(q, k_store, v_store, lengths)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("G", [1, 4])
def test_tiered_matches_oracle_via_ops_adapter(G):
    """The `kernels.ops` adapter the model's decode dispatch calls:
    scalar pos, table derived internally."""
    rng = np.random.default_rng(1)
    B, Hkv, D, W, max_len, pos = 2, 2, 64, 8, 40, 33
    _, _, k_store, v_store = _stores(rng, B, max_len, Hkv, D, W,
                                     [pos] * B)
    q = jnp.asarray(rng.standard_normal((B, 1, Hkv * G, D)), jnp.float32)
    got = ops.paged_decode_tiered(CFG, q, k_store, v_store,
                                  jnp.int32(pos))
    want = _oracle_tiered(q, k_store, v_store, [pos] * B)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("G", [1, 2])
def test_flat_matches_oracle_gqa(G):
    rng = np.random.default_rng(2)
    B, Hkv, D, max_len, pos = 2, 2, 64, 40, 29
    k = jnp.asarray(rng.standard_normal((B, max_len, Hkv, D)),
                    jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, max_len, Hkv, D)),
                    jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, 1, Hkv * G, D)), jnp.float32)
    got = ops.paged_decode_flat(CFG, q, {"flat": k}, {"flat": v},
                                jnp.int32(pos))
    want = A.attend_flat(CFG, q, {"k": k, "v": v}, jnp.int32(pos))
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_tiered_ragged_per_slot_lengths():
    """One compiled kernel, per-slot lengths in scalar prefetch: slots
    deep in the cold tier, inside the hot window, and at position 0."""
    rng = np.random.default_rng(3)
    B, Hkv, G, D, W, max_len = 3, 2, 2, 64, 8, 48
    lengths = [47, 5, 0]
    _, _, k_store, v_store = _stores(rng, B, max_len, Hkv, D, W, lengths)
    q = jnp.asarray(rng.standard_normal((B, 1, Hkv * G, D)), jnp.float32)
    got = _kernel_tiered(q, k_store, v_store, lengths, block_k=16)
    want = _oracle_tiered(q, k_store, v_store, lengths)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_ragged_tail_page_is_masked():
    """max_len not divisible by block_k: the padded tail page must never
    contribute (its tokens sit past every valid position)."""
    rng = np.random.default_rng(4)
    B, Hkv, G, D, W, max_len = 1, 2, 2, 64, 4, 37
    lengths = [36]
    _, _, k_store, v_store = _stores(rng, B, max_len, Hkv, D, W, lengths)
    q = jnp.asarray(rng.standard_normal((B, 1, Hkv * G, D)), jnp.float32)
    got = _kernel_tiered(q, k_store, v_store, lengths, block_k=16)
    want = _oracle_tiered(q, k_store, v_store, lengths)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# block-table indirection
# ---------------------------------------------------------------------------
def test_permuted_block_table_matches_identity():
    """Logical pages scattered over permuted physical pages read back
    EXACTLY what the identity layout reads (same arithmetic order)."""
    rng = np.random.default_rng(5)
    B, Hkv, G, D, W, max_len, bk = 2, 2, 2, 64, 8, 64, 16
    lengths = [63, 40]
    _, _, k_store, v_store = _stores(rng, B, max_len, Hkv, D, W, lengths)
    q = jnp.asarray(rng.standard_normal((B, 1, Hkv * G, D)), jnp.float32)
    base = _kernel_tiered(q, k_store, v_store, lengths, block_k=bk)

    n_pages = max_len // bk
    perm = rng.permutation(n_pages)

    def scatter(x):
        y = np.array(x)
        for j in range(n_pages):
            y[:, perm[j] * bk:(perm[j] + 1) * bk] = \
                np.array(x)[:, j * bk:(j + 1) * bk]
        return jnp.asarray(y)

    k_p = {**k_store, "cold_q": scatter(k_store["cold_q"]),
           "cold_scale": scatter(k_store["cold_scale"])}
    v_p = {**v_store, "cold_q": scatter(v_store["cold_q"]),
           "cold_scale": scatter(v_store["cold_scale"])}
    ident = jnp.stack([KT.cold_page_table(jnp.int32(lengths[b]), W,
                                          max_len, bk)
                       for b in range(B)])
    table = jnp.where(ident >= 0, jnp.asarray(perm, jnp.int32)[None], -1)
    got = _kernel_tiered(q, k_p, v_p, lengths, block_k=bk, table=table)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(base))


def test_dead_table_entries_are_skipped():
    """A table that marks live-range pages dead must drop exactly those
    pages' contributions (the scheduler's page-free path)."""
    rng = np.random.default_rng(6)
    B, Hkv, G, D, W, max_len, bk = 1, 1, 1, 64, 4, 32, 8
    lengths = [31]
    _, _, k_store, v_store = _stores(rng, B, max_len, Hkv, D, W, lengths)
    q = jnp.asarray(rng.standard_normal((B, 1, Hkv * G, D)), jnp.float32)
    table = jnp.asarray([[0, -1, 2, -1]], jnp.int32)   # kill pages 1, 3
    got = _kernel_tiered(q, k_store, v_store, lengths, block_k=bk,
                         table=table)
    # reference: two-segment attention over only the LIVE cold tokens
    # (dequantized), merged with the hot ring
    live = np.zeros(max_len, bool)
    live[0 * bk:1 * bk] = True
    live[2 * bk:3 * bk] = True
    live &= np.arange(max_len) <= lengths[0] - W
    kd = np.array(k_store["cold_q"], np.float32) \
        * np.array(k_store["cold_scale"])
    vd = np.array(v_store["cold_q"], np.float32) \
        * np.array(v_store["cold_scale"])
    scale = D ** -0.5
    p_cold = A.partial_attention(q, jnp.asarray(kd), jnp.asarray(vd),
                                 jnp.asarray(live), scale)
    hot_pos = KT.hot_ring_positions(jnp.int32(lengths[0]), W)
    hot_valid = (hot_pos >= 0) & (hot_pos <= lengths[0])
    p_hot = A.partial_attention(q, k_store["hot"], v_store["hot"],
                                hot_valid, scale)
    want = A.merge_partials([p_cold, p_hot], q.dtype)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# int8 codec contract
# ---------------------------------------------------------------------------
def test_cold_pages_respect_spill_codec_bound():
    """The arrays the kernel dequants in-VMEM are the PR 5 spill-codec
    representation: elementwise |full - scale*q| <= spill_codec_bound,
    and the fused output tracks full-precision attention within the
    bound-propagated tolerance."""
    rng = np.random.default_rng(7)
    B, Hkv, G, D, W, max_len = 1, 2, 2, 64, 8, 48
    lengths = [47]
    k_full, v_full, k_store, v_store = _stores(rng, B, max_len, Hkv, D,
                                               W, lengths)
    for full, store in ((k_full, k_store), (v_full, v_store)):
        deq = np.array(store["cold_q"], np.float32) \
            * np.array(store["cold_scale"])
        bound = np.array(spill_codec_bound(jnp.asarray(full)))
        assert (np.abs(full - deq) <= bound + 1e-7).all()
    q = jnp.asarray(rng.standard_normal((B, 1, Hkv * G, D)), jnp.float32)
    got = _kernel_tiered(q, k_store, v_store, lengths, block_k=16)
    # full-precision reference (no codec anywhere)
    scale = D ** -0.5
    cold_valid = jnp.arange(max_len) <= lengths[0] - W
    p_cold = A.partial_attention(q, jnp.asarray(k_full),
                                 jnp.asarray(v_full), cold_valid, scale)
    hot_pos = KT.hot_ring_positions(jnp.int32(lengths[0]), W)
    p_hot = A.partial_attention(q, k_store["hot"], v_store["hot"],
                                (hot_pos >= 0) & (hot_pos <= lengths[0]),
                                scale)
    want = A.merge_partials([p_cold, p_hot], q.dtype)
    # int8 codec error, not kernel error: ~scale/2 per element
    assert float(jnp.max(jnp.abs(got - want))) < 0.05


# ---------------------------------------------------------------------------
# SLIM sparse read
# ---------------------------------------------------------------------------
def test_sparse_tau_no_skip_is_bit_exact():
    """On unstructured data the l1 bound never crosses the threshold at
    small tau — the sparse kernel must then be BIT-identical to exact
    (the threshold test adds no arithmetic to surviving pages)."""
    rng = np.random.default_rng(8)
    B, Hkv, G, D, W, max_len = 2, 2, 2, 64, 8, 48
    lengths = [47, 30]
    _, _, k_store, v_store = _stores(rng, B, max_len, Hkv, D, W, lengths)
    q = jnp.asarray(rng.standard_normal((B, 1, Hkv * G, D)), jnp.float32)
    exact = _kernel_tiered(q, k_store, v_store, lengths, block_k=16)
    sparse = _kernel_tiered(q, k_store, v_store, lengths, block_k=16,
                            tau=1e-6)
    np.testing.assert_array_equal(np.asarray(sparse), np.asarray(exact))


def test_sparse_drift_within_documented_gate():
    """Structured workload that actually trips the skip: an anchored hot
    max (aligned large-norm key) + near-zero cold pages whose upper
    bound falls below m + log(tau). Drift obeys the documented contract
    (skipped mass/token < tau) and is nonzero — proof pages were really
    skipped, not vacuously equal."""
    rng = np.random.default_rng(9)
    B, Hkv, G, D, W, max_len, bk = 1, 1, 1, 64, 8, 40, 8
    pos = 39
    tau = 1e-2
    q = rng.standard_normal((B, 1, Hkv * G, D)).astype(np.float32)
    k_full = 1e-3 * rng.standard_normal((B, max_len, Hkv, D)) \
        .astype(np.float32)
    v_full = rng.standard_normal((B, max_len, Hkv, D)).astype(np.float32)
    # hot-window token aligned with q anchors m ~= scale * a * |q|^2
    a = 10.0 / (D ** -0.5 * float((q[0, 0, 0] ** 2).sum()))
    k_full[0, pos, 0] = a * q[0, 0, 0]
    k_store = KT.tiered_from_full(jnp.asarray(k_full), W, pos + 1,
                                  max_len)
    v_store = KT.tiered_from_full(jnp.asarray(v_full), W, pos + 1,
                                  max_len)
    qj = jnp.asarray(q)
    exact = _kernel_tiered(qj, k_store, v_store, [pos], block_k=bk)
    sparse = _kernel_tiered(qj, k_store, v_store, [pos], block_k=bk,
                            tau=tau)
    diff = float(jnp.max(jnp.abs(sparse - exact)))
    assert diff > 0.0, "no page was skipped — workload fails to trip SLIM"
    n_cold = pos + 1 - W
    gate = n_cold * tau * (float(np.abs(v_full).max())
                           + float(jnp.max(jnp.abs(exact))))
    assert diff <= gate, (diff, gate)
    # and the oracle agrees with the exact kernel on this workload too
    want = _oracle_tiered(qj, k_store, v_store, [pos])
    np.testing.assert_allclose(exact, want, atol=5e-5, rtol=5e-5)


# ---------------------------------------------------------------------------
# VMEM accounting
# ---------------------------------------------------------------------------
def test_paged_decode_vmem_budget():
    """Serving shapes fit v5e VMEM with headroom; the accounting counts
    the int8 tiles AND their f32 casts plus both scale streams."""
    V5E_VMEM = 128 * 2 ** 20
    n = PD.paged_decode_vmem_bytes(block_k=128, G=8, D=128, hot_w=64)
    assert n < V5E_VMEM // 4
    cold = 2 * 128 * 128 * (1 + 4)
    scales = 2 * 128 * (4 + 4)
    assert n >= cold + scales
