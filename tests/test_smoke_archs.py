"""Per-architecture smoke tests: REDUCED configs of the same family, one
forward + one train-grad step on CPU, asserting shapes and finiteness.
The FULL configs are exercised only via the dry-run (abstract lowering).

Models come from the shared `tests/conftest.py` `build_model` cache, so
every (arch, kv_policy, hot_window) is initialized once per session and
shared with the serving suites instead of rebuilt per test."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import build_model

from repro.configs.base import ASSIGNED_ARCHS, PAPER_MODELS
from repro.models import Model

jax.config.update("jax_platform_name", "cpu")

B, S = 2, 32


def make_batch(cfg, rng):
    r1, r2, r3 = jax.random.split(rng, 3)
    if cfg.family == "audio":
        batch = {
            "frames": jax.random.normal(
                r1, (B, S, cfg.frontend.frontend_dim), jnp.float32),
            "labels": jax.random.randint(r2, (B, S), 0, cfg.vocab_size),
        }
    elif cfg.frontend is not None:
        tv = cfg.frontend.num_tokens
        st = S - tv
        batch = {
            "tokens": jax.random.randint(r1, (B, st), 0, cfg.vocab_size),
            "patches": jax.random.normal(
                r2, (B, tv, cfg.frontend.frontend_dim), jnp.float32),
            "labels": jax.random.randint(r3, (B, S), 0, cfg.vocab_size),
        }
    else:
        batch = {
            "tokens": jax.random.randint(r1, (B, S), 0, cfg.vocab_size),
            "labels": jax.random.randint(r2, (B, S), 0, cfg.vocab_size),
        }
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS + PAPER_MODELS)
def test_forward_shapes_finite(arch):
    cfg, model, params = build_model(arch)
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    logits = jax.jit(model.forward)(params, batch)
    assert logits.shape == (B, S, model.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_grad_step(arch):
    # the cached params are remat-agnostic; only the loss graph needs
    # the remat="full" twin
    cfg, _, params = build_model(arch)
    model = Model(cfg.replace(remat="full"))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert np.isfinite(float(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", [a for a in ASSIGNED_ARCHS
                                  if a != "hubert-xlarge"])
@pytest.mark.parametrize("kv_policy", ["flat", "tiered"])
def test_prefill_then_decode(arch, kv_policy):
    cfg, model, params = build_model(arch, kv_policy=kv_policy,
                                     hot_window=16)
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    batch.pop("labels", None)
    max_len = S + 8
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, max_len))(params, batch)
    assert logits.shape == (B, 1, model.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    tok = jnp.minimum(jnp.argmax(logits[:, -1], -1),
                      cfg.vocab_size - 1)[:, None].astype(jnp.int32)
    step = jax.jit(model.decode_step)
    for i in range(3):
        pos = jnp.asarray(S + i, jnp.int32)
        logits, cache = step(params, tok, cache, pos)
        assert logits.shape == (B, 1, model.padded_vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)


def test_decode_matches_full_forward_dense():
    """Decoding token-by-token must agree with the full parallel forward —
    the strongest correctness property of the cache path."""
    cfg, model, params = build_model("granite-3-2b", kv_policy="flat")
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                cfg.vocab_size)
    full_logits = model.forward(params, {"tokens": tokens})
    # prefill 4 tokens, decode the next 4, compare logits
    pre = {"tokens": tokens[:, :4]}
    logits, cache = model.prefill(params, pre, max_len=16)
    np.testing.assert_allclose(
        np.asarray(logits[:, -1]), np.asarray(full_logits[:, 3]),
        rtol=2e-4, atol=2e-4)
    for i in range(4, 8):
        logits, cache = model.decode_step(
            params, tokens[:, i:i + 1], cache, jnp.asarray(i, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(logits[:, -1]), np.asarray(full_logits[:, i]),
            rtol=2e-4, atol=2e-4)


def test_decode_matches_full_forward_ssm():
    """Same agreement property for the recurrent-state path (rwkv6)."""
    cfg, model, params = build_model("rwkv6-7b", kv_policy="flat")
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                cfg.vocab_size)
    full_logits = model.forward(params, {"tokens": tokens})
    logits, cache = model.prefill(params, {"tokens": tokens[:, :4]},
                                  max_len=16)
    np.testing.assert_allclose(
        np.asarray(logits[:, -1]), np.asarray(full_logits[:, 3]),
        rtol=1e-3, atol=1e-3)
    for i in range(4, 8):
        logits, cache = model.decode_step(
            params, tokens[:, i:i + 1], cache, jnp.asarray(i, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(logits[:, -1]), np.asarray(full_logits[:, i]),
            rtol=1e-3, atol=1e-3)
