"""Hypothesis property tests on core numerical invariants:

* chunked WKV6 / SSD scans == exact per-step recurrences for any
  (shape, chunk) — the invariant that makes long_500k trustworthy;
* tier-store append/read preserves every position exactly once;
* MoE dispatch conserves token mass within capacity.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import kv_tiers as KT
from repro.models.ssm import ssd_chunked, wkv6_chunked

jax.config.update("jax_platform_name", "cpu")


def wkv6_naive(r, k, v, logw, u, s0):
    B, S, H, K = r.shape
    s = s0.astype(jnp.float32)
    ys = []
    for t in range(S):
        rt = r[:, t].astype(jnp.float32)
        kt = k[:, t].astype(jnp.float32)
        vt = v[:, t].astype(jnp.float32)
        wt = logw[:, t].astype(jnp.float32)
        y = jnp.einsum("bhk,bhkv->bhv", rt, s) \
            + jnp.einsum("bhk,bhk->bh", rt, u * kt)[..., None] * vt
        s = jnp.exp(wt)[..., None] * s + kt[..., None] * vt[..., None, :]
        ys.append(y)
    return jnp.stack(ys, axis=1), s


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 2), st.sampled_from([4, 8, 16]),
       st.integers(1, 3), st.sampled_from([4, 8]),
       st.sampled_from([2, 4, 8, 16]))
def test_wkv6_chunked_equals_naive(B, S, H, K, chunk):
    if S % chunk != 0:
        chunk = S
    ks = jax.random.split(jax.random.PRNGKey(S * 31 + chunk), 6)
    r = jax.random.normal(ks[0], (B, S, H, K))
    k = jax.random.normal(ks[1], (B, S, H, K))
    v = jax.random.normal(ks[2], (B, S, H, K))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, K)) * 0.5)
    u = jax.random.normal(ks[4], (H, K)) * 0.1
    s0 = jax.random.normal(ks[5], (B, H, K, K)) * 0.1
    y1, s1 = wkv6_chunked(r, k, v, logw, u, s0, chunk)
    y2, s2 = wkv6_naive(r, k, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-4, atol=1e-4)


def ssd_naive(xh, Bm, Cm, dt, a_log, s0):
    B, S, H, P = xh.shape
    s = s0.astype(jnp.float32)
    ys = []
    for t in range(S):
        lt = -jnp.exp(a_log.astype(jnp.float32)) * dt[:, t]
        s = jnp.exp(lt)[..., None, None] * s \
            + (xh[:, t].astype(jnp.float32)
               * dt[:, t][..., None])[..., None] \
            * Bm[:, t].astype(jnp.float32)[:, None, None, :]
        ys.append(jnp.einsum("bhps,bs->bhp", s,
                             Cm[:, t].astype(jnp.float32)))
    return jnp.stack(ys, axis=1), s


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 2), st.sampled_from([4, 8, 16]),
       st.integers(1, 3), st.sampled_from([4, 8]),
       st.sampled_from([4, 8]), st.sampled_from([2, 4, 8]))
def test_ssd_chunked_equals_naive(B, S, H, P, n, chunk):
    if S % chunk != 0:
        chunk = S
    ks = jax.random.split(jax.random.PRNGKey(S * 7 + chunk), 5)
    xh = jax.random.normal(ks[0], (B, S, H, P))
    Bm = jax.random.normal(ks[1], (B, S, n))
    Cm = jax.random.normal(ks[2], (B, S, n))
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
    a_log = jax.random.normal(ks[4], (H,)) * 0.3
    s0 = jnp.zeros((B, H, P, n))
    y1, s1 = ssd_chunked(xh, Bm, Cm, dt, a_log, s0, chunk)
    y2, s2 = ssd_naive(xh, Bm, Cm, dt, a_log, s0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(5, 40), st.sampled_from([4, 8]))
def test_tier_store_positions_exactly_once(n_tokens, W):
    """However many tokens flow through, every position is attendable
    exactly once and cold slots are written exactly once."""
    cache = KT.init_tiered(1, 64, (1, 4), hot_window=W)
    for pos in range(n_tokens):
        v = jnp.full((1, 1, 1, 4), float(pos + 1))
        cache = KT.tiered_append(cache, v, jnp.asarray(pos))
    _, valid = KT.tiered_read(cache, jnp.asarray(n_tokens - 1))
    positions = KT.combined_positions(cache, jnp.asarray(n_tokens - 1))
    vis = [int(p) for p, m in zip(np.asarray(positions), np.asarray(valid))
           if m]
    assert sorted(vis) == list(range(n_tokens))
    assert int(jnp.sum(cache["writes"])) == max(n_tokens - W, 0)


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 6), st.integers(1, 3))
def test_moe_dispatch_conserves_mass(T_log, top_k):
    """Combine weights of kept tokens sum to ~1 per token (after top-k
    renorm); dropped tokens contribute 0 (capacity discipline)."""
    from repro.configs.base import get_config
    from repro.models.layers import ParamBuilder, apply_moe, init_moe
    import dataclasses
    cfg = get_config("llama4-maverick-400b", reduced=True).replace(
        param_dtype="float32", compute_dtype="float32")
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, top_k=top_k))
    b = ParamBuilder(jax.random.PRNGKey(0), jnp.float32)
    mb = b.scope("moe")
    init_moe(mb, cfg)
    T = 2 ** T_log
    x = jax.random.normal(jax.random.PRNGKey(T), (1, T, cfg.d_model))
    out = apply_moe(b.params["moe"], cfg, x, None)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
