"""The compressed RRAM spill tier (PR 5): idle cold-KV offload +
int8 spill lanes.

Load-bearing properties:

* IDLE OFFLOAD IS BIT-EXACT — a request parked in an RRAM lane because
  an equal-priority waiter was blocked (not because anyone outranked
  it) and restored later produces EXACTLY the tokens of an
  uninterrupted run and of the single-request `generate` oracle, on
  GQA, MLA(+MoE), RWKV6 and hybrid-Mamba2, on the local vmapped and the
  pjit-sharded backend, with whole-prompt and chunked prefill. The
  offload reuses PR 4's verbatim evict/restore, so the parity guarantee
  carries over unchanged.
* COMPRESSED LANES ARE BOUNDED-ERROR — with `spill_compress` the hot
  ring is int8-requantized into the lane: after an evict/restore round
  trip every non-hot leaf is bit-exact and every hot leaf is within the
  documented codec bound (rowmax/254, `core.quant.spill_codec_bound`);
  the next decode step's LOGITS stay within SPILL_COMPRESS_LOGIT_TOL
  on the reduced test models (deterministic check, generous margin).
  A flat-policy cache has no hot ring, so its spill stays bit-exact
  even with compression on.
* ENDURANCE — lane counters advance exactly per
  `expected_spill_block_writes` across offload/restore cycles, slot
  counters stay exactly per `expected_block_writes`, and the report
  aggregates are stable across lazy lane materialization.

Run with XLA_FLAGS=--xla_force_host_platform_device_count=8 to exercise
the sharded cases on a real multi-device mesh (the CI multi-device job
does), and REPRO_SERVE_SPILL_COMPRESS=1 to force compressed lanes onto
every backend built without an explicit flag (the CI coverage job
does; the parity tests here pin spill_compress=False for exactness).
"""

import jax
import numpy as np
import pytest
from conftest import build_model, make_mesh, make_requests, oracle_tokens

from repro.core import kv_tiers as KT
from repro.core.quant import spill_codec_bound
from repro.serving import (CapacityBudget, Engine, FCFSScheduler,
                           LocalBackend, Request, ShardedBackend,
                           aggregate_metrics, simulated_efficiency,
                           spill_lane_bytes)

jax.config.update("jax_platform_name", "cpu")

# documented end-to-end tolerance of a compressed-lane restore on the
# reduced float32 test models: max |logit drift| after one decode step
# from a restored cache vs the untouched one, RELATIVE to the logit
# scale max|logits| (the codec's rowmax/254 cache-level bound is held
# exactly; this is its downstream effect — observed ~1.3e-3, asserted
# with ~7x margin)
SPILL_COMPRESS_LOGIT_RTOL = 1e-2

# rotation scenarios: 2 slots, 3 equal-priority requests -> the third
# is admitted by parking an idle runner, which later restores and must
# resume bit-exactly. Recurrent archs use grid-aligned chunk caps.
CASES = {
    "granite-3-2b": dict(specs=[(12, 10), (12, 10), (8, 6)],
                         max_len=32, chunk=5),
    "deepseek-v2-lite": dict(specs=[(12, 8), (12, 8), (8, 5)],
                             max_len=24, chunk=5),
    "rwkv6-7b": dict(specs=[(40, 8), (40, 8), (32, 5)],
                     max_len=48, chunk=32),
    "zamba2-1.2b": dict(specs=[(24, 8), (24, 8), (16, 5)],
                        max_len=48, chunk=16),
}

_oracle_memo: dict = {}


def _oracle(arch, model, params, req):
    key = (arch, req.rid)
    if key not in _oracle_memo:
        _oracle_memo[key] = oracle_tokens(model, params, req)
    return _oracle_memo[key]


def _run_offloaded(backend, reqs, chunk_tokens, idle_steps=2):
    """Drain ``reqs`` through a 2-slot engine whose third request can
    only be admitted via idle offload (equal priorities; base gates)."""
    hot_b, cold_b = backend.slot_kv_bytes()
    sched = FCFSScheduler(CapacityBudget(2 * hot_b, 1e15), hot_b, cold_b,
                          oversubscribe=1.0,
                          idle_offload_steps=idle_steps,
                          lane_bytes=backend.spill_lane_bytes())
    eng = Engine(backend, scheduler=sched, chunk_tokens=chunk_tokens)
    eng.run(reqs, max_steps=500)
    assert eng.stats["idle_offloads"] >= 1, eng.stats
    assert eng.stats["evictions"] == 0          # nobody outranked anyone
    assert eng.stats["restores"] == eng.stats["idle_offloads"]
    assert len(eng.finished) == len(reqs)
    return eng


@pytest.mark.parametrize("backend_kind", ["local", "sharded"])
@pytest.mark.parametrize("arch", list(CASES))
def test_idle_offload_token_parity(arch, backend_kind):
    """Acceptance: offloaded-then-restored == uninterrupted == oracle,
    whole-prompt AND chunked prefill, on both backends."""
    case = CASES[arch]
    cfg, model, params = build_model(arch)
    if backend_kind == "sharded":
        backend = ShardedBackend(model, params, 2, case["max_len"],
                                 mesh=make_mesh(), spill_compress=False)
    else:
        backend = LocalBackend(model, params, 2, case["max_len"],
                               spill_compress=False)
    for chunk in (0, case["chunk"]):          # whole-prompt and chunked
        reqs = make_requests(cfg, case["specs"], seed=3)
        eng = _run_offloaded(backend, reqs, chunk)
        for r in reqs:
            assert r.generated == _oracle(arch, model, params, r), (
                f"{arch}/{backend_kind}/chunk={chunk}: rid {r.rid} "
                f"diverged after idle offload")
        if cfg.kv_policy == "tiered":
            assert eng.endurance_report()["write_once_ok"]


def test_idle_offload_matches_uninterrupted_run():
    """Differential: the offloaded stream equals the SAME stream served
    with enough DRAM that nothing is ever parked."""
    case = CASES["granite-3-2b"]
    cfg, model, params = build_model()
    backend = LocalBackend(model, params, 2, case["max_len"],
                           spill_compress=False)
    reqs = make_requests(cfg, case["specs"], seed=3)
    _run_offloaded(backend, reqs, chunk_tokens=0)
    hot_b, cold_b = backend.slot_kv_bytes()
    calm = Engine(backend, scheduler=FCFSScheduler(
        CapacityBudget(1e15, 1e15), hot_b, cold_b, oversubscribe=1.0))
    ref = make_requests(cfg, case["specs"], seed=3)
    calm.run(ref, max_steps=500)
    assert calm.stats["idle_offloads"] == 0
    assert [r.generated for r in reqs] == [r.generated for r in ref]


# ---------------------------------------------------------------------------
# compressed spill lanes
# ---------------------------------------------------------------------------
def _steady_engine(backend, cfg, model, params, gen_steps=3):
    """One request decoded a few steps into slot 0 of ``backend``."""
    eng = Engine(backend)
    (req,) = make_requests(cfg, [(8, 20)], seed=5)
    eng.submit(req)
    for _ in range(gen_steps):
        eng.step()
    assert eng._active[0]
    return eng


def _leafwise_compare(cache0, cache1, codec_bound=True):
    """(n_hot_bounded, n_exact): hot leaves within the codec bound,
    everything else bit-exact."""
    l0 = jax.tree_util.tree_flatten_with_path(cache0)[0]
    l1 = jax.tree_util.tree_flatten_with_path(cache1)[0]
    hot = exact = 0
    for (p0, a), (p1, b) in zip(l0, l1):
        key = p0[-1].key if hasattr(p0[-1], "key") else str(p0[-1])
        a = np.asarray(a, np.float32)
        b = np.asarray(b, np.float32)
        if key == "hot" and codec_bound:
            bound = np.asarray(spill_codec_bound(a), np.float32)
            assert np.all(np.abs(a - b) <= bound * (1 + 1e-4) + 1e-7), key
            hot += 1
        else:
            np.testing.assert_array_equal(a, b, err_msg=str(key))
            exact += 1
    return hot, exact


@pytest.mark.parametrize("backend_kind", ["local", "sharded"])
def test_compressed_lane_restore_within_codec_bound(backend_kind):
    """Evict/restore through a compressed lane: hot leaves within
    rowmax/254, every other leaf (cold tier, scales, states, counters)
    bit-exact — on both backends."""
    cfg, model, params = build_model()
    if backend_kind == "sharded":
        backend = ShardedBackend(model, params, 2, 32, mesh=make_mesh(),
                                 spill_compress=True)
    else:
        backend = LocalBackend(model, params, 2, 32, spill_compress=True)
    assert backend.spill_compress
    eng = _steady_engine(backend, cfg, model, params)
    st0 = eng.pool.state
    ctx = int(eng._pos[0])
    st1 = backend.evict_slot(st0, 0, 0, ctx)
    assert st1.num_spill_lanes == backend.n_spill
    # the lane tree really is restructured: hot_q/hot_scale, no hot
    lane_keys = {p[-1].key for p, _ in
                 jax.tree_util.tree_flatten_with_path(st1.spill)[0]
                 if hasattr(p[-1], "key")}
    assert "hot_q" in lane_keys and "hot_scale" in lane_keys
    assert "hot" not in lane_keys
    st2 = backend.restore_slot(st1, 0, 0)
    n_hot, n_exact = _leafwise_compare(st0.cache, st2.cache)
    assert n_hot >= 1 and n_exact >= 1


def test_compressed_restore_logit_drift_within_documented_tol():
    """One decode step from the restored cache vs the untouched one:
    max |logit diff| <= SPILL_COMPRESS_LOGIT_RTOL * max |logit|
    (deterministic fixed-seed check)."""
    cfg, model, params = build_model()
    backend = LocalBackend(model, params, 1, 32, spill_compress=True)
    eng = _steady_engine(backend, cfg, model, params)
    st0 = eng.pool.state
    ctx = int(eng._pos[0])
    st2 = backend.restore_slot(backend.evict_slot(st0, 0, 0, ctx), 0, 0)
    tok = np.asarray(eng._tok)          # (1, 1) int32 last emitted token
    pos = np.asarray(ctx, np.int32)
    logits0, _ = model.decode_step(params, tok, st0.cache, pos)
    logits2, _ = model.decode_step(params, tok, st2.cache, pos)
    a = np.asarray(logits0, np.float32)
    b = np.asarray(logits2, np.float32)
    drift = float(np.max(np.abs(a - b)))
    scale = float(np.max(np.abs(a)))
    assert drift <= SPILL_COMPRESS_LOGIT_RTOL * scale, (drift, scale)
    assert drift > 0.0                  # the codec is genuinely lossy


def test_compressed_flat_policy_spill_is_bit_exact():
    """No hot ring -> nothing to compress: on a flat-policy cache the
    flag resolves to OFF (so lane bytes, sim pricing and the CLI report
    stay truthful) and the spill round trip is bit-exact."""
    cfg, model, params = build_model(kv_policy="flat")
    backend = LocalBackend(model, params, 2, 32, spill_compress=True)
    assert not backend.spill_compress
    assert backend.spill_lane_bytes() == sum(backend.slot_kv_bytes())
    eng = _steady_engine(backend, cfg, model, params)
    st0 = eng.pool.state
    st2 = backend.restore_slot(
        backend.evict_slot(st0, 0, 0, int(eng._pos[0])), 0, 0)
    _leafwise_compare(st0.cache, st2.cache, codec_bound=False)


def test_compressed_engine_drains_under_preemption_and_offload():
    """End-to-end: an engine on int8 lanes completes a mixed-priority
    stream with real preemptions AND idle offloads, restores everything
    it parked, and the endurance/metrics plumbing reports the spills."""
    cfg, model, params = build_model()
    backend = LocalBackend(model, params, 2, 32, spill_compress=True)
    hot_b, cold_b = backend.slot_kv_bytes()
    sched = FCFSScheduler(CapacityBudget(2 * hot_b, 1e15), hot_b, cold_b,
                          oversubscribe=1.0, idle_offload_steps=2,
                          lane_bytes=backend.spill_lane_bytes())
    eng = Engine(backend, scheduler=sched)
    low = make_requests(cfg, [(12, 10), (12, 10), (8, 6)], seed=3)
    for r in low:
        eng.submit(r)
    eng.step()
    eng.step()
    eng.step()
    (high,) = make_requests(cfg, [(8, 4)], seed=7, priorities=[1])
    high.rid = 9
    eng.submit(high)
    done = eng.run(max_steps=500)
    assert len(done) == 4
    assert eng.stats["evictions"] >= 1          # priority preemption
    assert eng.stats["idle_offloads"] >= 1      # capacity offload
    assert eng.stats["restores"] == eng.stats["evictions"] \
        + eng.stats["idle_offloads"]
    for r in done:
        assert r.n_generated == r.max_new_tokens or r.eos_id is not None
    m = aggregate_metrics(done, wall_s=1.0)
    assert m["restores"] == eng.stats["restores"]
    # metrics keep priority preemptions and capacity offloads apart
    assert m["preemptions"] == eng.stats["evictions"]
    assert m["idle_offloads"] == eng.stats["idle_offloads"]
    assert m["spills"] == m["preemptions"] + m["idle_offloads"]
    sim = simulated_efficiency(cfg, done, spill_compressed=True)
    sim_fp = simulated_efficiency(cfg, done, spill_compressed=False)
    assert sim["sim_spill_compressed"] is True
    assert sim["sim_spills"] == eng.stats["restores"]
    # pricing the int8 image moves strictly fewer bytes than the
    # full-precision one across UCIe
    assert sim["sim_spill_energy_j"] < sim_fp["sim_spill_energy_j"]


def test_spill_lane_bytes_accounting():
    """Compressed lanes charge less RRAM than verbatim ones (tiered),
    identically for flat caches, and both backends report the same
    numbers the model-level helper computes."""
    cfg, model, params = build_model()
    full = spill_lane_bytes(model, 32, compressed=False)
    comp = spill_lane_bytes(model, 32, compressed=True)
    assert comp < full
    assert full == sum(LocalBackend(model, params, 2, 32,
                                    spill_compress=False).slot_kv_bytes())
    assert LocalBackend(model, params, 2, 32,
                        spill_compress=True).spill_lane_bytes() == comp
    _, fmodel, fparams = build_model(kv_policy="flat")
    assert spill_lane_bytes(fmodel, 32, True) \
        == spill_lane_bytes(fmodel, 32, False)


# ---------------------------------------------------------------------------
# endurance accounting across offload/restore cycles
# ---------------------------------------------------------------------------
def test_offload_endurance_counters_exact():
    """Across a whole rotation workload: cumulative lane counters ==
    the sum of expected_spill_block_writes over every request's
    recorded offload contexts; slot counters stay exactly at
    expected_block_writes (restores add no cold writes); the report
    keys exist (as zeros) even BEFORE lazy lane materialization."""
    cfg, model, params = build_model(hot_window=4)
    backend = LocalBackend(model, params, 2, 64, spill_compress=False)
    hot_b, cold_b = backend.slot_kv_bytes()
    sched = FCFSScheduler(CapacityBudget(2 * hot_b, 1e15), hot_b, cold_b,
                          oversubscribe=1.0, idle_offload_steps=2)
    eng = Engine(backend, scheduler=sched)

    # lazy-lane report stability: spill keys present before any spill
    rep0 = eng.endurance_report()
    assert rep0["total_spill_writes"] == 0
    assert rep0["max_spill_writes_per_block"] == 0
    assert rep0["total_rram_writes"] == rep0.get("total_cold_writes", 0)

    reqs = make_requests(cfg, [(8, 14), (8, 14), (8, 8)], seed=5)
    done = eng.run(reqs, max_steps=500)
    assert len(done) == 3 and eng.stats["idle_offloads"] >= 2

    sw = np.asarray(eng.pool.state.spill_writes)
    nb = sw.shape[1]
    all_ctx = [c for r in reqs for c in r.evict_ctx]
    assert len(all_ctx) == eng.stats["idle_offloads"]
    np.testing.assert_array_equal(
        sw.sum(axis=0),
        np.asarray(KT.expected_spill_block_writes(nb, all_ctx)))

    worst = np.asarray(eng.pool.worst_case_writes())
    for slot in range(2):
        p = eng._slot_prefill_len[slot]
        t = eng._slot_total_len[slot]
        np.testing.assert_array_equal(
            worst[slot], np.asarray(KT.expected_block_writes(
                worst.shape[1], backend.hot_window, p, t)))

    rep = eng.endurance_report()
    assert rep["write_once_ok"]
    assert rep["idle_offloads"] == eng.stats["idle_offloads"]
    assert rep["preemptions"] == 0
    assert rep["spills"] == eng.stats["idle_offloads"]
    assert rep["total_spill_writes"] == int(sw.sum())
    assert rep["total_rram_writes"] == rep["total_cold_writes"] \
        + int(sw.sum())


# ---------------------------------------------------------------------------
# scheduler offload policy (host-only, no model)
# ---------------------------------------------------------------------------
def _req(rid, plen=8, gen=4, prio=0, resident=0, seq=-1):
    r = Request(rid=rid, tokens=np.zeros(plen, np.int32),
                max_new_tokens=gen, priority=prio)
    r.resident_steps = resident
    r.admit_seq = seq
    return r


def _sched(dram_slots=2, idle=2, **kw):
    kw.setdefault("oversubscribe", 1.0)
    kw.setdefault("spill_lanes", 2)
    return FCFSScheduler(CapacityBudget(100 * dram_slots, 1e9),
                         hot_bytes_per_slot=100, cold_bytes_per_slot=10,
                         idle_offload_steps=idle, **kw)


def test_offload_fires_for_equal_priority_waiter_after_threshold():
    sched = _sched()
    running = (_req(0, resident=5, seq=0), _req(1, resident=5, seq=1))
    sched.submit(_req(9))
    plan = sched.plan(active_slots=2, decode_slots=2, free_slots=0,
                      inflight=None, running=running, free_lanes=2)
    assert plan.evictions == ()
    assert [r.rid for r in plan.offloads] == [1]    # latest-admitted
    assert [(c.req.rid, c.admit) for c in plan.chunks] == [(9, True)]
    assert sched.spilled == 1


def test_no_offload_below_threshold_or_knob_off_or_no_lane():
    running = (_req(0, resident=1, seq=0), _req(1, resident=1, seq=1))
    sched = _sched()                                # threshold 2
    sched.submit(_req(9))
    plan = sched.plan(active_slots=2, decode_slots=2, free_slots=0,
                      inflight=None, running=running, free_lanes=2)
    assert plan.offloads == () and plan.chunks == ()
    off = _sched(idle=None)                         # knob off
    off.submit(_req(9))
    ready = (_req(0, resident=9, seq=0), _req(1, resident=9, seq=1))
    assert off.plan(active_slots=2, decode_slots=2, free_slots=0,
                    inflight=None, running=ready,
                    free_lanes=2).offloads == ()
    lanes = _sched()                                # no free lane
    lanes.submit(_req(9))
    assert lanes.plan(active_slots=2, decode_slots=2, free_slots=0,
                      inflight=None, running=ready,
                      free_lanes=0).offloads == ()


def test_offload_never_parks_higher_priority_runner():
    """A lower-priority waiter cannot displace higher-priority work, no
    matter how long it has been resident."""
    sched = _sched()
    running = (_req(0, prio=1, resident=9, seq=0),
               _req(1, prio=1, resident=9, seq=1))
    sched.submit(_req(9, prio=0))
    plan = sched.plan(active_slots=2, decode_slots=2, free_slots=0,
                      inflight=None, running=running, free_lanes=2)
    assert plan.offloads == () and plan.evictions == ()


def test_strict_preemption_preempts_offload():
    """When the waiter strictly outranks a runner, PR 4 preemption fires
    and the plan carries an eviction, never an offload too (one victim
    per step)."""
    sched = _sched()
    running = (_req(0, resident=9, seq=0), _req(1, resident=9, seq=1))
    sched.submit(_req(9, prio=2))
    plan = sched.plan(active_slots=2, decode_slots=2, free_slots=0,
                      inflight=None, running=running, free_lanes=2)
    assert [r.rid for r in plan.evictions] == [1]
    assert plan.offloads == ()


def test_useless_offload_guard_without_queue_waiter():
    """The only waiter is a spilled request that would restore AFTER the
    victim (it is junior at equal priority): parking the victim would
    just bounce it back — the plan must do nothing."""
    sched = _sched(dram_slots=3)
    junior = _req(7, seq=5)
    sched._spill_insert(junior)
    senior_runner = (_req(0, resident=9, seq=0),)
    plan = sched.plan(active_slots=1, decode_slots=1, free_slots=0,
                      inflight=None, running=senior_runner, free_lanes=1)
    assert plan.offloads == () and plan.restores == ()
    # ...but a SENIOR spilled waiter does displace a junior runner, and
    # the swap completes within the step
    sched2 = _sched(dram_slots=3)
    senior = _req(7, seq=0)
    sched2._spill_insert(senior)
    junior_runner = (_req(0, resident=9, seq=5),)
    plan2 = sched2.plan(active_slots=1, decode_slots=1, free_slots=0,
                        inflight=None, running=junior_runner,
                        free_lanes=1)
    assert [r.rid for r in plan2.offloads] == [0]
    assert [r.rid for r in plan2.restores] == [7]


def test_offloaded_request_restores_fcfs_when_capacity_frees():
    sched = _sched()
    running = (_req(0, resident=5, seq=0), _req(1, resident=5, seq=1))
    sched.submit(_req(9))
    plan = sched.plan(active_slots=2, decode_slots=2, free_slots=0,
                      inflight=None, running=running, free_lanes=2)
    assert [r.rid for r in plan.offloads] == [1]
    # a slot frees later: rid 1 resumes before any new admission
    sched.submit(_req(10))
    plan2 = sched.plan(active_slots=1, decode_slots=1, free_slots=1,
                       inflight=None, running=(running[0],), free_lanes=1)
    assert [r.rid for r in plan2.restores] == [1]
    assert plan2.chunks == ()


def test_idle_offload_validation():
    with pytest.raises(ValueError, match="idle_offload_steps"):
        _sched(idle=0)
    with pytest.raises(ValueError, match="idle_offload_steps"):
        _sched(idle=-3)


# ---------------------------------------------------------------------------
# knob resolution (env + engine + backend)
# ---------------------------------------------------------------------------
def test_spill_compress_env_knob(monkeypatch):
    _, model, params = build_model()
    monkeypatch.delenv("REPRO_SERVE_SPILL_COMPRESS", raising=False)
    assert not LocalBackend(model, params, 2, 24).spill_compress
    monkeypatch.setenv("REPRO_SERVE_SPILL_COMPRESS", "1")
    assert LocalBackend(model, params, 2, 24).spill_compress
    monkeypatch.setenv("REPRO_SERVE_SPILL_COMPRESS", "0")
    assert not LocalBackend(model, params, 2, 24).spill_compress
    monkeypatch.setenv("REPRO_SERVE_SPILL_COMPRESS", "1")
    # an explicit flag always wins over the env
    assert not LocalBackend(model, params, 2, 24,
                            spill_compress=False).spill_compress


def test_engine_idle_offload_env_knob(monkeypatch):
    _, model, params = build_model()
    monkeypatch.setenv("REPRO_SERVE_IDLE_OFFLOAD_STEPS", "3")
    eng = Engine(LocalBackend(model, params, 2, 24))
    assert eng.scheduler.idle_offload_steps == 3
    # explicit 0 disables even under the env knob
    eng0 = Engine(LocalBackend(model, params, 2, 24),
                  idle_offload_steps=0)
    assert eng0.scheduler.idle_offload_steps is None
    monkeypatch.setenv("REPRO_SERVE_IDLE_OFFLOAD_STEPS", "0")
    assert Engine(LocalBackend(model, params, 2, 24)) \
        .scheduler.idle_offload_steps is None
    monkeypatch.setenv("REPRO_SERVE_IDLE_OFFLOAD_STEPS", "nope")
    with pytest.warns(UserWarning, match="non-integer"):
        eng = Engine(LocalBackend(model, params, 2, 24))
    assert eng.scheduler.idle_offload_steps is None
    monkeypatch.delenv("REPRO_SERVE_IDLE_OFFLOAD_STEPS")
    with pytest.raises(ValueError, match="idle_offload_steps"):
        Engine(LocalBackend(model, params, 2, 24), idle_offload_steps=-1)


def test_engine_fills_lane_bytes_and_idle_knob_into_scheduler():
    _, model, params = build_model()
    backend = LocalBackend(model, params, 2, 24, spill_compress=True)
    hot_b, cold_b = backend.slot_kv_bytes()
    sched = FCFSScheduler(CapacityBudget(1e12, 1e12), hot_b, cold_b)
    eng = Engine(backend, scheduler=sched, idle_offload_steps=4)
    assert sched.idle_offload_steps == 4
    assert sched.lane_bytes == backend.spill_lane_bytes()
    assert sched.lane_bytes < hot_b + cold_b
