"""Whole-model integration of the Pallas kernel path: with
REPRO_PALLAS_INTERPRET=1 the fusion registry routes FUSED_ATTN_STREAM /
FUSED_FFN_ACT / FUSED_NORM through the Pallas kernels (interpret mode on
CPU); the forward must agree with the pure-jnp path."""

import os
import subprocess
import sys
import pathlib

import numpy as np
import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]

SCRIPT = r"""
import os, sys, json
import jax, jax.numpy as jnp
import numpy as np
from repro.configs.base import get_config
from repro.models import Model

arch = sys.argv[1]
cfg = get_config(arch, reduced=True).replace(
    param_dtype="float32", compute_dtype="float32", remat="none")
model_jnp = Model(cfg)
params = model_jnp.init(jax.random.PRNGKey(0))
B, S = 2, 32
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                      cfg.vocab_size)}
if cfg.frontend is not None and cfg.family != "audio":
    tv = cfg.frontend.num_tokens
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                          (B, S - tv), 0, cfg.vocab_size),
             "patches": jax.random.normal(jax.random.PRNGKey(2),
                                          (B, tv, cfg.frontend.frontend_dim))}
ref = model_jnp.forward(params, batch)

os.environ["REPRO_PALLAS_INTERPRET"] = "1"
model_k = Model(cfg.replace(use_pallas_kernels=True))
out = model_k.forward(params, batch)
err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                            - ref.astype(jnp.float32))))
rel = err / (float(jnp.max(jnp.abs(ref))) + 1e-9)
print("RESULT:" + json.dumps({"max_abs": err, "rel": rel}))
"""


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["granite-3-2b", "starcoder2-7b",
                                  "paligemma-3b"])
def test_model_forward_pallas_path_matches_jnp(arch):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env.pop("REPRO_PALLAS_INTERPRET", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT, arch], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT:")][0]
    import json
    res = json.loads(line[len("RESULT:"):])
    assert res["rel"] < 5e-3, res
