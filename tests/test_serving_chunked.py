"""Chunked prefill via the unified extend entry point (PR 3).

The load-bearing property: chunked prefill is TOKEN-FOR-TOKEN identical
to whole-prompt prefill — on GQA, MLA(+MoE), SSM and hybrid architectures,
on both backends, for flat and tiered KV policies, including VQA prompts
whose chunks split at the patch/text modality boundary. Plus: the
StepPlan scheduler's budget/FCFS/alignment behavior, decode interleaving
during a long prefill, the engine.run(max_steps=) off-by-one fix, the
REPRO_SERVE_CHUNK_TOKENS env knob, and the one-release deprecation shims
on the old prefill/insert backend surface.

Run with XLA_FLAGS=--xla_force_host_platform_device_count=8 to exercise
the sharded parity tests on a real multi-device mesh (the CI
serving-multi-device job does).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import build_model as _model
from conftest import generated as _generated
from conftest import make_mesh as _mesh
from conftest import make_requests

from repro.configs.base import get_config
from repro.serving import (CapacityBudget, Engine, FCFSScheduler,
                           LocalBackend, Request, ShardedBackend,
                           make_synthetic_requests)

jax.config.update("jax_platform_name", "cpu")

_requests = functools.partial(make_requests, seed=3)


# prompts sized so the chunk cap forces multi-chunk prefill; recurrent
# archs need prompts longer than their cfg.ssm.chunk_size grid unit
ARCH_CASES = {
    "granite-3-2b": dict(specs=[(16, 6), (13, 6), (8, 4), (16, 4)],
                         max_len=24, chunk=5),
    "deepseek-v2-lite": dict(specs=[(16, 6), (13, 6), (8, 6)],
                             max_len=24, chunk=5),
    "rwkv6-7b": dict(specs=[(40, 6), (35, 4)], max_len=48, chunk=32),
    "zamba2-1.2b": dict(specs=[(40, 6), (24, 4)], max_len=48, chunk=16),
}


def _parity(arch, *, kv_policy="tiered", backend_kind="local",
            image_every=0, num_slots=2):
    case = ARCH_CASES[arch]
    cfg, model, params = _model(arch, kv_policy=kv_policy)

    def reqs():
        if image_every:
            return make_synthetic_requests(
                cfg, 3, prompt_len=case["specs"][0][0],
                gen_len=case["specs"][0][1], seed=2,
                image_every=image_every)
        return _requests(cfg, case["specs"])

    def backend():
        if backend_kind == "sharded":
            return ShardedBackend(model, params, num_slots,
                                  case["max_len"], mesh=_mesh())
        return LocalBackend(model, params, num_slots, case["max_len"])

    whole = Engine(backend())
    got_w = _generated(whole.run(reqs(), max_steps=500))
    chunked = Engine(backend(), chunk_tokens=case["chunk"])
    got_c = _generated(chunked.run(reqs(), max_steps=900))
    assert got_w == got_c, f"{arch}: chunked prefill diverged from whole"
    # the chunk cap really forced multi-chunk prompts
    n_reqs = len(got_w)
    assert chunked.stats["prefill_chunks"] > n_reqs, chunked.stats
    if kv_policy == "tiered":
        assert chunked.endurance_report()["write_once_ok"]
    return got_w


# ---------------------------------------------------------------------------
# exact chunked-vs-whole token parity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", list(ARCH_CASES))
def test_chunked_matches_whole_local(arch):
    """GQA / MLA(+MoE) / RWKV6 / hybrid-Mamba2: chunked == whole, exactly.
    The recurrent archs run exact-length chunks on the canonical
    cfg.ssm.chunk_size grid; the attention archs run padded fixed-width
    chunks."""
    _parity(arch)


def test_chunked_matches_whole_flat_policy():
    _parity("granite-3-2b", kv_policy="flat")


@pytest.mark.parametrize("arch", ["granite-3-2b", "zamba2-1.2b"])
def test_chunked_matches_whole_sharded(arch):
    """The pjit backend's extend_step is a pure placement change too: the
    chunked sharded engine equals the chunked local engine's tokens (and
    both equal whole-prompt prefill)."""
    local = _parity(arch, backend_kind="local")
    sharded = _parity(arch, backend_kind="sharded")
    assert local == sharded


def test_chunked_vlm_mixed_stream_splits_modality_boundary():
    """VQA chunks split at the patch/text boundary: a mixed image+text
    stream chunked at 6 positions (< the visual span) matches whole
    prefill exactly, with patch-space and token-space chunks."""
    cfg, model, params = _model("mobilevlm-1.7b", hot_window=16)
    reqs = lambda: make_synthetic_requests(  # noqa: E731
        cfg, 3, prompt_len=20, gen_len=4, seed=2, image_every=2)
    whole = Engine(LocalBackend(model, params, 2, 32))
    got_w = _generated(whole.run(reqs(), max_steps=200))
    chunked = Engine(LocalBackend(model, params, 2, 32), chunk_tokens=6)
    got_c = _generated(chunked.run(reqs(), max_steps=400))
    assert got_w == got_c
    assert chunked.stats["prefill_chunks"] > 3


# ---------------------------------------------------------------------------
# Model.extend vs Model.prefill at the logits level (bit-exact)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch,chunks", [("granite-3-2b", (5, 5, 5, 1)),
                                         ("granite-3-2b", (8, 8)),
                                         ("deepseek-v2-lite", (5, 5, 5, 1))])
def test_extend_chunks_equal_prefill_logits(arch, chunks):
    """Any chunking of a prompt reproduces whole-prompt prefill's
    last-token logits: the same greedy token, with any residual
    difference at matmul-width rounding level (uneven chunk widths hit
    different GEMM accumulation blockings). The engine-level tests above
    hold the full served token streams to EXACT equality."""
    cfg, model, params = _model(arch)
    rng = np.random.default_rng(3)
    n = sum(chunks)
    toks = rng.integers(0, cfg.vocab_size, n).astype(np.int32)
    max_len = n + 8
    logits_w, _ = jax.jit(
        lambda p, b: model.prefill(p, b, max_len, n))(
        params, {"tokens": toks[None]})
    ext = model.init_extend_cache(1, max_len)
    pos = 0
    for i, c in enumerate(chunks):
        commit = i == len(chunks) - 1
        fn = jax.jit(lambda p, b, e, po, c=c, commit=commit: model.extend(
            p, b, e, po, length=c, commit=commit))
        logits_c, ext = fn(params, {"tokens": toks[pos:pos + c][None]},
                           ext, jnp.asarray(pos, jnp.int32))
        pos += c
    w = np.asarray(logits_w[:, -1])
    c = np.asarray(logits_c[:, -1])
    np.testing.assert_allclose(c, w, rtol=2e-4, atol=2e-4)
    np.testing.assert_array_equal(w.argmax(-1), c.argmax(-1))


# ---------------------------------------------------------------------------
# StepPlan scheduler behavior
# ---------------------------------------------------------------------------
def _sched(**kw):
    return FCFSScheduler(CapacityBudget(dram_bytes=1e9, rram_bytes=1e9),
                         hot_bytes_per_slot=100, cold_bytes_per_slot=100,
                         **kw)


def test_plan_splits_budget_between_decode_and_chunks():
    sched = _sched(token_budget=12, chunk_tokens=8)
    cfg = get_config("granite-3-2b", reduced=True)
    (req,) = _requests(cfg, [(20, 4)])
    sched.submit(req)
    # 4 decode slots leave 8 budget tokens -> one 8-token chunk
    plan = sched.plan(active_slots=4, decode_slots=4, free_slots=2,
                      inflight=None)
    assert [(c.start, c.length, c.admit, c.commit)
            for c in plan.chunks] == [(0, 8, True, False)]
    assert plan.decode
    # budget fully consumed by decode slots -> decode-only step
    sched2 = _sched(token_budget=4, chunk_tokens=8)
    sched2.submit(_requests(cfg, [(20, 4)])[0])
    assert sched2.plan(active_slots=4, decode_slots=4, free_slots=2,
                       inflight=None).chunks == ()
    # the in-flight prompt finishes before the next one is admitted (FCFS)
    plan3 = sched.plan(active_slots=4, decode_slots=4, free_slots=2,
                       inflight=(req, 8))
    assert [(c.start, c.length, c.commit) for c in plan3.chunks] \
        == [(8, 8, False)]
    plan4 = sched.plan(active_slots=4, decode_slots=4, free_slots=2,
                       inflight=(req, 16))
    assert [(c.start, c.length, c.commit) for c in plan4.chunks] \
        == [(16, 4, True)]


def test_plan_rounds_chunks_to_grid_unit():
    """Recurrent archs: non-final chunks align to cfg.ssm.chunk_size so
    the canonical SSM grid stays split-invariant; a unit never stalls
    even when the budget remainder is smaller."""
    cfg = get_config("zamba2-1.2b", reduced=True)
    sched = _sched(token_budget=100, chunk_tokens=10)
    (req,) = _requests(cfg, [(40, 4)])
    sched.submit(req)
    plan = sched.plan(active_slots=0, decode_slots=0, free_slots=1,
                      inflight=None, chunk_unit=16)
    lens = [c.length for c in plan.chunks]
    assert all(ln % 16 == 0 for ln in lens[:-1])
    assert sum(lens) == 40 and plan.chunks[-1].commit


def test_plan_admits_whole_queue_without_budget():
    """Default knobs reproduce the pre-StepPlan admission loop: every
    pending request prefills whole in one step, capacity permitting."""
    cfg = get_config("granite-3-2b", reduced=True)
    sched = _sched()
    for r in _requests(cfg, [(8, 2), (8, 2), (8, 2)]):
        sched.submit(r)
    plan = sched.plan(active_slots=0, decode_slots=0, free_slots=2,
                      inflight=None)
    # only 2 free slots -> 2 admissions, both whole-prompt commits
    assert [(c.admit, c.length, c.commit) for c in plan.chunks] \
        == [(True, 8, True), (True, 8, True)]
    assert sched.pending == 1


def test_engine_exposes_exact_prefill_grid():
    _, model, params = _model("zamba2-1.2b")
    b = LocalBackend(model, params, 1, 48)
    assert b.requires_exact_prefill
    assert b.chunk_unit == model.cfg.ssm.chunk_size
    _, model2, params2 = _model("granite-3-2b")
    b2 = LocalBackend(model2, params2, 1, 24)
    assert not b2.requires_exact_prefill and b2.chunk_unit == 1


# ---------------------------------------------------------------------------
# decode keeps flowing while a long prompt prefills
# ---------------------------------------------------------------------------
def test_decode_interleaves_with_chunked_prefill():
    """The Sarathi property this redesign exists for: with a token
    budget, already-running requests emit decode tokens in the same
    steps a long prompt's chunks run — the old engine stalled them for
    the whole prefill."""
    cfg, model, params = _model()
    eng = Engine(LocalBackend(model, params, 2, 32), chunk_tokens=4)
    short = _requests(cfg, [(8, 12)], seed=1)[0]
    eng.submit(short)
    eng.step()                                  # short request decoding
    long_req = Request(rid=7, tokens=np.arange(20, dtype=np.int32) % 11,
                       max_new_tokens=4)
    eng.submit(long_req)
    overlap = 0
    while not eng.idle:
        before = eng.stats["prefill_chunks"]
        events = eng.step()
        prefilled = eng.stats["prefill_chunks"] > before
        decoded_other = any(rid == short.rid for rid, _, _ in events)
        if prefilled and decoded_other:
            overlap += 1
    assert overlap >= 2, "decode stalled during chunked prefill"
    assert short.n_generated == 12 and long_req.n_generated == 4


# ---------------------------------------------------------------------------
# knobs, shims, off-by-one
# ---------------------------------------------------------------------------
def test_env_knob_enables_chunking(monkeypatch):
    monkeypatch.setenv("REPRO_SERVE_CHUNK_TOKENS", "6")
    cfg, model, params = _model()
    eng = Engine(LocalBackend(model, params, 2, 24))
    assert eng.scheduler.chunk_tokens == 6
    assert eng.scheduler.token_budget == 6 + 2
    (req,) = _requests(cfg, [(16, 3)])
    eng.run([req], max_steps=100)
    # 16 tokens under an 8-token step budget: 6+2 per step, 4 chunks
    assert eng.stats["prefill_chunks"] == 4
    assert req.n_generated == 3


def test_invalid_knobs_rejected_and_env_sanitized(monkeypatch):
    """Negative chunk/budget knobs raise (a negative cap would loop
    plan() forever); 0 is the explicit disable sentinel; malformed env
    values are ignored with a warning instead of wedging startup."""
    _, model, params = _model()
    backend = LocalBackend(model, params, 2, 24)
    with pytest.raises(ValueError, match="chunk_tokens"):
        Engine(backend, chunk_tokens=-3)
    with pytest.raises(ValueError, match="token_budget"):
        Engine(backend, chunk_tokens=4, token_budget=-1)
    # explicit 0 = disable/unbounded, even while chunking: the budget is
    # NOT rebound to the chunk+slots default
    e0 = Engine(backend, chunk_tokens=0, token_budget=0)
    assert e0.scheduler.chunk_tokens is None
    eu = Engine(backend, chunk_tokens=4, token_budget=0)
    assert eu.scheduler.chunk_tokens == 4
    assert eu.scheduler.token_budget is None
    # knobs reach a user-provided base scheduler too (CI env forcing)
    hot_b, cold_b = backend.slot_kv_bytes()
    sched = FCFSScheduler(CapacityBudget(1e12, 1e12), hot_b, cold_b)
    assert Engine(backend, scheduler=sched,
                  chunk_tokens=5).scheduler.chunk_tokens == 5
    monkeypatch.setenv("REPRO_SERVE_CHUNK_TOKENS", "-6")
    with pytest.warns(UserWarning, match="negative"):
        eng = Engine(backend)
    assert eng.scheduler.chunk_tokens is None
    monkeypatch.setenv("REPRO_SERVE_CHUNK_TOKENS", "nope")
    with pytest.warns(UserWarning, match="non-integer"):
        eng = Engine(backend)
    assert eng.scheduler.chunk_tokens is None


def test_run_max_steps_raises_at_exactly_max_steps():
    """Off-by-one fix: run(max_steps=N) allows exactly N steps.
    chunk_tokens=0 pins whole-prompt prefill so the step count is
    deterministic even under the env chunking knob."""
    cfg, model, params = _model()
    # (8, 3) drains in exactly 2 steps: commit+decode, then final decode
    eng = Engine(LocalBackend(model, params, 1, 16), chunk_tokens=0)
    eng.run(_requests(cfg, [(8, 3)]), max_steps=2)
    eng2 = Engine(LocalBackend(model, params, 1, 16), chunk_tokens=0)
    with pytest.raises(RuntimeError, match="did not drain in 1"):
        eng2.run(_requests(cfg, [(8, 3)]), max_steps=1)


def test_backend_prefill_insert_shims_removed():
    """The PR 3 backend.prefill/insert deprecation shims expired: the
    whole-prompt surface is gone (extend_step is the only prefill path)
    while the pool-internal _insert_state recycling path still works."""
    cfg, model, params = _model()
    backend = LocalBackend(model, params, 2, 24)
    assert not hasattr(backend, "prefill")
    assert not hasattr(backend, "insert")
    # pool-internal recycling never went through the deprecated surface
    pool = backend.make_pool()
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error", DeprecationWarning)
        pool.reset(0)


def test_scheduler_next_request_shim_removed():
    """The PR 3 FCFSScheduler.next_request shim expired: plan() is the
    only admission surface, and a subclass override of the removed name
    no longer steers the engine (it plans with the base class)."""
    sched = _sched()
    assert not hasattr(FCFSScheduler, "next_request")
    assert not hasattr(sched, "next_request")


def test_metrics_report_ttft_and_tbt_percentiles():
    cfg, model, params = _model()
    from repro.serving import aggregate_metrics
    eng = Engine(LocalBackend(model, params, 2, 24), chunk_tokens=5)
    done = eng.run(_requests(cfg, [(13, 5), (8, 5)]), max_steps=200)
    m = aggregate_metrics(done, wall_s=1.0)
    for k in ("ttft_p50_s", "ttft_p95_s", "tbt_p50_s", "tbt_p95_s"):
        assert k in m and m[k] >= 0.0
    assert all(len(r.token_times) == r.n_generated for r in done)
