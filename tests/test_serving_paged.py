"""Paged prefix-sharing KV pool: exact-parity, copy-on-write, endurance
and refcount-invariant tests for `serving.block_pool` + the engine's
paged admission path.

The contract under test (PR 7):

* a paged engine (`Engine(paged=True)`) is EXACTLY token-equal to the
  slot-pool engine over shared-prefix streams — across GQA / MLA / SSM /
  hybrid mixers, local and sharded backends, whole-prompt and chunked
  prefill, and under priority preemption through the RRAM spill lanes;
* a request diverging strictly INSIDE a shared block still hits the
  common prefix and registers its tail to a FRESH block (copy-on-write
  — the shared block is never rewritten);
* a shared block is physically written exactly once no matter how many
  requests reference it (the RRAM write-once contract, audited via
  `BlockPool.block_writes`), with recurrent state snapshots accounted
  as one extra write on the chain terminal;
* block-charged admission (`FCFSScheduler` charge mode) admits more
  concurrent sharers than worst-case slot charging from the same DRAM
  byte budget;
* refcounts are conserved under arbitrary interleavings of
  register / lookup+acquire / release / epoch (hypothesis), and
  `BlockPool.check_invariants` holds throughout — eviction can never
  free a referenced block.
"""

import numpy as np
import pytest
from conftest import build_model as _model
from conftest import make_mesh as _mesh

import jax

from repro.serving import (BlockPool, CapacityBudget, Engine,
                           FCFSScheduler, LocalBackend, Request,
                           ShardedBackend, slot_kv_bytes,
                           spill_lane_bytes)

jax.config.update("jax_platform_name", "cpu")

# per-arch geometry: ``head`` is the shared prefix every request opens
# with, ``tails`` the per-request unique suffix lengths (request 0
# registers the chain; the rest are sharers). Recurrent mixers need the
# head ON the chunk grid and the registering prompt EQUAL to it (state
# snapshots only attach to grid-aligned chain terminals).
CASES = {
    "granite-3-2b": dict(head=12, tails=(4, 1, 4), gen=5, bt=4,
                         max_len=24, chunk=6),                 # GQA
    "deepseek-v2-lite": dict(head=12, tails=(4, 1, 4), gen=5, bt=4,
                             max_len=24, chunk=6),             # MLA
    "rwkv6-7b": dict(head=32, tails=(0, 8, 8), gen=5, bt=32,
                     max_len=48, chunk=32),                    # SSM
    "zamba2-1.2b": dict(head=32, tails=(0, 8, 8), gen=5, bt=16,
                        max_len=48, chunk=16),                 # hybrid
}


def _shared_head_requests(cfg, head, tails, gen, seed=3, priorities=None):
    """Requests sharing a ``head``-token prompt prefix, with unique
    random tails of the given lengths."""
    rng = np.random.default_rng(seed)
    head_toks = rng.integers(0, cfg.vocab_size, head).astype(np.int32)
    reqs = []
    for i, tail in enumerate(tails):
        toks = head_toks if tail == 0 else np.concatenate(
            [head_toks,
             rng.integers(0, cfg.vocab_size, tail).astype(np.int32)])
        reqs.append(Request(
            rid=i, tokens=np.asarray(toks, np.int32), max_new_tokens=gen,
            priority=0 if priorities is None else priorities[i]))
    return reqs


def _drain_warm(engine, reqs):
    """Drain the chain-registering head request first, then the sharers
    together — every sharer's admission probe then sees the registered
    chain (FCFS admissions within one plan() call probe before the
    earlier request's commit registers, so a single burst would
    cold-prefill the whole first wave)."""
    engine.submit(reqs[0])
    while not engine.idle:
        engine.step()
    for r in reqs[1:]:
        engine.submit(r)
    while not engine.idle:
        engine.step()
    return {r.rid: list(r.generated) for r in engine.finished}


_BASELINE: dict = {}


def _requests(arch, **kw):
    case = CASES[arch]
    cfg, _, _ = _model(arch)
    return _shared_head_requests(cfg, case["head"], case["tails"],
                                 case["gen"], **kw)


def _baseline(arch):
    """Slot-pool (paged=False) reference tokens for the arch's shared
    stream. Chunked/whole and local/sharded engines are all held
    token-identical by the existing parity suites, so ONE baseline
    serves every paged mode."""
    if arch not in _BASELINE:
        case = CASES[arch]
        _, model, params = _model(arch)
        eng = Engine(LocalBackend(model, params, num_slots=2,
                                  max_len=case["max_len"]), paged=False)
        _BASELINE[arch] = _drain_warm(eng, _requests(arch))
    return _BASELINE[arch]


def _check_paged(engine, arch, got):
    case = CASES[arch]
    n_sharers = len(case["tails"]) - 1
    assert got == _baseline(arch), \
        f"{arch}: paged tokens diverged from the slot pool"
    assert engine.stats["prefix_hits"] == n_sharers
    assert engine.stats["prefix_hit_tokens"] >= n_sharers * case["head"]
    bp = engine.block_pool
    bp.check_invariants()
    assert bp.total_refcount == 0, "refcounts leaked past drain"
    assert engine.endurance_report()["write_once_ok"]


# ---------------------------------------------------------------------------
# exact parity: GQA / MLA / SSM / hybrid x local / sharded x whole /
# chunked prefill
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", list(CASES))
@pytest.mark.parametrize("mode", ["whole", "chunked"])
def test_paged_matches_slot_local(arch, mode):
    case = CASES[arch]
    _, model, params = _model(arch)
    chunk = None if mode == "whole" else case["chunk"]
    eng = Engine(LocalBackend(model, params, num_slots=2,
                              max_len=case["max_len"],
                              block_tokens=case["bt"]),
                 chunk_tokens=chunk, paged=True)
    _check_paged(eng, arch, _drain_warm(eng, _requests(arch)))


@pytest.mark.parametrize("arch", list(CASES))
@pytest.mark.parametrize("mode", ["whole", "chunked"])
def test_paged_matches_slot_sharded(arch, mode):
    """Paged admission under pjit placement: the prefix store shards
    with the pool and block seeding stays exact."""
    case = CASES[arch]
    _, model, params = _model(arch)
    chunk = None if mode == "whole" else case["chunk"]
    eng = Engine(ShardedBackend(model, params, num_slots=2,
                                max_len=case["max_len"], mesh=_mesh(),
                                block_tokens=case["bt"]),
                 chunk_tokens=chunk, paged=True)
    _check_paged(eng, arch, _drain_warm(eng, _requests(arch)))


def test_paged_matches_slot_shared_image_vlm():
    """Many questions about ONE image: requests share the visual span
    (keyed by per-patch-row digest) + a text head; parity and hits must
    survive the multimodal prefix."""
    cfg, model, params = _model("mobilevlm-1.7b")
    tv = cfg.frontend.num_tokens
    rng = np.random.default_rng(5)
    patches = np.asarray(
        rng.standard_normal((tv, cfg.frontend.frontend_dim)), np.float32)
    head = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    token_streams = [
        np.concatenate([head, rng.integers(0, cfg.vocab_size, tail)
                        .astype(np.int32)]) for tail in (4, 2, 4)]

    def reqs():
        return [Request(rid=i, tokens=toks.copy(),
                        patches=patches.copy(), max_new_tokens=4)
                for i, toks in enumerate(token_streams)]

    max_len = tv + 12 + 4 + 4
    slot = Engine(LocalBackend(model, params, num_slots=2,
                               max_len=max_len), paged=False)
    got_slot = _drain_warm(slot, reqs())
    paged = Engine(LocalBackend(model, params, num_slots=2,
                                max_len=max_len, block_tokens=4),
                   paged=True)
    got_paged = _drain_warm(paged, reqs())
    assert got_paged == got_slot
    assert paged.stats["prefix_hits"] == 2
    # the whole visual span + shared text head is reused
    assert paged.stats["prefix_hit_tokens"] >= 2 * (tv + 8)
    paged.block_pool.check_invariants()
    assert paged.block_pool.total_refcount == 0


# ---------------------------------------------------------------------------
# copy-on-write + write-once endurance
# ---------------------------------------------------------------------------
def test_cow_divergence_mid_block():
    """Two prompts diverging strictly INSIDE block [8, 12): the sharer
    hits the 10-position common prefix, recomputes from there, and its
    differing block registers to a FRESH id — the shared block keeps
    exactly one write and the answers match the slot pool."""
    cfg, model, params = _model("granite-3-2b")
    rng = np.random.default_rng(9)
    base = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
    fork = base.copy()
    fork[10] = (fork[10] + 1) % cfg.vocab_size

    def reqs():
        return [Request(rid=0, tokens=base.copy(), max_new_tokens=4),
                Request(rid=1, tokens=fork.copy(), max_new_tokens=4)]

    slot = Engine(LocalBackend(model, params, num_slots=2, max_len=20),
                  paged=False)
    got_slot = _drain_warm(slot, reqs())
    paged = Engine(LocalBackend(model, params, num_slots=2, max_len=20,
                                block_tokens=4), paged=True)
    got_paged = _drain_warm(paged, reqs())
    assert got_paged == got_slot
    bp = paged.block_pool
    assert paged.stats["prefix_hits"] == 1
    assert paged.finished[-1].prefix_hit == 10          # mid-block hit
    assert bp.stats["cow_copies"] == 1
    # 3 blocks from the cold prompt + 1 CoW block from the fork; every
    # physical block written exactly once
    assert bp.stats["blocks_registered"] == 4
    assert bp.stats["block_writes"] == 4
    assert int(bp.block_writes.max()) == 1
    bp.check_invariants()


def test_shared_blocks_written_once_n_way():
    """Five identical prompts: the first writes 4 blocks, the other four
    adopt them by reference — zero additional physical writes."""
    cfg, model, params = _model("granite-3-2b")
    rng = np.random.default_rng(2)
    toks = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
    eng = Engine(LocalBackend(model, params, num_slots=2, max_len=24,
                              block_tokens=4), paged=True)
    for i in range(5):
        eng.submit(Request(rid=i, tokens=toks.copy(), max_new_tokens=4))
        while not eng.idle:
            eng.step()
    bp = eng.block_pool
    assert eng.stats["prefix_hits"] == 4
    assert bp.stats["blocks_registered"] == 4
    assert bp.stats["block_writes"] == 4
    assert int(bp.block_writes.max()) == 1, \
        "a shared block was rewritten under N-way sharing"
    assert bp.max_refcount == 0
    bp.check_invariants()
    # all five answers identical (same prompt, greedy decode)
    outs = {tuple(r.generated) for r in eng.finished}
    assert len(outs) == 1


def test_ssm_state_snapshot_write_accounting():
    """Recurrent chains carry one EXTRA write for the terminal state
    snapshot: a 32-token rwkv6 prompt registers 1 block (ws rows) + 1
    snapshot = 2 writes; sharers add none."""
    cfg, model, params = _model("rwkv6-7b")
    case = CASES["rwkv6-7b"]
    eng = Engine(LocalBackend(model, params, num_slots=2,
                              max_len=case["max_len"],
                              block_tokens=case["bt"]), paged=True)
    _drain_warm(eng, _requests("rwkv6-7b"))
    bp = eng.block_pool
    assert eng.stats["prefix_hits"] == 2
    assert eng.stats["prefix_hit_tokens"] == 2 * case["head"]
    assert bp.stats["blocks_registered"] == 1
    assert bp.stats["block_writes"] == 2        # ws rows + state snapshot
    assert int(bp.block_writes.max()) == 2
    bp.check_invariants()


# ---------------------------------------------------------------------------
# preemption / spill interplay
# ---------------------------------------------------------------------------
def test_paged_parity_under_preemption():
    """A priority-1 sharer lands mid-run, preempts a low-priority victim
    into an RRAM spill lane, and everyone still finishes token-identical
    to the slot-pool engine running the same trace."""
    cfg, model, params = _model("granite-3-2b")

    def reqs():
        return _shared_head_requests(
            cfg, 12, (4, 1, 2, 3), gen=8, seed=4,
            priorities=(0, 0, 0, 1))

    def drive(paged):
        eng = Engine(LocalBackend(model, params, num_slots=2, max_len=24,
                                  n_spill=2, block_tokens=4),
                     paged=paged)
        rs = reqs()
        eng.submit(rs[0])
        while not eng.idle:
            eng.step()
        for r in rs[1:3]:                     # fill both slots
            eng.submit(r)
        for _ in range(3):
            eng.step()
        eng.submit(rs[3])                     # priority-1: preempts
        while not eng.idle:
            eng.step()
        return eng, {r.rid: list(r.generated) for r in eng.finished}

    slot_eng, got_slot = drive(False)
    paged_eng, got_paged = drive(True)
    assert got_paged == got_slot
    assert paged_eng.stats["evictions"] >= 1, \
        "trace never exercised preemption"
    assert paged_eng.stats["prefix_hits"] >= 3
    paged_eng.block_pool.check_invariants()
    assert paged_eng.block_pool.total_refcount == 0
    assert paged_eng.endurance_report()["write_once_ok"]


# ---------------------------------------------------------------------------
# block-charged admission capacity
# ---------------------------------------------------------------------------
def test_block_charged_admission_beats_slot_charging():
    """Same DRAM byte budget (2 worst-case slot images): slot charging
    pins concurrency at 2, block charging admits every sharer at once
    because a prefix hit only charges the unshared tail blocks."""
    cfg, model, params = _model("granite-3-2b", hot_window=28)
    backend = LocalBackend(model, params, num_slots=4, max_len=28,
                           block_tokens=4)
    hot_b, cold_b = backend.slot_kv_bytes()

    def drive(paged):
        sched = FCFSScheduler(
            CapacityBudget(2 * hot_b, 16 * (hot_b + cold_b)),
            hot_b, cold_b)
        eng = Engine(backend, scheduler=sched, paged=paged)
        rs = _shared_head_requests(cfg, 20, (4, 1, 2, 3), gen=4, seed=6)
        eng.submit(rs[0])
        while not eng.idle:
            eng.step()
        for r in rs[1:]:
            eng.submit(r)
        peak = 0
        while not eng.idle:
            eng.step()
            peak = max(peak, eng.pool.active_slots)
        return peak, {r.rid: list(r.generated) for r in eng.finished}

    slot_peak, got_slot = drive(False)
    paged_peak, got_paged = drive(True)
    assert got_paged == got_slot
    assert slot_peak == 2, "worst-case charging should cap at the budget"
    assert paged_peak == 3, \
        f"block charging admitted {paged_peak} sharers, expected all 3"


def test_cached_blocks_do_not_wedge_admission():
    """Regression: only *pinned* prefix blocks (refcount > 0) may charge
    the RRAM gate. An RRAM budget with zero headroom over two residents
    must keep admitting wave after wave — the earlier waves' blocks stay
    cached (reclaimable), and charging them would deny every later
    admission forever."""
    cfg, model, params = _model("granite-3-2b")
    backend = LocalBackend(model, params, num_slots=4, max_len=24,
                           block_tokens=4)
    hot_b, cold_b = backend.slot_kv_bytes()
    sched = FCFSScheduler(CapacityBudget(2 * hot_b, 2 * cold_b),
                          hot_b, cold_b, oversubscribe=1.0)
    eng = Engine(backend, scheduler=sched, paged=True)
    reqs = _shared_head_requests(cfg, 12, (4, 1, 4, 2, 3), gen=4, seed=9)
    done = eng.run(list(reqs), max_steps=300)
    assert len(done) == 5
    assert eng.block_pool.pinned_blocks == 0
    assert eng.block_pool.used_blocks > 0, "cache should stay warm"


def test_slot_kv_bytes_length_aware():
    """Satellite: the byte model's live-length variant rounds to whole
    blocks, clamps to max_len, and never exceeds the worst case."""
    _, model, _ = _model("granite-3-2b")
    full = slot_kv_bytes(model, 24)
    short = slot_kv_bytes(model, 24, length=5, block_tokens=4)
    assert short == slot_kv_bytes(model, 24, length=8, block_tokens=4), \
        "length must be charged in whole blocks"
    assert short[0] <= full[0] and short[1] < full[1]
    assert slot_kv_bytes(model, 24, length=999, block_tokens=4) == full
    lane_full = spill_lane_bytes(model, 24)
    lane_short = spill_lane_bytes(model, 24, length=5, block_tokens=4)
    assert lane_short < lane_full
    assert spill_lane_bytes(model, 24, length=999, block_tokens=4) \
        == lane_full


# ---------------------------------------------------------------------------
# telemetry: prefix-adopt ledger terms reconcile bit-for-bit
# ---------------------------------------------------------------------------
def test_paged_ledger_reconciles_with_simulated_efficiency(tmp_path):
    """On a drained paged run the step-by-step TierLedger (which prices
    tail-only prefills + the PREFIX_ADOPT RRAM/UCIe traffic as the
    engine runs) must equal `simulated_efficiency` (one fsum over the
    whole trace, `cached_prefix` per request) EXACTLY, and the prefix
    gauges must surface in the Prometheus exposition."""
    from repro.serving import (Telemetry, parse_prometheus,
                               simulated_efficiency)

    cfg, model, params = _model("granite-3-2b")
    tel = Telemetry()
    eng = Engine(LocalBackend(model, params, num_slots=2, max_len=24,
                              block_tokens=4), paged=True, telemetry=tel)
    _drain_warm(eng, _requests("granite-3-2b"))
    assert eng.stats["prefix_hits"] == 2
    sim = simulated_efficiency(cfg, eng.finished)
    led = tel.ledger.totals()
    assert led["sim_energy_j"] == sim["sim_energy_j"]
    assert led["sim_total_s"] == sim["sim_total_s"]
    assert led["sim_energy_split_j"] == sim["sim_energy_split_j"]
    assert led["prefix_adopt_bytes"] > 0
    path = tmp_path / "metrics.prom"
    tel.write_prometheus(str(path))
    samples = parse_prometheus(path.read_text())
    by = {name: value for name, _, value in samples}
    assert by["repro_serving_prefix_hits"] == 2
    assert by["repro_serving_prefix_hit_tokens"] \
        == eng.stats["prefix_hit_tokens"]
    assert "repro_serving_prefix_blocks_used" in by
    assert "repro_serving_prefix_cow_copies" in by
    tel.close()


# ---------------------------------------------------------------------------
# property tests: refcount conservation + structural invariants
# ---------------------------------------------------------------------------
def _drive_pool_ops(choose_int, choose_seq, choose_op, n_ops):
    """Random interleavings of register / lookup+acquire / release /
    epoch on a small pool over a tiny key alphabet (maximal collisions):
    the pool's total refcount always equals the outstanding
    acquisitions, eviction under pressure never frees a referenced
    block (check_invariants + the double-release guard would trip), and
    releasing everything returns the count to zero. ``choose_*`` are
    the randomness hooks — a seeded numpy RNG for the always-on test,
    hypothesis draws for the shrinking one."""
    pool = BlockPool(num_blocks=5, block_tokens=3)
    seqs = [[choose_int(0, 2) for _ in range(choose_int(1, 11))]
            for _ in range(choose_int(1, 5))]
    held = []
    for _ in range(n_ops):
        op = choose_op(["register", "acquire", "release", "epoch"])
        keys = tuple(choose_seq(seqs))
        if op == "register":
            new, term = pool.register(keys, max_start=100)
            assert all(n.refcount == 0 for n in new)
            if term is not None:
                assert term.end == len(keys)
        elif op == "acquire":
            hit = pool.lookup(keys, max_hit=max(len(keys) - 1, 1))
            assert hit.length <= max(len(keys) - 1, 1)
            if hit.length:
                pool.acquire(hit)
                held.append(hit)
        elif op == "release" and held:
            pool.release(held.pop(choose_int(0, len(held) - 1)))
        else:
            pool.begin_epoch()
        pool.check_invariants()
        assert pool.total_refcount == sum(len(h.nodes) for h in held), \
            "refcount drifted from outstanding acquisitions"
    for h in held:
        pool.release(h)
    assert pool.total_refcount == 0
    pool.check_invariants()


@pytest.mark.parametrize("seed", range(25))
def test_block_pool_refcount_conservation_seeded(seed):
    """Deterministic randomized interleavings (always runs, even without
    hypothesis installed)."""
    rng = np.random.default_rng(seed)
    _drive_pool_ops(
        choose_int=lambda lo, hi: int(rng.integers(lo, hi + 1)),
        choose_seq=lambda seqs: seqs[int(rng.integers(len(seqs)))],
        choose_op=lambda ops: ops[int(rng.integers(len(ops)))],
        n_ops=int(rng.integers(1, 41)))


def test_block_pool_refcount_conservation_hypothesis():
    """The same invariants under hypothesis's shrinking search."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(st.data())
    @settings(max_examples=60, deadline=None)
    def run(data):
        _drive_pool_ops(
            choose_int=lambda lo, hi: data.draw(st.integers(lo, hi)),
            choose_seq=lambda seqs: data.draw(st.sampled_from(seqs)),
            choose_op=lambda ops: data.draw(st.sampled_from(ops)),
            n_ops=data.draw(st.integers(1, 40)))

    run()


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-q"]))
