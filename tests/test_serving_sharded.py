"""Sharded/local backend parity: the pjit `ShardedBackend` must be a pure
placement change. Every test holds it to EXACT token equality with
`LocalBackend` — on the 1-device `make_local_mesh` always, and on 8 fake
CPU devices either in-process (when the host platform was forced to 8
devices, as the CI multi-device job does) or via a subprocess re-exec.

Run the multi-device path directly with:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m pytest -q tests/test_serving_sharded.py
"""

import subprocess
import sys

import jax
import pytest
from conftest import build_model as _model
from conftest import forced_device_env
from conftest import generated as _generated
from conftest import make_mesh as _mesh
from conftest import make_requests

from repro.serving import (Engine, LocalBackend, ShardedBackend,
                           make_synthetic_requests)

jax.config.update("jax_platform_name", "cpu")


def _requests(cfg, specs, seed=3):
    return make_requests(cfg, specs, seed=seed)


def _run_parity(arch, specs, *, kv_policy="tiered", num_slots=4,
                max_len=24, seed=3, image_every=0):
    cfg, model, params = _model(arch, kv_policy=kv_policy)
    if image_every:
        reqs = lambda: make_synthetic_requests(   # noqa: E731
            cfg, len(specs), prompt_len=specs[0][0], gen_len=specs[0][1],
            seed=seed, image_every=image_every)
    else:
        reqs = lambda: _requests(cfg, specs, seed=seed)  # noqa: E731
    local = Engine(LocalBackend(model, params, num_slots, max_len))
    sharded = Engine(ShardedBackend(model, params, num_slots, max_len,
                                    mesh=_mesh()))
    got_l = _generated(local.run(reqs(), max_steps=400))
    got_s = _generated(sharded.run(reqs(), max_steps=400))
    assert got_l == got_s, f"{arch}: sharded decode diverged from local"
    # the audit must hold on the sharded pool too (per-slot counters
    # survive pjit placement and slot recycling)
    if kv_policy == "tiered":
        assert sharded.endurance_report()["write_once_ok"]
    return got_l


# ---------------------------------------------------------------------------
# exact parity on whatever devices this process has (1 locally, 8 in the
# CI multi-device job)
# ---------------------------------------------------------------------------
def test_sharded_matches_local_gqa_tiered_padded_buckets():
    """GQA + tiered KV + a padded admission bucket (13 -> 16) + slot
    recycling (6 requests through 4 slots)."""
    out = _run_parity("granite-3-2b",
                      [(16, 8), (13, 8), (8, 6), (16, 4), (13, 6), (8, 8)])
    assert len(out) == 6


def test_sharded_matches_local_mla():
    _run_parity("deepseek-v2-lite", [(16, 6), (13, 6), (16, 4), (8, 6)])


def test_sharded_matches_local_flat_policy():
    _run_parity("granite-3-2b", [(16, 6), (13, 6), (8, 4), (16, 4)],
                kv_policy="flat")


def test_sharded_matches_local_vlm_mixed_stream():
    """VQA + text mixed stream: visual patches ride through the sharded
    prefill path too."""
    _run_parity("mobilevlm-1.7b", [(20, 4)] * 3, num_slots=2, max_len=32,
                image_every=2, seed=2)


def test_sharded_pool_state_is_committed_to_mesh():
    """The pool cache must actually live on the backend's mesh sharding
    (not fall back to single-device default placement)."""
    _, model, params = _model()
    b = ShardedBackend(model, params, 4, 24, mesh=_mesh())
    state = b.init_pool()
    shardings = jax.tree.leaves(b._pool_sh)
    leaves = jax.tree.leaves(state.cache)
    assert len(shardings) == len(leaves)
    for leaf, want in zip(leaves, shardings):
        assert leaf.sharding == want


# ---------------------------------------------------------------------------
# forced 8-device host platform (subprocess: XLA flags must be set before
# jax initializes, so an in-process re-init is impossible)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_sharded_parity_on_8_fake_cpu_devices():
    if jax.device_count() >= 8:
        pytest.skip("already on a multi-device host platform; the "
                    "in-process parity tests above cover it")
    from conftest import REPO
    proc = subprocess.run(
        [sys.executable, __file__, "--eight-device-selfcheck"],
        cwd=REPO, env=forced_device_env(8), capture_output=True,
        text=True, timeout=900)
    assert proc.returncode == 0, (
        f"8-device parity selfcheck failed:\n{proc.stdout}\n{proc.stderr}")
    assert "PARITY OK on 8 devices" in proc.stdout


def _eight_device_selfcheck():
    n = jax.device_count()
    assert n == 8, f"expected 8 forced host devices, got {n}"
    _run_parity("granite-3-2b", [(16, 8), (13, 8), (8, 6), (16, 4)])
    print("PARITY OK on 8 devices")


if __name__ == "__main__":
    if "--eight-device-selfcheck" in sys.argv:
        _eight_device_selfcheck()
