"""Serving telemetry (PR 6): span tracing, the tier-traffic ledger, and
the Perfetto/Prometheus exports.

The load-bearing property is CONSERVATION: the `TierLedger` prices
engine events live, step by step, and on a drained run its totals must
equal `simulated_efficiency` over the finished trace **bit for bit** —
same floats, not approximately — including on a forced-preemption
stream where spill/restore traffic and compressed lanes are in play.
Both sides fold the identical `CostTerm` multiset with `math.fsum`
(correctly rounded, hence order-independent), so any drift is a real
accounting bug, never float noise.

Plus: Chrome-trace schema validation (every phase span, slot/lane/
request timeline, counter track), strict Prometheus exposition parsing,
scheduler decision codes under forced denial/preemption, the
NullTelemetry no-op contract (disabled telemetry must not perturb
tokens), and the metrics edge cases this PR fixed — empty finished
lists, requests that never emitted a token, evictions whose restore
never happened.
"""

import json

import jax
import numpy as np
import pytest
from conftest import build_model, make_requests, oracle_tokens

from repro.serving import (CapacityBudget, Engine, FCFSScheduler,
                           LocalBackend, NullTelemetry, REASON_CODES,
                           Request, Telemetry, aggregate_metrics,
                           parse_prometheus, request_metrics,
                           simulated_efficiency, validate_chrome_trace)

jax.config.update("jax_platform_name", "cpu")

ARCH = "granite-3-2b"


def _preempt_engine(telemetry=None, spill_compress=False,
                    chunk_tokens=5):
    """A forced-preemption scenario: DRAM budget of exactly two
    residents, both slots decoding priority-0 work when a priority-1
    intruder lands — evict, park, restore, drain."""
    cfg, model, params = build_model(ARCH)
    backend = LocalBackend(model, params, 2, 32,
                           spill_compress=spill_compress)
    hot_b, cold_b = backend.slot_kv_bytes()
    sched = FCFSScheduler(CapacityBudget(2 * hot_b, 1e15), hot_b, cold_b,
                          oversubscribe=1.0)
    eng = Engine(backend, scheduler=sched, chunk_tokens=chunk_tokens,
                 telemetry=telemetry)
    low_hi = make_requests(cfg, [(12, 10), (12, 10), (8, 4)], seed=3,
                           priorities=[0, 0, 1])
    for r in low_hi[:2]:
        eng.submit(r)
    for _ in range(6):
        eng.step()
    eng.submit(low_hi[2])
    eng.run(max_steps=400)
    assert len(eng.finished) == 3
    assert eng.stats["evictions"] >= 1, eng.stats
    return cfg, backend, eng, low_hi


# ---------------------------------------------------------------------------
# conservation: ledger == simulated_efficiency, bit for bit
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("spill_compress", [False, True])
def test_ledger_conserves_bit_for_bit(spill_compress):
    """The headline invariant: on a drained forced-preemption run the
    live ledger's fsum totals are the SAME floats as the end-of-run
    simulated_efficiency — energy, time, spill split, and the full
    per-domain energy split dict."""
    tel = Telemetry()
    cfg, backend, eng, _ = _preempt_engine(
        telemetry=tel, spill_compress=spill_compress)
    led = tel.ledger.totals()
    sim = simulated_efficiency(cfg, eng.finished,
                               spill_compressed=backend.spill_compress)
    assert led["sim_energy_j"] == sim["sim_energy_j"]
    assert led["sim_total_s"] == sim["sim_total_s"]
    assert led["sim_spill_energy_j"] == sim["sim_spill_energy_j"]
    assert led["sim_spill_s"] == sim["sim_spill_s"]
    assert led["sim_energy_split_j"] == sim["sim_energy_split_j"]
    # the split is exhaustive: domains fsum back to the total
    assert np.isclose(sum(led["sim_energy_split_j"].values()),
                      led["sim_energy_j"], rtol=1e-12)
    assert led["requests_closed"] == 3
    assert led["tokens"] == sum(r.n_generated for r in eng.finished)
    # the byte-level tier counters saw real traffic
    assert led["dram_hot_ring_bytes"] > 0
    assert led["rram_cold_read_bytes"] > 0   # ctx grows past hot_window=8
    assert led["rram_spill_bytes"] > 0       # the eviction + restore
    assert led["kv_append_bytes"] > 0


def test_ledger_conserves_without_spills():
    """Conservation also holds on a plain unpressured run (no spill
    terms in either stream)."""
    cfg, model, params = build_model(ARCH)
    backend = LocalBackend(model, params, 2, 24)
    tel = Telemetry()
    eng = Engine(backend, telemetry=tel)
    reqs = make_requests(cfg, [(8, 6), (10, 4), (6, 5)], seed=1)
    eng.run(reqs)
    led = tel.ledger.totals()
    sim = simulated_efficiency(cfg, eng.finished)
    assert led["sim_energy_j"] == sim["sim_energy_j"]
    assert led["sim_total_s"] == sim["sim_total_s"]
    assert led["sim_energy_split_j"] == sim["sim_energy_split_j"]
    assert led["rram_spill_bytes"] == 0.0


# ---------------------------------------------------------------------------
# trace + exposition schemas
# ---------------------------------------------------------------------------
def test_chrome_trace_schema_and_content(tmp_path):
    tel = Telemetry()
    cfg, backend, eng, reqs = _preempt_engine(telemetry=tel)
    path = tmp_path / "trace.json"
    tel.write_chrome_trace(str(path))
    trace = json.loads(path.read_text())
    info = validate_chrome_trace(trace)
    # every engine phase that ran is a named span on the engine track
    for phase in ("plan", "chunk-prefill", "commit", "decode", "evict",
                  "restore"):
        assert phase in info["phases"], info["phases"]
    # all four timeline processes present, with slot/lane/request lanes
    assert info["processes"] == [1, 2, 3, 4]
    assert info["spans"] > 0 and info["counters"] > 0
    # preempt + restore instants on the victim's request track
    names = [e["name"] for e in trace["traceEvents"]]
    assert "preempt" in names and "first-token" in names
    # ts/dur are µs ints and non-negative (validator enforced; spot-check)
    xs = [e for e in trace["traceEvents"] if e.get("ph") == "X"]
    assert all(e["dur"] >= 1 for e in xs)


def test_chrome_trace_mid_run_closes_open_segments():
    """chrome_trace() mid-run must close open slot/request segments at
    the last timestamp WITHOUT mutating live state."""
    cfg, model, params = build_model(ARCH)
    backend = LocalBackend(model, params, 2, 24)
    tel = Telemetry()
    eng = Engine(backend, telemetry=tel)
    for r in make_requests(cfg, [(8, 8), (8, 8)], seed=2):
        eng.submit(r)
    for _ in range(4):
        eng.step()
    open_before = dict(tel._req_open)
    info = validate_chrome_trace(tel.chrome_trace())
    assert tel._req_open == open_before      # not mutated
    assert info["spans"] > 0
    eng.run(max_steps=200)                   # still drains cleanly
    assert len(eng.finished) == 2


def test_validate_chrome_trace_rejects_garbage():
    with pytest.raises(ValueError):
        validate_chrome_trace({"events": []})
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": [{"ph": "X"}]})
    with pytest.raises(ValueError):        # X span without dur
        validate_chrome_trace({"traceEvents": [
            {"ph": "X", "pid": 1, "tid": 0, "name": "p", "ts": 0}]})
    with pytest.raises(ValueError):        # negative ts
        validate_chrome_trace({"traceEvents": [
            {"ph": "i", "pid": 1, "tid": 0, "name": "p", "ts": -1}]})


def test_prometheus_exposition(tmp_path):
    tel = Telemetry()
    cfg, backend, eng, reqs = _preempt_engine(telemetry=tel)
    path = tmp_path / "metrics.prom"
    tel.write_prometheus(str(path))
    samples = parse_prometheus(path.read_text())
    by = {}
    for name, labels, value in samples:
        by.setdefault(name, []).append((labels, value))
    # counters agree with ground truth
    assert by["repro_serving_tokens_total"][0][1] == sum(
        r.n_generated for r in eng.finished)
    assert by["repro_serving_steps_total"][0][1] == eng.stats["steps"]
    ev = {lab["kind"]: v
          for lab, v in by["repro_serving_spill_events_total"]}
    assert ev["preempt"] == eng.stats["evictions"]
    assert ev["restore"] == eng.stats["restores"]
    codes = {lab["code"]
             for lab, _ in by["repro_serving_scheduler_decisions_total"]}
    assert "admit" in codes and "evict_priority" in codes
    assert codes <= set(REASON_CODES)      # every code has a glossary row
    # ledger families round-trip exactly through repr()
    led = tel.ledger.totals()
    sim_e = {lab["domain"]: v
             for lab, v in by["repro_serving_sim_energy_joules_total"]}
    for dom, e in led["sim_energy_split_j"].items():
        assert sim_e[dom] == e             # bitwise via repr round-trip
    assert by["repro_serving_sim_seconds_total"][0][1] \
        == led["sim_total_s"]
    # endurance watermarks exported as gauges
    assert "repro_serving_endurance" in by


def test_parse_prometheus_rejects_garbage():
    with pytest.raises(ValueError):        # sample without # TYPE
        parse_prometheus("foo_total 3\n")
    with pytest.raises(ValueError):        # malformed sample line
        parse_prometheus("# TYPE foo counter\nfoo{ 3\n")
    with pytest.raises(ValueError):        # malformed label pair
        parse_prometheus('# TYPE foo counter\nfoo{bar=3} 1\n')
    ok = parse_prometheus('# TYPE foo counter\nfoo{a="b"} 2.5\n')
    assert ok == [("foo", {"a": "b"}, 2.5)]


# ---------------------------------------------------------------------------
# decision codes
# ---------------------------------------------------------------------------
def test_decision_codes_preemption():
    tel = Telemetry()
    _preempt_engine(telemetry=tel)
    dc = tel.decision_counts
    assert dc["admit"] == 3
    assert dc["evict_priority"] == 1
    assert dc["restore"] >= 1
    # the decision log carries rid + context args
    evict = [d for d in tel.decisions if d["code"] == "evict_priority"]
    assert evict and "rid" in evict[0] and "waiter_priority" in evict[0]


def test_decision_codes_denials():
    """A DRAM budget of one resident with two waiting requests logs
    deny_dram_budget for the blocked queue head."""
    cfg, model, params = build_model(ARCH)
    backend = LocalBackend(model, params, 2, 24)
    hot_b, cold_b = backend.slot_kv_bytes()
    sched = FCFSScheduler(CapacityBudget(1 * hot_b, 1e15), hot_b, cold_b,
                          oversubscribe=1.0)
    tel = Telemetry()
    eng = Engine(backend, scheduler=sched, telemetry=tel)
    eng.run(make_requests(cfg, [(8, 6), (8, 6)], seed=4))
    assert tel.decision_counts["deny_dram_budget"] >= 1
    assert tel.decision_counts["admit"] == 2   # second admits post-drain
    assert set(tel.decision_counts) <= set(REASON_CODES)


# ---------------------------------------------------------------------------
# disabled telemetry: the no-op contract
# ---------------------------------------------------------------------------
def test_null_telemetry_default_and_token_parity():
    """Engine without telemetry installs NullTelemetry, and enabling
    telemetry must not perturb a single emitted token."""
    cfg, model, params = build_model(ARCH)
    specs = [(10, 6), (8, 5)]
    backend = LocalBackend(model, params, 2, 24)
    eng_off = Engine(backend)
    assert isinstance(eng_off.telemetry, NullTelemetry)
    assert eng_off.telemetry.enabled is False
    eng_off.run(make_requests(cfg, specs, seed=5))

    eng_on = Engine(LocalBackend(model, params, 2, 24),
                    telemetry=Telemetry())
    eng_on.run(make_requests(cfg, specs, seed=5))
    for a, b in zip(eng_off.finished, eng_on.finished):
        assert a.generated == b.generated
    # the null hooks are callable with the full signature set and
    # return nothing — the engine never branches on enablement for them
    null = NullTelemetry()
    null.bind(cfg=cfg)
    null.step_begin(0)
    null.phase_begin("plan")
    null.phase_end(count=0)
    null.decision("admit", rid=1)
    null.step_end({})
    assert null.snapshot() == {}
    assert null.ledger is None


def test_null_telemetry_overhead_budget():
    """The disabled hot path is ~15 no-op calls per engine step. Bound
    their cost directly (a stable proxy for the <2% throughput
    contract, which a wall-clock A/B on millisecond CPU steps could
    never assert without flaking): 10k simulated steps of hook traffic
    must cost well under the time of ONE jitted decode step (~1ms)."""
    import time as _time
    null = NullTelemetry()
    req = _bare_request()
    t0 = _time.perf_counter()
    for step in range(10_000):
        null.step_begin(step)
        null.phase_begin("plan")
        null.phase_end(chunks=0)
        null.phase_begin("chunk-prefill")
        null.phase_end()
        null.phase_begin("decode")
        null.token(req)
        null.phase_end(count=1)
        null.decision("admit", rid=0)
        null.step_end(None)
    per_step = (_time.perf_counter() - t0) / 10_000
    assert per_step < 20e-6, f"null hooks cost {per_step * 1e6:.1f}us/step"


# ---------------------------------------------------------------------------
# snapshots
# ---------------------------------------------------------------------------
def test_snapshot_jsonl_stream(tmp_path):
    path = tmp_path / "snaps.jsonl"
    tel = Telemetry(stats_every=3, snapshot_path=str(path))
    cfg, backend, eng, _ = _preempt_engine(telemetry=tel)
    tel.close()
    snaps = [json.loads(l) for l in path.read_text().splitlines()]
    assert len(snaps) == len(tel.snapshots) >= 2
    for s in snaps:
        assert {"step", "counters", "decisions", "ledger",
                "endurance"} <= set(s)
    # cumulative and monotone
    toks = [s["counters"]["tokens"] for s in snaps]
    assert toks == sorted(toks)
    assert snaps[-1]["endurance"]["write_once_ok"]


# ---------------------------------------------------------------------------
# metrics edge cases (the garbage this PR fixed)
# ---------------------------------------------------------------------------
def test_aggregate_metrics_empty():
    m = aggregate_metrics([], 1.0)
    assert m == {"requests": 0, "total_tokens": 0, "tok_per_s": 0.0}


def _bare_request(**kw):
    return Request(rid=kw.pop("rid", 0),
                   tokens=np.zeros(4, np.int32),
                   max_new_tokens=kw.pop("max_new_tokens", 4), **kw)


def test_request_metrics_never_ran():
    """A request that never got a slot has NO ttft/latency/queue keys
    (they used to be computed off the 0.0 defaults: negative garbage)."""
    req = _bare_request()
    req.arrival_s = 5.0
    m = request_metrics(req)
    assert m["finished"] is False
    for absent in ("ttft_s", "latency_s", "queue_s", "tbt_p95_s"):
        assert absent not in m
    assert m["n_generated"] == 0


def test_request_metrics_partial_and_unrestored():
    req = _bare_request()
    req.arrival_s = 1.0
    req.admit_s = 1.5
    req.first_token_s = 2.0
    req.generated = [7, 7]
    req.evict_times = [2.5]            # evicted, never restored,
    req.evict_ctx = [6]                # never finished
    m = request_metrics(req)
    assert m["queue_s"] == pytest.approx(0.5)
    assert m["ttft_s"] == pytest.approx(1.0)
    assert "latency_s" not in m and "spilled_s" not in m
    assert m["unrestored_evictions"] == 1
    assert m["finished"] is False


def test_aggregate_metrics_mixed_population():
    """Zero-token and unfinished requests are excluded from the TTFT /
    latency pools and surfaced as counts instead."""
    ok = _bare_request(rid=0)
    ok.arrival_s, ok.first_token_s, ok.finish_s = 1.0, 2.0, 3.0
    ok.generated = [1, 2]
    ok.token_times = [2.0, 2.5]
    never = _bare_request(rid=1)
    never.arrival_s = 1.0              # no token, no finish
    part = _bare_request(rid=2)
    part.arrival_s, part.first_token_s = 1.0, 4.0
    part.generated = [3]
    part.token_times = [4.0]
    part.evict_times = [4.5]
    part.evict_ctx = [5]
    m = aggregate_metrics([ok, never, part], wall_s=5.0)
    assert m["requests"] == 3
    assert m["no_token_requests"] == 1
    assert m["unfinished_requests"] == 2
    assert m["unrestored_evictions"] == 1
    assert m["mean_ttft_s"] == pytest.approx(2.0)   # (1.0 + 3.0) / 2
    assert m["mean_latency_s"] == pytest.approx(2.0)  # only `ok`
    assert m["total_tokens"] == 3


def test_simulated_efficiency_zero_generation_and_unpaired_spill():
    """simulated_efficiency tolerates zero-token requests (skipped) but
    still prices recorded spill traffic for them."""
    cfg, _, _ = build_model(ARCH)
    req = _bare_request()
    sim0 = simulated_efficiency(cfg, [req])
    assert sim0["sim_energy_j"] == 0.0 and sim0["sim_tokens_per_j"] == 0.0
    req.evict_ctx = [6]
    sim1 = simulated_efficiency(cfg, [req])
    assert sim1["sim_spills"] == 1
    assert sim1["sim_energy_j"] == sim1["sim_spill_energy_j"] > 0.0
