"""Preemptive serving under oversubscription (PR 4).

The load-bearing property: a preempted request — its KV slot evicted
into an RRAM spill lane mid-decode and later restored into a (possibly
different) slot — produces EXACTLY the same tokens as an uninterrupted
run and as the single-request `generate` oracle, on GQA, MLA(+MoE),
RWKV6 and hybrid-Mamba2 architectures, on both the local vmapped and the
pjit-sharded backend, with whole-prompt and chunked prefill. Plus: the
differential oracle over mixed text/VQA streams, oversubscribed
admission, the endurance accounting of evict/restore cycles
(spill-lane counters advance exactly per `expected_spill_block_writes`,
slot counters stay exactly per `expected_block_writes`), preemption
metrics, and the n_spill=0 degraded mode.

Run with XLA_FLAGS=--xla_force_host_platform_device_count=8 to exercise
the sharded cases on a real multi-device mesh (the CI multi-device job
does).
"""

import jax
import numpy as np
import pytest
from conftest import build_model, generated, make_mesh, make_requests, \
    oracle_tokens

from repro.core import kv_tiers as KT
from repro.serving import (CapacityBudget, Engine, FCFSScheduler,
                           LocalBackend, ShardedBackend,
                           aggregate_metrics, make_synthetic_requests,
                           request_metrics, simulated_efficiency)

jax.config.update("jax_platform_name", "cpu")


# prompts sized so the victim is mid-decode when the intruder lands and
# still has tokens left after its restore; recurrent archs use
# grid-aligned chunk caps (cfg.ssm.chunk_size)
CASES = {
    "granite-3-2b": dict(low=[(12, 10), (12, 10)], high=(8, 4),
                         max_len=32, chunk=5),
    "deepseek-v2-lite": dict(low=[(12, 8), (12, 8)], high=(8, 4),
                             max_len=24, chunk=5),
    "rwkv6-7b": dict(low=[(40, 8), (40, 8)], high=(32, 4),
                     max_len=48, chunk=32),
    "zamba2-1.2b": dict(low=[(24, 8), (24, 8)], high=(16, 4),
                        max_len=48, chunk=16),
}

_oracle_memo: dict = {}


def _oracle(arch, model, params, req):
    key = (arch, req.rid)
    if key not in _oracle_memo:
        _oracle_memo[key] = oracle_tokens(model, params, req)
    return _oracle_memo[key]


def _case_requests(cfg, arch):
    """The case's stream: two priority-0 victims then one priority-1
    intruder (deterministic per arch, shared by every backend/chunking
    variant so the oracle memoizes)."""
    case = CASES[arch]
    reqs = make_requests(cfg, case["low"] + [case["high"]], seed=3,
                         priorities=[0, 0, 1])
    return reqs[:-1], reqs[-1]


def _run_preempted(backend, low, high, chunk_tokens):
    """Drive the engine into a forced preemption: a DRAM budget of
    exactly two residents, both slots decoding low-priority work when
    the priority-1 intruder arrives."""
    hot_b, cold_b = backend.slot_kv_bytes()
    sched = FCFSScheduler(CapacityBudget(2 * hot_b, 1e15), hot_b, cold_b,
                          oversubscribe=1.0)
    eng = Engine(backend, scheduler=sched, chunk_tokens=chunk_tokens)
    for r in low:
        eng.submit(r)
    guard = 0
    while not (eng.pool.active_slots == 2 and eng._inflight is None):
        eng.step()
        guard += 1
        assert guard < 60, "victims never reached steady decode"
    eng.step()                        # give the victim decode context
    eng.submit(high)
    eng.run(max_steps=400)
    assert eng.stats["evictions"] >= 1, eng.stats
    assert eng.stats["restores"] == eng.stats["evictions"]
    assert len(eng.finished) == len(low) + 1
    victims = [r for r in low + [high] if r.n_evictions]
    assert victims and all(r.priority == 0 for r in victims)
    return eng


@pytest.mark.parametrize("backend_kind", ["local", "sharded"])
@pytest.mark.parametrize("arch", list(CASES))
def test_preempted_restore_token_parity(arch, backend_kind):
    """Acceptance: preempted-then-restored == uninterrupted == oracle,
    whole-prompt AND chunked prefill, on both backends."""
    case = CASES[arch]
    cfg, model, params = build_model(arch)
    if backend_kind == "sharded":
        backend = ShardedBackend(model, params, 2, case["max_len"],
                                 mesh=make_mesh())
    else:
        backend = LocalBackend(model, params, 2, case["max_len"])
    for chunk in (0, case["chunk"]):          # whole-prompt and chunked
        low, high = _case_requests(cfg, arch)
        eng = _run_preempted(backend, low, high, chunk)
        for r in low + [high]:
            assert r.generated == _oracle(arch, model, params, r), (
                f"{arch}/{backend_kind}/chunk={chunk}: rid {r.rid} "
                f"diverged after preemption")
        if cfg.kv_policy == "tiered":
            assert eng.endurance_report()["write_once_ok"]


# ---------------------------------------------------------------------------
# differential oracle: random mixed text/VQA streams == sequential
# per-request generate(), including runs that force evictions
# ---------------------------------------------------------------------------
def test_oracle_mixed_vqa_stream_with_evictions():
    cfg, model, params = build_model("mobilevlm-1.7b", hot_window=16)
    backend = LocalBackend(model, params, 2, 40)
    hot_b, cold_b = backend.slot_kv_bytes()
    low = make_synthetic_requests(cfg, 3, prompt_len=20, gen_len=8,
                                  seed=2, image_every=2)
    (high,) = make_synthetic_requests(cfg, 1, prompt_len=12, gen_len=3,
                                      seed=7)
    high.rid, high.priority = 3, 1
    sched = FCFSScheduler(CapacityBudget(2 * hot_b, 1e15), hot_b, cold_b,
                          oversubscribe=1.0)
    eng = Engine(backend, scheduler=sched)
    for r in low:
        eng.submit(r)
    guard = 0
    while eng.pool.active_slots < 2 or eng._inflight is not None:
        eng.step()
        guard += 1
        assert guard < 60
    eng.submit(high)
    done = eng.run(max_steps=400)
    assert eng.stats["evictions"] >= 1
    assert len(done) == 4
    for r in low + [high]:
        assert r.generated == oracle_tokens(model, params, r), r.rid
    assert eng.endurance_report()["write_once_ok"]


def test_oracle_random_stream_oversubscribed():
    """Oversubscription is a pure admission relaxation: a random stream
    served at 2x the DRAM budget still matches the sequential oracle
    token-for-token, at genuinely higher concurrency."""
    cfg, model, params = build_model()
    backend = LocalBackend(model, params, 4, 24)
    hot_b, cold_b = backend.slot_kv_bytes()
    budget = CapacityBudget(2 * hot_b, 1e15)
    specs = [(8, 6), (13, 6), (16, 4), (8, 8), (11, 5), (16, 6)]

    def run(over):
        sched = FCFSScheduler(budget, hot_b, cold_b, oversubscribe=over,
                              spill_lanes=4)
        eng = Engine(backend, scheduler=sched)
        reqs = make_requests(cfg, specs, seed=11)
        peak = 0
        for r in reqs:
            eng.submit(r)
        while not eng.idle:
            eng.step()
            peak = max(peak, eng.pool.active_slots)
        return generated(eng.finished), peak

    blocked, peak_b = run(1.0)
    oversub, peak_o = run(2.0)
    assert peak_b == 2 and peak_o == 4
    assert blocked == oversub
    oracle = [oracle_tokens(model, params, r)
              for r in make_requests(cfg, specs, seed=11)]
    assert oversub == oracle


# ---------------------------------------------------------------------------
# endurance accounting of evict/restore cycles
# ---------------------------------------------------------------------------
def test_evict_restore_endurance_accounting_exact():
    """Two evict/restore cycles of one long-lived request: the spill
    lane's RRAM counters advance exactly per expected_spill_block_writes
    (one write per touched block per spill), the victim's SLOT counters
    stay exactly per expected_block_writes (the restore is verbatim —
    no phantom cold writes), and the report reflects the spills."""
    cfg, model, params = build_model(hot_window=4)
    backend = LocalBackend(model, params, 2, 64)
    hot_b, cold_b = backend.slot_kv_bytes()
    sched = FCFSScheduler(CapacityBudget(2 * hot_b, 1e15), hot_b, cold_b,
                          oversubscribe=1.0)
    eng = Engine(backend, scheduler=sched)
    victim, partner = make_requests(cfg, [(8, 30), (8, 30)], seed=5)
    eng.submit(victim)
    eng.submit(partner)
    eng.step()                       # both decoding
    eng.step()
    intruders = make_requests(cfg, [(8, 3), (8, 3)], seed=6,
                              priorities=[1, 1])
    for k, intr in enumerate(intruders):
        intr.rid = 10 + k
        eng.submit(intr)
        guard = 0                    # drain the intruder, forcing one
        while intr.status != "finished":     # evict+restore cycle
            eng.step()
            guard += 1
            assert guard < 100
        for _ in range(2):
            eng.step()
    eng.run(max_steps=400)
    assert eng.stats["evictions"] == 2 and eng.stats["restores"] == 2
    evicted = victim if victim.n_evictions else partner
    assert evicted.n_evictions == 2

    sw = np.asarray(eng.pool.state.spill_writes)
    nb = sw.shape[1]
    # both cycles recycled the same (lowest-index) freed lane
    expected_lane = np.asarray(KT.expected_spill_block_writes(
        nb, evicted.evict_ctx))
    np.testing.assert_array_equal(sw.sum(axis=0), expected_lane)
    assert int(sw.sum()) == sum(
        (ctx + KT.ENDURANCE_BLOCK - 1) // KT.ENDURANCE_BLOCK
        for ctx in evicted.evict_ctx)

    # slot counters: every occupant's cold writes are exactly the
    # analytic expectation — evict/restore cycles added none
    worst = np.asarray(eng.pool.worst_case_writes())
    for slot in range(2):
        p = eng._slot_prefill_len[slot]
        t = eng._slot_total_len[slot]
        np.testing.assert_array_equal(
            worst[slot], np.asarray(KT.expected_block_writes(
                worst.shape[1], backend.hot_window, p, t)))
    rep = eng.endurance_report()
    assert rep["write_once_ok"]
    assert rep["spills"] == 2 and rep["restores"] == 2
    assert rep["total_spill_writes"] == int(sw.sum())
    assert rep["spill_lanes"] == 2


def test_spill_block_writes_unit():
    nb = KT.n_endurance_blocks(512)
    assert nb == 4
    np.testing.assert_array_equal(
        np.asarray(KT.spill_block_writes(nb, 0)), [0, 0, 0, 0])
    np.testing.assert_array_equal(
        np.asarray(KT.spill_block_writes(nb, 1)), [1, 0, 0, 0])
    np.testing.assert_array_equal(
        np.asarray(KT.spill_block_writes(nb, 128)), [1, 0, 0, 0])
    np.testing.assert_array_equal(
        np.asarray(KT.spill_block_writes(nb, 129)), [1, 1, 0, 0])
    np.testing.assert_array_equal(
        np.asarray(KT.expected_spill_block_writes(nb, [129, 300, 512])),
        [3, 3, 2, 1])


# ---------------------------------------------------------------------------
# metrics + degraded modes
# ---------------------------------------------------------------------------
def test_preemption_metrics_and_sim_spill_energy():
    cfg, model, params = build_model()
    backend = LocalBackend(model, params, 2, 32)
    hot_b, cold_b = backend.slot_kv_bytes()
    sched = FCFSScheduler(CapacityBudget(2 * hot_b, 1e15), hot_b, cold_b,
                          oversubscribe=1.0)
    eng = Engine(backend, scheduler=sched)
    low, high = _case_requests(cfg, "granite-3-2b")
    for r in low:
        eng.submit(r)
    eng.step()
    eng.step()
    eng.submit(high)
    done = eng.run(max_steps=300)
    m = aggregate_metrics(done, wall_s=1.0)
    assert m["preemptions"] >= 1 and m["restores"] == m["preemptions"]
    assert m["restore_latency_p95_s"] >= m["restore_latency_p50_s"] >= 0
    victim = next(r for r in done if r.n_evictions)
    rm = request_metrics(victim)
    assert rm["preemptions"] == victim.n_evictions
    assert rm["spilled_s"] > 0
    sim = simulated_efficiency(cfg, done)
    assert sim["sim_spills"] == eng.stats["evictions"]
    assert sim["sim_spill_energy_j"] > 0
    assert sim["sim_energy_j"] > sim["sim_spill_energy_j"]


def test_spill_buffers_materialize_lazily():
    """Reserved lanes cost nothing until the first eviction: the pool's
    spill tree is None at construction (no doubled KV memory for
    engines that never preempt) and materializes on evict_slot."""
    cfg, model, params = build_model()
    backend = LocalBackend(model, params, 2, 24)
    pool = backend.make_pool()
    assert backend.n_spill == 2 and pool.num_spill_lanes == 2
    assert pool.state.spill is None and pool.state.spill_writes is None
    with pytest.raises(ValueError, match="nothing has been spilled"):
        backend.restore_slot(pool.state, 0, 0)
    st = backend.evict_slot(pool.state, 0, 0, 4)
    assert st.spill is not None and st.num_spill_lanes == 2
    assert int(np.asarray(st.spill_writes).sum()) == 1


def test_no_spill_lanes_disables_preemption():
    """n_spill=0: the pool has no spill buffers, evict_slot refuses, and
    the scheduler simply keeps the intruder queued (no preemption, no
    crash) until a slot frees — strict PR 3 behavior."""
    cfg, model, params = build_model()
    backend = LocalBackend(model, params, 2, 32, n_spill=0)
    assert backend.n_spill == 0
    pool = backend.make_pool()
    assert pool.num_spill_lanes == 0 and pool.state.spill is None
    with pytest.raises(ValueError, match="n_spill=0"):
        backend.evict_slot(pool.state, 0, 0, 4)
    hot_b, cold_b = backend.slot_kv_bytes()
    sched = FCFSScheduler(CapacityBudget(2 * hot_b, 1e15), hot_b, cold_b,
                          oversubscribe=1.0)
    eng = Engine(backend, scheduler=sched)
    low, high = _case_requests(cfg, "granite-3-2b")
    for r in low:
        eng.submit(r)
    eng.step()
    eng.submit(high)
    done = eng.run(max_steps=300)
    assert eng.stats["evictions"] == 0
    assert len(done) == 3
    for r in low + [high]:
        assert r.generated == _oracle("granite-3-2b", model, params, r)


# ---------------------------------------------------------------------------
# scheduler preemption policy (host-only, no model)
# ---------------------------------------------------------------------------
def _req(rid, plen=8, gen=4, prio=0):
    from repro.serving import Request
    return Request(rid=rid, tokens=np.zeros(plen, np.int32),
                   max_new_tokens=gen, priority=prio)


def _sched(dram_slots=2, **kw):
    kw.setdefault("oversubscribe", 1.0)
    kw.setdefault("spill_lanes", 2)
    return FCFSScheduler(CapacityBudget(100 * dram_slots, 1e9),
                         hot_bytes_per_slot=100, cold_bytes_per_slot=10,
                         **kw)


def test_plan_evicts_lowest_priority_latest_admitted():
    sched = _sched(dram_slots=3)
    running = [_req(0, prio=0), _req(1, prio=0), _req(2, prio=1)]
    for i, r in enumerate(running):
        r.admit_seq = i
    sched.submit(_req(9, prio=2))
    plan = sched.plan(active_slots=3, decode_slots=3, free_slots=0,
                      inflight=None, running=tuple(running), free_lanes=2)
    assert [r.rid for r in plan.evictions] == [1]   # prio 0, latest
    assert sched.spilled == 1
    # the freed slot goes to the prio-2 head in the same plan
    assert [(c.req.rid, c.admit) for c in plan.chunks] == [(9, True)]


def test_plan_never_evicts_for_equal_priority():
    sched = _sched()
    running = [_req(0, prio=1), _req(1, prio=1)]
    for i, r in enumerate(running):
        r.admit_seq = i
    sched.submit(_req(9, prio=1))
    plan = sched.plan(active_slots=2, decode_slots=2, free_slots=0,
                      inflight=None, running=tuple(running), free_lanes=2)
    assert plan.evictions == () and plan.chunks == ()
    assert sched.pending == 1


def test_plan_never_evicts_without_free_lane_or_inflight_waiter():
    sched = _sched()
    running = [_req(0, prio=0), _req(1, prio=0)]
    for i, r in enumerate(running):
        r.admit_seq = i
    sched.submit(_req(9, prio=2))
    # no lane -> no eviction
    plan = sched.plan(active_slots=2, decode_slots=2, free_slots=0,
                      inflight=None, running=tuple(running), free_lanes=0)
    assert plan.evictions == ()
    # an in-flight prefill means the head is not the next admission;
    # nothing outranks the runners on the spilled side either
    other = _req(7, plen=16)
    plan = sched.plan(active_slots=2, decode_slots=1, free_slots=0,
                      inflight=(other, 8), running=tuple(running),
                      free_lanes=2)
    assert plan.evictions == ()


def test_restore_yields_to_strictly_higher_priority_head():
    """Anti-thrash: a spilled prio-0 request must not grab the free slot
    a queued prio-1 head is about to take (it would be evicted right
    back); at equal priority the spilled request resumes FIRST (it was
    admitted earlier — FCFS)."""
    sched = _sched(dram_slots=3)
    running = [_req(0, prio=0), _req(1, prio=0)]
    for i, r in enumerate(running):
        r.admit_seq = i
    sched.submit(_req(9, prio=2))
    plan = sched.plan(active_slots=2, decode_slots=2, free_slots=0,
                      inflight=None, running=tuple(running), free_lanes=2)
    assert [r.rid for r in plan.evictions] == [1]
    # slot frees while a prio-1 head waits: the head wins, rid 1 stays
    # spilled
    sched.submit(_req(10, prio=1))
    plan2 = sched.plan(active_slots=1, decode_slots=1, free_slots=2,
                       inflight=None, running=(running[0],), free_lanes=1)
    assert plan2.restores == ()
    assert plan2.chunks[0].req.rid == 10
    # equal priority: the spilled request resumes before a new admission
    sched.submit(_req(11, prio=0))
    plan3 = sched.plan(active_slots=2, decode_slots=2, free_slots=1,
                       inflight=None, running=(running[0],), free_lanes=1)
    assert [r.rid for r in plan3.restores] == [1]
    assert plan3.chunks == ()                    # no slot left for rid 11


def test_no_eviction_when_waiter_cannot_be_admitted_after_it():
    """Anti-livelock: a high-priority waiter whose cold tier cannot fit
    in RRAM alongside the parked spill image must NOT trigger an
    eviction — the victim would be stranded and the plan empty forever."""
    # rram 150: holds 2 resident cold tiers (80) but not waiter cold
    # (40) + one parked image (140)
    budget = CapacityBudget(dram_bytes=200, rram_bytes=150)
    sched = FCFSScheduler(budget, 100, 40, oversubscribe=1.0,
                          spill_lanes=2)
    running = [_req(0), _req(1)]
    for i, r in enumerate(running):
        r.admit_seq = i
    sched.submit(_req(9, prio=2))
    plan = sched.plan(active_slots=2, decode_slots=2, free_slots=0,
                      inflight=None, running=tuple(running), free_lanes=2)
    assert plan.evictions == () and plan.chunks == ()
    assert sched.spilled == 0


def test_restore_proceeds_when_higher_priority_head_is_byte_blocked():
    """Anti-livelock: a byte-blocked higher-priority head must not hold
    a free slot hostage — the spilled request restores (which also frees
    the RRAM image the head is waiting on)."""
    budget = CapacityBudget(dram_bytes=200, rram_bytes=150)
    sched = FCFSScheduler(budget, 100, 40, oversubscribe=1.0,
                          spill_lanes=2)
    victim = _req(0)
    victim.admit_seq = 0
    sched._spill_insert(victim)
    sched.submit(_req(9, prio=2))
    # head outranks but cold(40) + parked image(140) > 150: restore wins
    plan = sched.plan(active_slots=0, decode_slots=0, free_slots=2,
                      inflight=None, running=(), free_lanes=1)
    assert [r.rid for r in plan.restores] == [0]


def test_eviction_fires_when_byte_blocked_with_free_slots():
    """The preemption trigger is 'the waiter cannot get in', not
    'no free slot': with 4 slots but a 2-resident DRAM budget, a
    priority-1 waiter evicts a priority-0 victim even though slots are
    free — the victim's hot bytes are what it needs."""
    budget = CapacityBudget(dram_bytes=200, rram_bytes=1e9)
    sched = FCFSScheduler(budget, 100, 40, oversubscribe=1.0,
                          spill_lanes=2)
    running = [_req(0), _req(1)]
    for i, r in enumerate(running):
        r.admit_seq = i
    sched.submit(_req(9, prio=1))
    plan = sched.plan(active_slots=2, decode_slots=2, free_slots=2,
                      inflight=None, running=tuple(running), free_lanes=2)
    assert [r.rid for r in plan.evictions] == [1]
    assert [(c.req.rid, c.admit) for c in plan.chunks] == [(9, True)]


def test_no_livelock_when_rram_cannot_hold_spill_plus_waiter():
    """Engine-level regression of the scheduler livelock: with an RRAM
    budget that fits both residents' cold tiers but not a spill image
    alongside the intruder, the run must drain normally (no eviction,
    intruder served after a victim finishes) instead of spinning."""
    cfg, model, params = build_model()
    backend = LocalBackend(model, params, 2, 32)
    hot_b, cold_b = backend.slot_kv_bytes()
    budget = CapacityBudget(2 * hot_b, 2 * cold_b + hot_b // 2)
    sched = FCFSScheduler(budget, hot_b, cold_b, oversubscribe=1.0)
    eng = Engine(backend, scheduler=sched)
    low, high = _case_requests(cfg, "granite-3-2b")
    for r in low:
        eng.submit(r)
    eng.step()
    eng.step()
    eng.submit(high)
    done = eng.run(max_steps=300)
    assert len(done) == 3 and eng.stats["evictions"] == 0
    for r in low + [high]:
        assert r.generated == _oracle("granite-3-2b", model, params, r)


def test_oversubscription_requires_spill_lane_backing():
    """Residents beyond the base DRAM capacity must be coverable by free
    spill lanes: with lanes they admit, without lanes the gate holds."""
    budget = CapacityBudget(100 * 2, 1e9)
    backed = FCFSScheduler(budget, 100, 10, oversubscribe=2.0,
                           spill_lanes=2)
    bare = FCFSScheduler(budget, 100, 10, oversubscribe=2.0,
                         spill_lanes=0)
    for s in (backed, bare):
        for i in range(4):
            s.submit(_req(i))
    p1 = backed.plan(active_slots=0, decode_slots=0, free_slots=4,
                     inflight=None)
    assert len([c for c in p1.chunks if c.admit]) == 4
    p2 = bare.plan(active_slots=0, decode_slots=0, free_slots=4,
                   inflight=None)
    assert len([c for c in p2.chunks if c.admit]) == 2


def test_fcfs_within_priority_class_admission_order():
    sched = _sched(dram_slots=8, spill_lanes=0)
    reqs = [_req(0, prio=0), _req(1, prio=1), _req(2, prio=0),
            _req(3, prio=1), _req(4, prio=2)]
    for r in reqs:
        sched.submit(r)
    plan = sched.plan(active_slots=0, decode_slots=0, free_slots=8,
                      inflight=None)
    order = [c.req.rid for c in plan.chunks if c.admit]
    assert order == [4, 1, 3, 0, 2]   # priority desc, FCFS within class


def test_pr3_era_custom_planner_still_plans(recwarn):
    """One-release compat: a custom plan() override with the PR-3
    signature (no running=/free_lanes=) must keep serving — the engine
    warns and plans without preemption instead of crashing."""
    import warnings as _w

    planned = []

    class OldSigScheduler(FCFSScheduler):
        def plan(self, *, active_slots, decode_slots, free_slots,
                 inflight, chunk_unit=1):
            planned.append(True)
            return super().plan(active_slots=active_slots,
                                decode_slots=decode_slots,
                                free_slots=free_slots, inflight=inflight,
                                chunk_unit=chunk_unit)

    cfg, model, params = build_model()
    backend = LocalBackend(model, params, 2, 24)
    hot_b, cold_b = backend.slot_kv_bytes()
    sched = OldSigScheduler(CapacityBudget(1e12, 1e12), hot_b, cold_b)
    with pytest.warns(DeprecationWarning, match="running=/free_lanes="):
        eng = Engine(backend, scheduler=sched)
    with _w.catch_warnings():
        _w.simplefilter("ignore", DeprecationWarning)
        done = eng.run(make_requests(cfg, [(8, 3), (8, 3)], seed=2),
                       max_steps=100)
    assert len(done) == 2 and planned
    assert eng.stats["evictions"] == 0


def test_pr3_era_custom_backend_without_n_spill():
    """A custom InferenceBackend written against the PR-2/3 protocol has
    no n_spill attribute: Engine degrades to preemption-disabled."""
    from repro.serving import TieredKVPool

    _, model, params = build_model()
    backend = LocalBackend(model, params, 2, 24)
    del backend.n_spill
    backend.make_pool = lambda: TieredKVPool(          # PR-3 pool wiring
        backend.init_pool(), backend._insert_state, backend.fresh_slot)
    eng = Engine(backend)
    assert eng.scheduler.spill_lanes == 0
    assert eng.pool.num_spill_lanes == 0


def test_engine_oversubscribe_env_knob(monkeypatch):
    monkeypatch.setenv("REPRO_SERVE_OVERSUBSCRIBE", "2")
    cfg, model, params = build_model()
    eng = Engine(LocalBackend(model, params, 2, 24))
    assert eng.scheduler.oversubscribe == 2.0
    # explicit 0 disables even under the env knob
    eng0 = Engine(LocalBackend(model, params, 2, 24), oversubscribe=0)
    assert eng0.scheduler.oversubscribe is None
    # a sub-1 ENV value warns and is ignored (an env var never wedges
    # startup); the same value as an explicit ARG is a hard error
    monkeypatch.setenv("REPRO_SERVE_OVERSUBSCRIBE", "0.5")
    with pytest.warns(UserWarning, match="OVERSUBSCRIBE"):
        eng = Engine(LocalBackend(model, params, 2, 24))
    assert eng.scheduler.oversubscribe is None
    with pytest.raises(ValueError, match="oversubscribe"):
        Engine(LocalBackend(model, params, 2, 24), oversubscribe=0.5)
    monkeypatch.setenv("REPRO_SERVE_OVERSUBSCRIBE", "nope")
    with pytest.warns(UserWarning, match="non-numeric"):
        eng = Engine(LocalBackend(model, params, 2, 24))
    assert eng.scheduler.oversubscribe is None
