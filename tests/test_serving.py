"""Serving subsystem: capacity-aware admission, slot recycling +
endurance-counter reset, engine-vs-generate token parity, KV pool
mechanics, backend API + compat shim, streaming + metrics.

Shared tiny-model / request-stream helpers live in tests/conftest.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import build_model as _model
from conftest import make_requests as _requests

from repro.configs.base import get_config
from repro.launch.serve import generate
from repro.models import Model
from repro.models.counting import kv_bytes_per_token
from repro.serving import (CapacityBudget, Engine, FCFSScheduler,
                           LocalBackend, aggregate_metrics,
                           make_synthetic_requests, simulated_efficiency,
                           slot_kv_bytes)

jax.config.update("jax_platform_name", "cpu")


def _engine(model, params, num_slots, max_len, **kw) -> Engine:
    return Engine(LocalBackend(model, params, num_slots, max_len), **kw)


# ---------------------------------------------------------------------------
# scheduler / capacity budgets
# ---------------------------------------------------------------------------
def test_capacity_budget_limits_concurrency():
    b = CapacityBudget(dram_bytes=1000, rram_bytes=10_000)
    assert b.max_concurrent(hot_bytes_per_slot=300,
                            cold_bytes_per_slot=100) == 3  # DRAM-bound
    assert b.max_concurrent(300, 4000) == 2                # RRAM-bound
    assert b.admits(1, 300, 100) and not b.admits(3, 300, 100)


def test_scheduler_is_fcfs_and_capacity_gated():
    b = CapacityBudget(dram_bytes=200, rram_bytes=200)
    sched = FCFSScheduler(b, hot_bytes_per_slot=100, cold_bytes_per_slot=50)
    r = _requests(get_config("granite-3-2b", reduced=True),
                  [(4, 2), (4, 2), (4, 2)])
    for q in r:
        sched.submit(q)
    # whole-prompt plans (no budget): admissions are FCFS and stop at the
    # DRAM byte budget (2 resident requests)
    plan = sched.plan(active_slots=0, decode_slots=0, free_slots=4,
                      inflight=None)
    assert [c.req.rid for c in plan.chunks] == [0, 1]
    assert all(c.admit and c.commit for c in plan.chunks)
    assert sched.pending == 1                # DRAM budget full at 2
    plan2 = sched.plan(active_slots=1, decode_slots=1, free_slots=3,
                       inflight=None)       # room again after a retire
    assert [c.req.rid for c in plan2.chunks] == [2]


def test_engine_admission_respects_byte_budgets():
    """Slots beyond the domain budgets stay idle: with a budget that fits
    exactly 2 resident requests, a 4-slot engine never runs more than 2."""
    cfg, model, params = _model()
    hot_b, cold_b = slot_kv_bytes(model, max_len=24)
    budget = CapacityBudget(dram_bytes=2 * hot_b, rram_bytes=2 * cold_b)
    # oversubscribe pinned to 1.0 and the weight charge off: this test
    # is about the STRICT KV-only gate (the CI coverage job force-
    # relaxes unset schedulers via REPRO_SERVE_OVERSUBSCRIBE, and the
    # weight-stream pass's REPRO_SERVE_WEIGHT_STREAM would otherwise
    # charge the weight working set against this synthetic KV budget)
    sched = FCFSScheduler(budget, hot_b, cold_b, oversubscribe=1.0)
    eng = _engine(model, params, 4, 24, scheduler=sched,
                  charge_weights=False)
    for r in _requests(cfg, [(8, 6)] * 5):
        eng.submit(r)
    peak = 0
    for _ in range(200):
        eng.step()
        peak = max(peak, eng.pool.active_slots)
        if not (eng.scheduler.pending or eng.pool.active_slots):
            break
    assert peak == 2
    assert len(eng.finished) == 5
    assert all(r.n_generated == 6 for r in eng.finished)


def test_engine_rejects_oversized_request():
    cfg, model, params = _model()
    eng = _engine(model, params, 2, 16)
    (req,) = _requests(cfg, [(12, 8)])       # 20 positions > 16
    with pytest.raises(ValueError):
        eng.submit(req)


# ---------------------------------------------------------------------------
# KV byte math: admission vs simulator single source of truth
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["granite-3-2b", "deepseek-v2-lite",
                                  "zamba2-1.2b", "rwkv6-7b"])
@pytest.mark.parametrize("kv_policy", ["tiered", "flat"])
def test_slot_kv_bytes_matches_cache_spec(arch, kv_policy):
    """slot_kv_bytes derives from counting.kv_elems_per_token; it must
    equal an exact byte walk of the real cache layout, or capacity
    admission and the simulator's cost terms have drifted."""
    cfg = get_config(arch, reduced=True).replace(
        param_dtype="float32", compute_dtype="float32",
        kv_policy=kv_policy, kv_hot_window=8)
    model = Model(cfg)
    max_len = 24
    shapes, _ = model.cache_spec(1, max_len)
    hot = cold = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if key == "writes":
            continue
        nbytes = jnp.dtype(leaf.dtype).itemsize
        for d in leaf.shape:
            nbytes *= d
        if key in ("cold_q", "cold_scale"):
            cold += nbytes
        else:
            hot += nbytes
    assert slot_kv_bytes(model, max_len) == (hot, cold)
    if kv_policy == "flat":
        # flat hot bytes = simulator per-token bytes x length + SSM state
        per_tok = kv_bytes_per_token(
            cfg, jnp.dtype(cfg.compute_dtype).itemsize)
        assert hot >= per_tok * max_len
        if arch in ("granite-3-2b", "deepseek-v2-lite"):
            assert hot == per_tok * max_len


# ---------------------------------------------------------------------------
# KV pool mechanics
# ---------------------------------------------------------------------------
def test_pool_insert_places_request_cache_in_slot():
    cfg, model, params = _model()
    pool = LocalBackend(model, params, 3, 24).make_pool()
    batch = {"tokens": jnp.arange(8, dtype=jnp.int32)[None]}
    _, req_cache = jax.jit(
        lambda p, b: model.prefill(p, b, 24))(params, batch)
    pool.insert(req_cache, 1)

    def slot_of(leaf, a):
        return jax.lax.dynamic_slice_in_dim(leaf, 1, 1, axis=a)

    got = jax.tree.map(slot_of, pool.cache, pool.axes)
    for g, want in zip(jax.tree.leaves(got), jax.tree.leaves(req_cache)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(want))


def test_pool_reset_restores_initial_slot_state():
    cfg, model, params = _model()
    pool = LocalBackend(model, params, 2, 24).make_pool()
    batch = {"tokens": jnp.arange(8, dtype=jnp.int32)[None]}
    _, req_cache = jax.jit(
        lambda p, b: model.prefill(p, b, 24))(params, batch)
    pool.insert(req_cache, 0)
    fresh = model.init_cache(1, 24)
    changed = any(
        not np.array_equal(
            np.asarray(jax.lax.dynamic_slice_in_dim(leaf, 0, 1, axis=a)),
            np.asarray(want))
        for leaf, a, want in zip(jax.tree.leaves(pool.cache),
                                 jax.tree.leaves(pool.axes),
                                 jax.tree.leaves(fresh)))
    assert changed
    pool.reset(0)
    for leaf, a, want in zip(jax.tree.leaves(pool.cache),
                             jax.tree.leaves(pool.axes),
                             jax.tree.leaves(fresh)):
        s = jax.lax.dynamic_slice_in_dim(leaf, 0, 1, axis=a)
        np.testing.assert_array_equal(np.asarray(s), np.asarray(want))


def test_slot_recycling_resets_endurance_counters():
    """Serve two requests sequentially through ONE slot: after recycling,
    the slot's endurance counters must equal what the SECOND occupancy
    alone would produce (writes<=1 per cold slot), not the sum."""
    cfg, model, params = _model(hot_window=4)
    eng = _engine(model, params, 1, 32)
    eng.run(_requests(cfg, [(8, 10), (8, 10)]))
    rep = eng.endurance_report()
    assert rep["tiered"] and rep["write_once_ok"]
    assert rep["max_writes_per_cold_slot"] <= 1.0
    # occupancy 2: 8-token prefill then 9 decode appends (10 generated
    # tokens, the last is never fed back); with W=4 evictions cover
    # positions [4, 13) -> 9 writes in block 0 — NOT 18, which is what a
    # recycle without counter reset would leave behind
    worst = np.asarray(eng.pool.worst_case_writes())
    assert worst[0, 0] == 9
    assert worst[0, 1:].sum() == 0


# ---------------------------------------------------------------------------
# engine vs single-request reference
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kv_policy", ["tiered", "flat"])
def test_engine_matches_generate_per_request(kv_policy):
    """Continuous batching must be a pure scheduling change: every
    request's tokens equal the single-request generate() path, including
    prompts that land in a padded admission bucket (13 -> 16)."""
    cfg, model, params = _model(kv_policy=kv_policy)
    specs = [(16, 8), (13, 8), (8, 6), (16, 4)]
    reqs = _requests(cfg, specs, seed=3)
    eng = _engine(model, params, 2, 24)
    eng.run(reqs, max_steps=200)
    for r, (p, g) in zip(reqs, specs):
        toks, _ = generate(model, params, {"tokens": r.tokens[None]}, p, g)
        assert r.generated == toks[0].tolist(), r.rid


def test_engine_matches_generate_mla():
    cfg, model, params = _model("deepseek-v2-lite")
    reqs = _requests(cfg, [(16, 6), (16, 6), (16, 6)], seed=5)
    eng = _engine(model, params, 2, 24)
    eng.run(reqs, max_steps=200)
    for r in reqs:
        toks, _ = generate(model, params, {"tokens": r.tokens[None]}, 16, 6)
        assert r.generated == toks[0].tolist(), r.rid


def test_engine_mixed_image_text_stream():
    cfg, model, params = _model("mobilevlm-1.7b", hot_window=16)
    reqs = make_synthetic_requests(cfg, 3, prompt_len=20, gen_len=4,
                                   seed=2, image_every=2)
    assert any(r.has_image for r in reqs) \
        and any(not r.has_image for r in reqs)
    eng = _engine(model, params, 2, 32)
    done = eng.run(reqs, max_steps=100)
    assert len(done) == 3
    assert all(r.n_generated == 4 for r in done)
    assert eng.endurance_report()["write_once_ok"]


def test_one_token_request_finishes_at_admission_with_event():
    """A request satisfied by its prefill token retires the moment the
    prompt commits (its slot is freed immediately), still streaming its
    (rid, token, done=True) event. Stepping until the first event keeps
    this robust under env-forced chunked prefill (multi-chunk prompts
    commit after several steps)."""
    cfg, model, params = _model()
    eng = _engine(model, params, 2, 16)
    eng.submit(_requests(cfg, [(8, 1)])[0])
    events = []
    for _ in range(8):
        events = eng.step()
        if events:
            break
    assert len(events) == 1
    rid, tok, done = events[0]
    assert rid == 0 and done
    assert eng.finished and eng.finished[0].generated == [tok]
    assert eng.pool.active_slots == 0


# ---------------------------------------------------------------------------
# backend API + compat shim
# ---------------------------------------------------------------------------
def test_engine_model_params_shim_removed():
    """The PR 3 Engine(model, params, num_slots=, max_len=) compat shim
    expired: positional model/params construction now fails loudly
    instead of silently building a backend."""
    cfg, model, params = _model()
    with pytest.raises(TypeError):
        Engine(model, params, num_slots=2, max_len=24)


def test_backend_rejects_encoder_and_zero_slots():
    cfg, model, params = _model()
    with pytest.raises(ValueError):
        LocalBackend(model, params, 0, 16)
    enc_cfg = get_config("hubert-xlarge", reduced=True)
    enc_model = Model(enc_cfg)
    with pytest.raises(ValueError):
        LocalBackend(enc_model, None, 1, 16)


# ---------------------------------------------------------------------------
# streaming + metrics
# ---------------------------------------------------------------------------
def test_streaming_order_and_metrics():
    cfg, model, params = _model()
    reqs = _requests(cfg, [(8, 5), (8, 5), (8, 5)], seed=9)
    events = []
    for r in reqs:
        r.on_token = lambda req, tok: events.append((req.rid, tok))
    eng = _engine(model, params, 2, 16)
    done = eng.run(reqs)
    # every request streamed exactly its generated tokens, in order
    for r in reqs:
        assert [t for rid, t in events if rid == r.rid] == r.generated
    m = aggregate_metrics(done, wall_s=1.0)
    assert m["requests"] == 3 and m["total_tokens"] == 15
    assert m["tok_per_s"] == pytest.approx(15.0)
    assert all(r.first_token_s <= r.finish_s for r in done)
    sim = simulated_efficiency(cfg, done)
    assert sim["sim_tokens_per_j"] > 0
    assert sim["sim_energy_j"] > 0
