"""Sharding resolver: unit + hypothesis property tests of the divisibility
fallback invariants."""

import jax
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.sharding import DEFAULT_RULES, ShardingRules

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def mesh():
    # abstract mesh over 1 real device is fine for spec resolution tests
    return jax.sharding.AbstractMesh((16, 16), ("data", "model"))


@pytest.fixture(scope="module")
def rules(mesh):
    return ShardingRules(mesh)


def test_divisible_dims_bind(rules):
    spec = rules.spec(("batch", None, "vocab"), (256, 4096, 49408))
    assert spec == P("data", None, "model")


def test_indivisible_dims_replicate(rules):
    # 36 heads % 16 != 0 -> replicate that dim
    spec = rules.spec(("batch", None, "heads", None), (256, 128, 36, 128))
    assert spec == P("data", None, None, None)


def test_axis_conflict_falls_through(rules):
    # batch takes 'data'; kv_seq_shard then takes 'model'; kv_heads (8)
    # can neither divide nor reuse 'model' -> replicated
    spec = rules.spec(("batch", "kv_seq_shard", "kv_heads", None),
                      (128, 32768, 8, 128))
    assert spec == P("data", "model", None, None)


def test_long_context_batch1_seq_shards(rules):
    # batch=1 can't shard -> kv_seq takes 'data', heads take 'model'
    spec = rules.spec(("batch", "kv_seq_shard", "kv_heads", None),
                      (1, 524288, 32, 64))
    assert spec == P(None, "data", "model", None)


def test_multipod_batch(mesh):
    mesh3 = jax.sharding.AbstractMesh((2, 16, 16),
                                      ("pod", "data", "model"))
    rules3 = ShardingRules(mesh3)
    spec = rules3.spec(("batch", None), (256, 4096))
    assert spec == P(("pod", "data"), None)


def test_fsdp_embed_binds_data(rules):
    spec = rules.spec(("fsdp_embed", "mlp"), (18432, 73728))
    assert spec == P("data", "model")


def test_unknown_logical_axis_raises(rules):
    with pytest.raises(KeyError):
        rules.spec(("nonsense",), (8,))


# ---------------------------------------------------------------------------
# property tests
# ---------------------------------------------------------------------------
LOGICAL = st.sampled_from(list(DEFAULT_RULES.keys()))
DIMS = st.integers(min_value=1, max_value=2 ** 20)


@settings(max_examples=200, deadline=None)
@given(st.lists(st.tuples(LOGICAL, DIMS), min_size=1, max_size=5))
def test_spec_resolution_total_and_divisible(entries):
    """For ANY combination of logical axes and dim sizes the resolver must
    (a) never raise, (b) only bind mesh axes whose product divides the dim,
    (c) never bind one mesh axis to two dims."""
    mesh = jax.sharding.AbstractMesh((16, 16), ("data", "model"))
    rules = ShardingRules(mesh)
    logical = tuple(e[0] for e in entries)
    shape = tuple(e[1] for e in entries)
    spec = rules.spec(logical, shape)
    used = []
    for dim, binding in zip(shape, tuple(spec)):
        if binding is None:
            continue
        axes = binding if isinstance(binding, tuple) else (binding,)
        prod = 1
        for a in axes:
            prod *= mesh.shape[a]
            used.append(a)
        assert dim % prod == 0, (logical, shape, spec)
    assert len(used) == len(set(used)), (logical, shape, spec)


@settings(max_examples=100, deadline=None)
@given(DIMS, DIMS)
def test_batch_vocab_consistency(b, v):
    mesh = jax.sharding.AbstractMesh((16, 16), ("data", "model"))
    rules = ShardingRules(mesh)
    spec = rules.spec(("batch", "vocab"), (b, v))
    if b % 16 == 0:
        assert tuple(spec)[0] == "data"
    if v % 16 == 0:
        assert tuple(spec)[1] == "model"
