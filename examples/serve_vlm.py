"""End-to-end VQA serving (the paper's workload), two ways:

1. the single-batch reference path (flat vs CHIME-tiered KV agreement +
   write-once endurance check), and
2. the continuous-batching engine serving a MIXED stream of image+text
   requests through a shared multi-request tiered KV pool — VQA requests
   carry visual patches, chat requests are text-only, and the scheduler
   admits them FCFS under the DRAM/RRAM byte budgets, and

3. chunked prefill on a long-vision-prompt mixed stream: a large VQA
   prompt streams into its pool slot in fixed-size chunks while
   already-running chat requests keep emitting tokens between chunks
   (the per-step trace prints the overlap), and

4. prefix sharing on the paged pool: many questions about ONE camera
   frame — every request opens with the same system prompt + image,
   later requests adopt the first one's cached block chain by reference
   and prefill only their question tail, token-identical to the
   unshared slot pool.

    PYTHONPATH=src python examples/serve_vlm.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core import kv_tiers as KT
from repro.launch.serve import generate
from repro.models import Model
from repro.serving import (Engine, LocalBackend, Request,
                           aggregate_metrics, make_synthetic_requests,
                           simulated_efficiency)


def make_cfg(kv_policy: str):
    return get_config("mobilevlm-1.7b", reduced=True).replace(
        param_dtype="float32", compute_dtype="float32", remat="none",
        kv_policy=kv_policy, kv_hot_window=16)


def run(kv_policy: str, batch_size: int = 4, prompt: int = 32,
        gen: int = 12):
    cfg = make_cfg(kv_policy)
    model = Model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    tv = cfg.frontend.num_tokens
    batch = {
        "patches": jax.random.normal(
            rng, (batch_size, tv, cfg.frontend.frontend_dim)),
        "tokens": jax.random.randint(
            rng, (batch_size, prompt - tv), 0, cfg.vocab_size),
    }
    t0 = time.time()
    toks, cache = generate(model, params, batch, prompt, gen)
    dt = time.time() - t0
    print(f"[{kv_policy:6s}] {batch_size} requests x {gen} tokens "
          f"in {dt:.2f}s; first answer ids: {toks[0, :8].tolist()}")
    return toks, cache


def serve_mixed_stream(n_requests: int = 8, concurrency: int = 4,
                       prompt: int = 24, gen: int = 10):
    """Continuous batching over a mixed image+text request stream."""
    cfg = make_cfg("tiered")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    backend = LocalBackend(model, params, num_slots=concurrency,
                           max_len=prompt + gen + 8)
    engine = Engine(backend)
    # every 2nd request is VQA (patches + text tail), the rest pure text,
    # with prompt-length jitter to exercise the admission buckets
    reqs = make_synthetic_requests(cfg, n_requests, prompt, gen, seed=7,
                                   image_every=2, jitter=4)
    streamed = []
    for r in reqs:
        r.on_token = lambda req, tok: streamed.append((req.rid, tok))
    t0 = time.time()
    done = engine.run(reqs)
    wall = time.time() - t0
    m = aggregate_metrics(done, wall)
    n_img = sum(1 for r in done if r.has_image)
    print(f"[engine] {m['requests']} requests ({n_img} VQA, "
          f"{m['requests'] - n_img} text) on {concurrency} slots: "
          f"{m['total_tokens']} tokens in {wall:.2f}s "
          f"({m['tok_per_s']:.1f} tok/s incl. compile, "
          f"mean ttft {m.get('mean_ttft_s', 0.0) * 1e3:.0f} ms)")
    rep = engine.endurance_report()
    print(f"[engine] endurance after recycling: max writes/cold-slot="
          f"{rep['max_writes_per_cold_slot']:.2f} "
          f"(write-once {'OK' if rep['write_once_ok'] else 'VIOLATED'})")
    sim = simulated_efficiency(cfg, done)
    print(f"[engine] simulated on {sim['platform']}: "
          f"{sim['sim_tokens_per_j']:.1f} tok/J")
    print(f"[engine] streamed {len(streamed)} token events; first 6: "
          f"{streamed[:6]}")


def serve_chunked_long_vqa(chunk_tokens: int = 8, gen: int = 12):
    """Chunked prefill keeping decode slots live: short chat requests are
    already decoding when a LONG VQA prompt (full visual span + text tail)
    arrives; with --chunk-tokens-style chunking the big prompt streams
    into its pool slot a few positions per step and the chat requests
    keep emitting tokens between chunks — the per-step trace below shows
    decode events flowing while the prefill is still in flight."""
    cfg = make_cfg("tiered")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tv = cfg.frontend.num_tokens
    long_prompt = tv + 16                       # visual span + text tail
    backend = LocalBackend(model, params, num_slots=3,
                           max_len=long_prompt + gen)
    engine = Engine(backend, chunk_tokens=chunk_tokens)
    rng = jax.random.PRNGKey(1)
    import numpy as np
    nrng = np.random.default_rng(5)
    chats = [Request(rid=i, tokens=nrng.integers(
        0, cfg.vocab_size, 6).astype(np.int32), max_new_tokens=gen)
        for i in range(2)]
    vqa = Request(
        rid=9, max_new_tokens=gen,
        tokens=nrng.integers(0, cfg.vocab_size, 16).astype(np.int32),
        patches=np.asarray(jax.random.normal(
            rng, (tv, cfg.frontend.frontend_dim)), np.float32))
    for r in chats:
        engine.submit(r)
    engine.step()                               # chats admitted + decoding
    engine.submit(vqa)                          # long prompt arrives
    overlap_steps = 0
    while not engine.idle:
        before = engine.stats["prefill_chunks"]
        events = engine.step()
        chunked = engine.stats["prefill_chunks"] > before
        decode_evs = [e for e in events if e[0] != vqa.rid]
        if chunked and decode_evs:
            overlap_steps += 1
        if chunked or decode_evs:
            print(f"[chunked] step {engine.stats['steps']:3d}: "
                  f"prefill@{9 if chunked else '-'} "
                  f"decode events {decode_evs[:4]}")
    print(f"[chunked] {overlap_steps} steps decoded chat tokens WHILE the "
          f"{long_prompt}-position VQA prompt prefilled "
          f"({engine.stats['prefill_chunks']} chunks of <= {chunk_tokens})")
    assert overlap_steps > 0
    assert all(r.n_generated == gen for r in engine.finished)


def serve_shared_prefix(n_requests: int = 6, prompt: int = 24,
                        gen: int = 10, shared: int = 20):
    """Prefix sharing over the paged pool: every request opens with the
    same system prompt + image (the multi-turn VQA shape: one camera
    frame, many questions). The first request pays the cold prefill and
    registers its block chain in the prefix index; every later request
    hashes to the cached chain, adopts the shared blocks by reference
    (refcount, not copy) and prefills only its own question tail —
    answers stay token-identical to the unshared slot-pool engine."""
    import copy

    cfg = make_cfg("tiered")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = make_synthetic_requests(cfg, n_requests, prompt, gen, seed=11,
                                   image_every=1, shared_prefix=shared)

    def drain(paged):
        backend = LocalBackend(model, params, num_slots=2,
                               max_len=prompt + gen, block_tokens=4)
        engine = Engine(backend, paged=paged)
        # submit one request per step-wave so each admission can see the
        # chain its predecessor registered (a single up-front burst would
        # cold-prefill the whole first wave side by side)
        for r in copy.deepcopy(reqs):
            engine.submit(r)
            engine.step()
        while not engine.idle:
            engine.step()
        return engine, {r.rid: list(r.generated) for r in engine.finished}

    slot_eng, slot_toks = drain(False)
    paged_eng, paged_toks = drain(True)
    assert slot_toks == paged_toks, "paged answers diverged from slot pool"
    bp = paged_eng.block_pool
    s = paged_eng.stats
    print(f"[prefix] {n_requests} VQA turns over one shared "
          f"{shared}-token system prompt + image: {s['prefix_hits']} "
          f"prefix hits skipped {s['prefix_hit_tokens']} prompt "
          f"positions, {bp.stats['cow_copies']} CoW copies, max "
          f"refcount {max(1, bp.max_refcount)}, answers identical to "
          f"the unshared slot pool")
    writes = bp.block_writes
    print(f"[prefix] endurance: shared blocks written "
          f"{int(writes.max()) if writes.size else 0}x max despite "
          f"{n_requests}-way reuse (write-once preserved)")


def main():
    toks_flat, _ = run("flat")
    toks_tier, cache = run("tiered")
    # tiered decoding should agree with flat decoding on most tokens
    # (int8 cold tier is a approximation only for tokens older than the
    # hot window)
    agree = float((toks_flat == toks_tier).mean())
    print(f"flat-vs-tiered token agreement: {agree:.2%}")
    # endurance discipline: cold slots written once
    for store in jax.tree.leaves(
            cache, is_leaf=lambda x: isinstance(x, dict) and "hot" in x):
        if isinstance(store, dict) and "hot" in store:
            rep = KT.endurance_report(store)
            print(f"cold-tier writes: {int(rep['total_cold_writes'])}, "
                  f"max per block {int(rep['max_writes_per_block'])}")
            break
    serve_mixed_stream()
    serve_chunked_long_vqa()
    serve_shared_prefix()


if __name__ == "__main__":
    main()
