"""End-to-end VQA serving (the paper's workload): batched requests through
prefill + decode on a paper model, comparing flat vs CHIME-tiered KV.

    PYTHONPATH=src python examples/serve_vlm.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core import kv_tiers as KT
from repro.launch.serve import generate
from repro.models import Model


def run(kv_policy: str, batch_size: int = 4, prompt: int = 32,
        gen: int = 12):
    cfg = get_config("mobilevlm-1.7b", reduced=True).replace(
        param_dtype="float32", compute_dtype="float32", remat="none",
        kv_policy=kv_policy, kv_hot_window=16)
    model = Model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    tv = cfg.frontend.num_tokens
    batch = {
        "patches": jax.random.normal(
            rng, (batch_size, tv, cfg.frontend.frontend_dim)),
        "tokens": jax.random.randint(
            rng, (batch_size, prompt - tv), 0, cfg.vocab_size),
    }
    t0 = time.time()
    toks, cache = generate(model, params, batch, prompt, gen)
    dt = time.time() - t0
    print(f"[{kv_policy:6s}] {batch_size} requests x {gen} tokens "
          f"in {dt:.2f}s; first answer ids: {toks[0, :8].tolist()}")
    return toks, cache


def main():
    toks_flat, _ = run("flat")
    toks_tier, cache = run("tiered")
    # tiered decoding should agree with flat decoding on most tokens
    # (int8 cold tier is a approximation only for tokens older than the
    # hot window)
    agree = float((toks_flat == toks_tier).mean())
    print(f"flat-vs-tiered token agreement: {agree:.2%}")
    # endurance discipline: cold slots written once
    for store in jax.tree.leaves(
            cache, is_leaf=lambda x: isinstance(x, dict) and "hot" in x):
        if isinstance(store, dict) and "hot" in store:
            rep = KT.endurance_report(store)
            print(f"cold-tier writes: {int(rep['total_cold_writes'])}, "
                  f"max per block {int(rep['max_writes_per_block'])}")
            break


if __name__ == "__main__":
    main()
