"""Quickstart: build a CHIME-mapped model, inspect its mapping plan, run a
forward pass and a few decode steps with the tiered KV cache.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core.planner import plan_for
from repro.models import Model


def main():
    # the paper's smallest evaluated model (reduced for CPU)
    cfg = get_config("fastvlm-0.6b", reduced=True).replace(
        param_dtype="float32", compute_dtype="float32", remat="none",
        kv_policy="tiered", kv_hot_window=16)
    model = Model(cfg)

    # 1. the CHIME mapping framework: where does every operator live?
    plan = plan_for(cfg)
    plan.audit()  # two-cut-point invariant
    print("== CHIME mapping plan ==")
    for lp in plan.layers:
        ops = " -> ".join(f"{p.op}@{p.domain}" for p in lp.placements)
        print(f"  [{lp.mixer} x{lp.repeats}] {ops}  cuts={lp.cut_points}")
    print(f"  cross-domain bytes/token: "
          f"{plan.cross_domain_bytes_per_token(cfg)}")

    # 2. run it: prefill a VQA-style prompt (image patches + text)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    tv = cfg.frontend.num_tokens
    batch = {
        "patches": jax.random.normal(rng, (1, tv, cfg.frontend.frontend_dim)),
        "tokens": jax.random.randint(rng, (1, 24), 0, cfg.vocab_size),
    }
    prompt_len = tv + 24
    logits, cache = model.prefill(params, batch, max_len=prompt_len + 8)
    print(f"\n== prefill == logits {logits.shape}")

    # 3. decode with the tiered cache (hot bf16 window / int8 cold tier)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    for i in range(5):
        logits, cache = model.decode_step(
            params, tok, cache, jnp.asarray(prompt_len + i, jnp.int32))
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        print(f"  step {i}: token {int(tok[0, 0])}")
    print("done.")


if __name__ == "__main__":
    main()
