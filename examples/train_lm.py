"""End-to-end training driver example: ~100M-param granite-family model for
a few hundred steps with checkpointing and fault-tolerant resume.

    PYTHONPATH=src python examples/train_lm.py --steps 200

(A true ~100M config: 8 layers x d512 x ff2048 x 8 heads, vocab 49155 ->
~78M backbone + embeddings. Reduce --steps for a smoke run.)
"""

import argparse

from repro.configs.base import get_config
from repro.launch import train as train_driver


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    # register a ~100M variant of the granite family for this example
    from repro.configs import base as cb
    full = get_config("granite-3-2b")
    cfg100m = full.replace(
        num_layers=8, d_model=512, num_heads=8, num_kv_heads=4,
        head_dim=64, d_ff=2048, segments=())
    cb._REGISTRY["granite-100m"] = cfg100m
    cb._REDUCED["granite-100m"] = cfg100m

    train_driver.main([
        "--arch", "granite-100m", "--reduced",
        "--steps", str(args.steps),
        "--batch", str(args.batch), "--seq", str(args.seq),
        "--ckpt-dir", "/tmp/repro_ckpt_100m",
        "--ckpt-every", "50",
    ])


if __name__ == "__main__":
    main()
