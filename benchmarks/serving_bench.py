"""Serving throughput vs. concurrency: does continuous batching over the
shared tiered KV pool actually buy aggregate tok/s?

    PYTHONPATH=src python benchmarks/serving_bench.py --concurrency 8
    PYTHONPATH=src python benchmarks/serving_bench.py --backend sharded
    # mixed long-VQA stream, chunked prefill (Sarathi-style):
    PYTHONPATH=src python benchmarks/serving_bench.py --arch mobilevlm-1.7b \
        --image-every 2 --prompt-len 48 --gen 16 --chunk-tokens 8
    # oversubscription: clamp the DRAM budget to concurrency/F residents
    # and compare admission-blocked vs spill-backed oversubscribed:
    PYTHONPATH=src python benchmarks/serving_bench.py --arch mobilevlm-1.7b \
        --image-every 2 --prompt-len 48 --gen 16 --chunk-tokens 8 \
        --oversubscribe 2
    # compressed-spill capacity comparison: SAME DRAM + RRAM spill
    # budgets, full-precision vs int8 lanes (lane count = budget//bytes):
    PYTHONPATH=src python benchmarks/serving_bench.py --arch mobilevlm-1.7b \
        --image-every 2 --prompt-len 48 --gen 16 --chunk-tokens 8 \
        --oversubscribe 2 --spill-compress
    # prefix sharing: every request opens with the same 28-token system
    # prompt; compare slot-charged vs block-charged admission at a DRAM
    # budget of 3 worst-case slots, plus queue-free hit vs cold TTFT:
    PYTHONPATH=src python benchmarks/serving_bench.py --arch granite-3-2b \
        --prompt-len 32 --gen 16 --hot-window 48 --prefix-share 28 \
        --block-tokens 4 --dram-budget-slots 3 --requests 12

For each slot count in {1, --concurrency} the bench drains the SAME
request stream (2x the slot count, so slots recycle) through a fresh
engine twice — the first pass pays jit compilation, the second is timed
step-by-step — and reports aggregate decode throughput, per-request and
per-step (p50/p95) latency, TTFT/TBT percentiles, the simulated CHIME
tokens/J for the served trace, and the endurance audit (write-once
discipline must survive slot recycling). Steps that decode are timed
separately (decode-BEARING: some request waited on the step for its
next token, whether or not a prefill chunk co-ran): with --chunk-tokens
their p95 is bounded by one small chunk, while whole-prompt admission
(chunk 0) drags every co-resident request's next token behind a full
prompt. A third (telemetry-enabled) pass per configuration records the
simulated tier-traffic ledger — per-tier bytes, the DRAM/RRAM/compute
energy split, the engine phase breakdown and scheduler decision counts —
and asserts it reconciles bit-for-bit with ``simulated_efficiency``.
Results append to the BENCH json trajectory at
``experiments/bench/serving.json`` so successive PRs can be compared.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models import Model
from repro.serving import (CapacityBudget, Engine, FCFSScheduler,
                           Telemetry, aggregate_metrics, make_backend,
                           make_synthetic_requests, simulated_efficiency)
from repro.simulator.hardware import CHIME

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent \
    / "experiments" / "bench" / "serving.json"


def bench_one(model, params, cfg, backend_kind: str, concurrency: int,
              n_requests: int, prompt_len: int, gen: int, max_len: int,
              mesh=None, chunk_tokens: int | None = None,
              token_budget: int | None = None,
              image_every: int = 0, priority_every: int = 0,
              dram_budget_slots: int | None = None,
              oversubscribe: float | None = None,
              n_spill: int | None = None,
              spill_compress: bool | None = None,
              idle_offload_steps: int | None = None,
              rram_spill_bytes: float | None = None,
              fused_decode: bool | None = None,
              sparse_read: float | None = None,
              weight_stream: int | None = None) -> dict:
    backend = make_backend(backend_kind, model, params,
                           num_slots=concurrency, max_len=max_len,
                           mesh=mesh, n_spill=n_spill,
                           spill_compress=spill_compress,
                           fused_decode=fused_decode,
                           sparse_read=sparse_read,
                           weight_stream=weight_stream)
    # price with the backend's RESOLVED cfg: the per-layer "streamed"
    # flags the weight-stream pricing keys off live in cost_layers(cfg)
    sim_cfg = backend.sim_context()[0]

    def fresh_engine(telemetry=None):
        # verbatim: None consults the env knobs, explicit 0 disables.
        # With a --oversubscribe comparison, the DRAM byte budget is
        # clamped to dram_budget_slots residents: the blocked baseline
        # runs at that concurrency, the oversubscribed run reclaims the
        # full slot count with spill-lane-backed admission. With
        # rram_spill_bytes, the RRAM budget for parked spill images is
        # capped too, so the lane COUNT the budget can back is
        # n_spill = rram_spill_bytes // backend.spill_lane_bytes() —
        # the capacity lever int8-compressed lanes pull.
        sched = None
        if dram_budget_slots:
            hot_b, cold_b = backend.slot_kv_bytes()
            rram = CapacityBudget.from_platform(CHIME).rram_bytes
            if rram_spill_bytes is not None:
                rram = concurrency * cold_b + rram_spill_bytes
            sched = FCFSScheduler(
                CapacityBudget(dram_budget_slots * hot_b, rram),
                hot_b, cold_b, oversubscribe=oversubscribe or 1.0,
                spill_lanes=backend.n_spill,
                lane_bytes=backend.spill_lane_bytes(),
                idle_offload_steps=idle_offload_steps)
        return Engine(backend, scheduler=sched,
                      chunk_tokens=chunk_tokens,
                      token_budget=token_budget,
                      oversubscribe=None if sched else oversubscribe,
                      idle_offload_steps=None if sched
                      else idle_offload_steps,
                      telemetry=telemetry)

    def stream(seed):
        return make_synthetic_requests(cfg, n_requests, prompt_len, gen,
                                       seed=seed, image_every=image_every,
                                       priority_every=priority_every)

    fresh_engine().run(stream(0))              # warm-up: pays compilation
    engine = fresh_engine()                    # timed pass: clean stats
    for r in stream(1):
        engine.submit(r)
    step_s, decode_step_s = [], []
    t0 = time.perf_counter()
    start = len(engine.finished)
    while not engine.idle:
        decodes_before = engine.stats["decode_steps"]
        ts = time.perf_counter()
        engine.step()
        dt = time.perf_counter() - ts
        step_s.append(dt)
        if engine.stats["decode_steps"] > decodes_before:
            # decode-heavy step: some request waited on it for its next
            # token — the TBT tail chunked prefill exists to bound
            decode_step_s.append(dt)
    wall = time.perf_counter() - t0
    done = engine.finished[start:]
    m = aggregate_metrics(done, wall)
    m["backend"] = backend_kind
    m["concurrency"] = concurrency
    # record what the engine RESOLVED (CLI flag or REPRO_SERVE_* env), so
    # env-forced chunked runs are distinguishable in the trajectory
    m["chunk_tokens"] = engine.scheduler.chunk_tokens or 0
    m["token_budget"] = engine.scheduler.token_budget or 0
    m["image_every"] = image_every
    m["oversubscribe"] = getattr(engine.scheduler, "oversubscribe",
                                 None) or 0
    m["dram_budget_slots"] = dram_budget_slots or 0
    m["spill_lanes"] = backend.n_spill
    m["spill_compress"] = bool(backend.spill_compress)
    m["spill_lane_bytes"] = backend.spill_lane_bytes()
    m["fused_decode"] = bool(backend.fused_decode)
    m["sparse_read_tau"] = float(backend.sparse_read_tau)
    m["weight_stream"] = int(backend.weight_stream)
    wb_dram, wb_rram = backend.weight_bytes()
    m["weight_bytes_dram"] = int(wb_dram)
    m["weight_bytes_rram"] = int(wb_rram)
    m["idle_offload_steps"] = getattr(engine.scheduler,
                                      "idle_offload_steps", None) or 0
    m["idle_offloads"] = engine.stats["idle_offloads"]
    m["evictions"] = engine.stats["evictions"]
    m["steps"] = len(step_s)
    m["p50_step_s"] = float(np.percentile(step_s, 50))
    m["p95_step_s"] = float(np.percentile(step_s, 95))
    if decode_step_s:
        m["decode_steps_timed"] = len(decode_step_s)
        m["p95_decode_step_s"] = float(np.percentile(decode_step_s, 95))
    m["engine_stats"] = dict(engine.stats)
    m["endurance"] = engine.endurance_report()
    m["sim"] = simulated_efficiency(
        sim_cfg, done, spill_compressed=backend.spill_compress,
        fused_decode=backend.fused_decode,
        sparse_read_tau=backend.sparse_read_tau,
        weight_stream=bool(backend.weight_stream))
    # third pass: telemetry ON over the same stream — records the
    # per-tier traffic/energy ledger + phase breakdown into the BENCH
    # trajectory, checks the ledger reconciles bit-for-bit against
    # simulated_efficiency, and measures the enabled-vs-disabled
    # wall-clock overhead (the <2% contract is on DISABLED telemetry;
    # the enabled cost recorded here is informational)
    tel = Telemetry()
    tel_engine = fresh_engine(telemetry=tel)
    for r in stream(1):
        tel_engine.submit(r)
    t0 = time.perf_counter()
    while not tel_engine.idle:
        tel_engine.step()
    tel_wall = time.perf_counter() - t0
    tel_sim = simulated_efficiency(sim_cfg, tel_engine.finished,
                                   spill_compressed=backend.spill_compress,
                                   fused_decode=backend.fused_decode,
                                   sparse_read_tau=backend.sparse_read_tau,
                                   weight_stream=bool(
                                       backend.weight_stream))
    led = tel.ledger.totals()
    summary = tel.summary()
    m["telemetry"] = {
        "tier_bytes": {k: led[k] for k in
                       ("dram_hot_ring_bytes", "rram_cold_read_bytes",
                        "rram_spill_bytes", "dram_stream_bytes",
                        "rram_stream_bytes", "sparse_skipped_bytes",
                        "weight_stream_bytes", "kv_append_bytes",
                        "ucie_bytes")},
        "energy_split_j": led["sim_energy_split_j"],
        "phase_s": summary["phase_s"],
        "decisions": summary["decisions"],
        "ledger_reconciles": (
            led["sim_energy_j"] == tel_sim["sim_energy_j"]
            and led["sim_total_s"] == tel_sim["sim_total_s"]
            and led["sim_energy_split_j"]
            == tel_sim["sim_energy_split_j"]),
        "enabled_overhead_pct": (tel_wall / max(wall, 1e-9) - 1.0) * 100,
    }
    return m


def bench_prefix_share(model, params, cfg, backend_kind: str,
                       concurrency: int, n_requests: int, prompt_len: int,
                       gen: int, max_len: int, shared: int,
                       dram_budget_slots: int, mesh=None,
                       chunk_tokens: int | None = None,
                       token_budget: int | None = None,
                       image_every: int = 0,
                       block_tokens: int | None = None) -> dict:
    """Prefix-sharing capacity + TTFT comparison at a FIXED DRAM budget.

    Every request in the stream opens with the same ``shared``-token
    system prompt (and, for VQA requests, the same image), the admission
    gate's DRAM budget is clamped to ``dram_budget_slots`` worst-case
    residents, and the SAME stream drains twice:

    - slot mode (``paged=False``): every resident is charged the
      worst-case ``max_len`` slot image, so peak concurrency is pinned
      at the budgeted slot count no matter how much of each prompt is
      duplicated work;
    - paged (``paged=True``): residents are charged their live block
      count and a prefix hit charges only the unshared tail, so the
      same bytes admit the redundant requests concurrently.

    Peak concurrent residents (and residents per DRAM GiB) is the
    capacity comparison; the two passes must agree token-for-token.
    A third, unconstrained pass submits requests one at a time so TTFT
    is pure admit-to-first-token: request 0 pays the cold prefill,
    every later request adopts the registered chain — prefix-hit TTFT
    vs cold-prefill TTFT without queueing noise."""
    backend = make_backend(backend_kind, model, params,
                           num_slots=concurrency, max_len=max_len,
                           mesh=mesh, block_tokens=block_tokens)
    hot_b, cold_b = backend.slot_kv_bytes()
    dram_budget = dram_budget_slots * hot_b
    rram = CapacityBudget.from_platform(CHIME).rram_bytes

    def fresh_engine(paged, budget=True, telemetry=None):
        sched = None
        if budget:
            sched = FCFSScheduler(
                CapacityBudget(dram_budget, rram), hot_b, cold_b,
                spill_lanes=backend.n_spill,
                lane_bytes=backend.spill_lane_bytes())
        return Engine(backend, scheduler=sched,
                      chunk_tokens=chunk_tokens,
                      token_budget=token_budget, paged=paged,
                      telemetry=telemetry)

    def stream(seed):
        return make_synthetic_requests(cfg, n_requests, prompt_len, gen,
                                       seed=seed, image_every=image_every,
                                       shared_prefix=shared)

    fresh_engine(True).run(stream(0))          # warm-up: pays compilation

    def drain(paged):
        engine = fresh_engine(paged)
        for r in stream(1):
            engine.submit(r)
        peak, step_s = 0, []
        t0 = time.perf_counter()
        while not engine.idle:
            ts = time.perf_counter()
            engine.step()
            step_s.append(time.perf_counter() - ts)
            peak = max(peak, engine.pool.active_slots)
        wall = time.perf_counter() - t0
        m = aggregate_metrics(engine.finished, wall)
        m["paged"] = paged
        m["peak_concurrency"] = peak
        m["requests_per_dram_gib"] = peak / (dram_budget / 2**30)
        m["steps"] = len(step_s)
        m["p50_step_s"] = float(np.percentile(step_s, 50))
        m["p95_step_s"] = float(np.percentile(step_s, 95))
        m["engine_stats"] = dict(engine.stats)
        m["endurance"] = engine.endurance_report()
        m["sim"] = simulated_efficiency(cfg, engine.finished)
        if engine.block_pool is not None:
            bp = engine.block_pool
            m["block_pool"] = {k: int(v) for k, v in bp.stats.items()
                               if k != "block_writes"}
            m["block_pool"]["peak_used_blocks"] = bp.used_blocks
        return m, {r.rid: list(r.generated) for r in engine.finished}

    slot_m, slot_toks = drain(False)
    paged_m, paged_toks = drain(True)
    parity = slot_toks == paged_toks

    # TTFT pass: unconstrained budget, one request in flight at a time,
    # so TTFT is admit-to-first-token with an empty queue. Request 0 is
    # the cold prefill that registers the chain; later requests hit it.
    eng = fresh_engine(True, budget=False)
    for r in stream(2):
        eng.submit(r)
        while not eng.idle:
            eng.step()
    seq = eng.finished[-n_requests:]
    cold = [r for r in seq if r.prefix_hit == 0]
    hits = [r for r in seq if r.prefix_hit > 0]
    cold_ttft = float(np.mean([r.first_token_s - r.arrival_s
                               for r in cold])) if cold else 0.0
    hit_ttft = float(np.mean([r.first_token_s - r.arrival_s
                              for r in hits])) if hits else 0.0

    return {
        "mode": "prefix-share",
        "shared_prefix": shared,
        "block_tokens": backend.block_tokens,
        "dram_budget_slots": dram_budget_slots,
        "dram_budget_bytes": dram_budget,
        "slot": slot_m,
        "paged": paged_m,
        "token_parity": parity,
        "capacity_gain": (paged_m["peak_concurrency"]
                          / max(slot_m["peak_concurrency"], 1)),
        "sequential_ttft": {
            "cold_requests": len(cold),
            "hit_requests": len(hits),
            "cold_mean_ttft_s": cold_ttft,
            "prefix_hit_mean_ttft_s": hit_ttft,
            "hit_faster": bool(hits) and hit_ttft < cold_ttft,
        },
    }


def append_bench_json(record: dict, path: pathlib.Path = BENCH_JSON):
    """Append one run record to the serving BENCH trajectory. Tolerates a
    truncated/corrupt file (starts fresh) and replaces atomically so an
    interrupted run can't wedge future ones."""
    path.parent.mkdir(parents=True, exist_ok=True)
    history = []
    if path.exists():
        try:
            history = json.loads(path.read_text())
        except json.JSONDecodeError:
            print(f"[bench] WARNING: {path} is corrupt; starting a "
                  f"fresh trajectory")
    history.append(record)
    tmp = path.with_suffix(".json.tmp")
    tmp.write_text(json.dumps(history, indent=1, sort_keys=True) + "\n")
    tmp.replace(path)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--full", action="store_true",
                    help="full-size config (default: reduced)")
    ap.add_argument("--backend", default="local",
                    choices=["local", "sharded"])
    ap.add_argument("--mesh", default="local",
                    help="sharded backend mesh (see launch.mesh.get_mesh)")
    ap.add_argument("--concurrency", type=int, default=8)
    ap.add_argument("--requests", type=int, default=0,
                    help="requests per run (0 = 2x concurrency)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--kv-policy", default="tiered",
                    choices=["flat", "tiered"])
    ap.add_argument("--hot-window", type=int, default=8)
    ap.add_argument("--chunk-tokens", type=int, default=None,
                    help="chunked prefill chunk cap (0 = whole prompts "
                         "even under REPRO_SERVE_CHUNK_TOKENS; default: "
                         "consult the env knob)")
    ap.add_argument("--token-budget", type=int, default=None,
                    help="per-step token budget (0 = unbounded; "
                         "default: env knob / derived)")
    ap.add_argument("--image-every", type=int, default=0,
                    help="every k-th request is a VQA request (0 = none)")
    ap.add_argument("--priority-every", type=int, default=0,
                    help="every k-th request is priority-1 traffic")
    ap.add_argument("--oversubscribe", type=float, default=0.0,
                    help="> 1: compare an admission-blocked baseline "
                         "(DRAM budget = concurrency/F residents) "
                         "against spill-backed oversubscription at the "
                         "full slot count")
    ap.add_argument("--spill-compress", action="store_true", default=None,
                    help="int8-compress spill-lane hot rings; with "
                         "--oversubscribe > 1 this switches to the "
                         "capacity comparison: blocked baseline vs "
                         "full-precision lanes vs compressed lanes at "
                         "the SAME fixed DRAM + RRAM spill budgets "
                         "(lane count = budget // lane bytes)")
    ap.add_argument("--idle-offload-steps", type=int, default=None,
                    help="enable proactive idle cold-KV offload at this "
                         "residency threshold (see serving/scheduler.py)")
    ap.add_argument("--fused-decode", action="store_true", default=None,
                    help="fused Pallas paged-decode attention over the "
                         "tiered pool (GQA archs; default: consult "
                         "REPRO_SERVE_FUSED_DECODE)")
    ap.add_argument("--sparse-read", type=float, default=None,
                    metavar="TAU",
                    help="SLIM-style sparse-read threshold inside the "
                         "fused kernel (0 = exact; needs --fused-decode; "
                         "default: consult REPRO_SERVE_SPARSE_READ)")
    ap.add_argument("--weight-stream", type=int, default=None, metavar="W",
                    help="RRAM weight streaming: run the streamed-vs-"
                         "resident comparison at this DRAM sliding-"
                         "window depth (0 = off even under "
                         "REPRO_SERVE_WEIGHT_STREAM; default: consult "
                         "the env knob)")
    ap.add_argument("--prefix-share", type=int, default=0, metavar="N",
                    help="prefix-sharing comparison: every request opens "
                         "with the same N-token system prompt (and VQA "
                         "requests share one image); drains the stream "
                         "slot-charged vs block-charged at the same "
                         "clamped DRAM budget and measures peak "
                         "concurrency, hit rate and prefix-hit vs cold "
                         "TTFT (0 = off)")
    ap.add_argument("--block-tokens", type=int, default=None,
                    help="prefix-share page size in tokens (default: "
                         "backend's, i.e. ENDURANCE_BLOCK clamped to "
                         "max_len and the chunk grid)")
    ap.add_argument("--dram-budget-slots", type=int, default=0,
                    help="prefix-share DRAM budget, in worst-case slot "
                         "images (0 = concurrency // 2)")
    ap.add_argument("--no-json", action="store_true",
                    help="skip appending to the BENCH json trajectory")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=not args.full).replace(
        param_dtype="float32", compute_dtype="float32", remat="none",
        kv_policy=args.kv_policy, kv_hot_window=args.hot_window)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_requests = args.requests or 2 * args.concurrency
    vis = (cfg.frontend.num_tokens
           if args.image_every and cfg.frontend is not None else 0)
    max_len = max(args.prompt_len, vis + 1) + args.gen
    mesh = None
    if args.backend == "sharded":
        from repro.launch.mesh import get_mesh
        mesh = get_mesh(args.mesh)

    print(f"[bench] arch={args.arch} kv={args.kv_policy} "
          f"backend={args.backend} chunk={args.chunk_tokens or 0} "
          f"requests={n_requests} prompt={args.prompt_len} gen={args.gen}")

    def show(label, r):
        rep = r["endurance"]
        print(f"[bench] {label}: {r['tok_per_s']:8.1f} tok/s  "
              f"step p50={r['p50_step_s'] * 1e3:.1f}ms "
              f"p95={r['p95_step_s'] * 1e3:.1f}ms "
              f"decode p95={r.get('p95_decode_step_s', 0.0) * 1e3:.1f}ms  "
              f"ttft p95={r.get('ttft_p95_s', 0.0) * 1e3:.1f}ms "
              f"tbt p95={r.get('tbt_p95_s', 0.0) * 1e3:.1f}ms  "
              f"sim={r['sim']['sim_tokens_per_j']:.1f} tok/J  "
              f"endurance max writes/block="
              f"{rep['max_writes_per_cold_slot']:.2f} "
              f"({'OK' if rep['write_once_ok'] else 'VIOLATED'})")
        t = r["telemetry"]
        split = t["energy_split_j"]
        print(f"[bench]   ledger: dram={split.get('dram', 0.0):.3g} J "
              f"rram={split.get('rram', 0.0):.3g} J "
              f"compute={split.get('compute', 0.0):.3g} J "
              f"({'reconciles EXACT' if t['ledger_reconciles'] else 'DRIFT'}"
              f"; telemetry-on overhead {t['enabled_overhead_pct']:+.1f}%)")

    results = []
    if args.prefix_share:
        # prefix-sharing capacity comparison: same stream, same DRAM
        # budget, slot-charged vs block-charged admission (+ a
        # sequential pass for queue-free hit-vs-cold TTFT)
        base = args.dram_budget_slots or max(1, args.concurrency // 2)
        r = bench_prefix_share(
            model, params, cfg, args.backend, args.concurrency,
            n_requests, args.prompt_len, args.gen, max_len,
            args.prefix_share, base, mesh=mesh,
            chunk_tokens=args.chunk_tokens,
            token_budget=args.token_budget,
            image_every=args.image_every,
            block_tokens=args.block_tokens)
        results.append(r)
        sm, pm, tt = r["slot"], r["paged"], r["sequential_ttft"]
        print(f"[bench] shared prefix {args.prefix_share} tok, DRAM "
              f"budget {base} worst-case slots "
              f"({r['dram_budget_bytes']} B), block={r['block_tokens']}:")
        print(f"[bench]   slot-charged: peak {sm['peak_concurrency']} "
              f"concurrent ({sm['requests_per_dram_gib']:.1f}/GiB), "
              f"{sm['tok_per_s']:.1f} tok/s")
        print(f"[bench]   block-charged: peak {pm['peak_concurrency']} "
              f"concurrent ({pm['requests_per_dram_gib']:.1f}/GiB), "
              f"{pm['tok_per_s']:.1f} tok/s, hit rate "
              f"{pm.get('prefix_hit_rate', 0.0):.2f}, "
              f"{pm.get('block_pool', {}).get('cow_copies', 0)} CoW "
              f"(tokens {'MATCH' if r['token_parity'] else 'DIVERGE'})")
        print(f"[bench]   capacity x{r['capacity_gain']:.2f} at the same "
              f"DRAM budget; sequential TTFT: cold "
              f"{tt['cold_mean_ttft_s'] * 1e3:.1f} ms vs prefix-hit "
              f"{tt['prefix_hit_mean_ttft_s'] * 1e3:.1f} ms over "
              f"{tt['hit_requests']} hits "
              f"({'hit faster' if tt['hit_faster'] else 'NO SPEEDUP'})")
    elif args.oversubscribe and args.oversubscribe > 1 \
            and args.spill_compress:
        # CAPACITY comparison at fixed DRAM *and* RRAM spill budgets:
        # oversubscribed residents beyond the DRAM base must be backed
        # by spill lanes, and the lane count is what a fixed RRAM spill
        # budget divided by the lane bytes affords. The budget is sized
        # so int8-compressed lanes back the full overflow; full-
        # precision (PR 4) lanes afford fewer lanes from the SAME bytes,
        # so the baseline admits fewer residents — completed tok/s at
        # the full slot count is the comparison.
        from repro.serving import spill_lane_bytes as lane_bytes_of
        base = max(1, int(round(args.concurrency / args.oversubscribe)))
        overflow = args.concurrency - base
        full_b = lane_bytes_of(model, max_len, compressed=False)
        comp_b = lane_bytes_of(model, max_len, compressed=True)
        budget = overflow * comp_b
        lanes_full = int(budget // full_b)
        lanes_comp = int(budget // comp_b)
        print(f"[bench] RRAM spill budget {budget} B: "
              f"{lanes_full} full-precision lanes "
              f"({full_b} B) vs {lanes_comp} int8 lanes ({comp_b} B)")
        for label, compress, lanes in (
                ("blocked baseline", False, 0),
                (f"oversubscribe={args.oversubscribe:g} fp-lanes",
                 False, lanes_full),
                (f"oversubscribe={args.oversubscribe:g} int8-lanes",
                 True, lanes_comp)):
            r = bench_one(model, params, cfg, args.backend,
                          args.concurrency, n_requests, args.prompt_len,
                          args.gen, max_len, mesh=mesh,
                          chunk_tokens=args.chunk_tokens,
                          token_budget=args.token_budget,
                          image_every=args.image_every,
                          priority_every=args.priority_every,
                          dram_budget_slots=base,
                          oversubscribe=(1.0 if lanes == 0
                                         else args.oversubscribe),
                          n_spill=lanes, spill_compress=compress,
                          idle_offload_steps=args.idle_offload_steps,
                          rram_spill_bytes=budget)
            results.append(r)
            show(f"dram-budget={base} {label}", r)
        gain_fp = results[1]["tok_per_s"] / max(results[0]["tok_per_s"],
                                                1e-9)
        gain_int8 = results[2]["tok_per_s"] / max(results[0]["tok_per_s"],
                                                  1e-9)
        print(f"[bench] at a fixed DRAM budget of {base} residents and "
              f"{budget} B of spill RRAM: full-precision lanes buy "
              f"x{gain_fp:.2f}, int8 lanes x{gain_int8:.2f} completed "
              f"tok/s over the admission-blocked baseline")
    elif args.oversubscribe and args.oversubscribe > 1:
        # admission-blocked baseline vs spill-backed oversubscription at
        # the SAME tight DRAM budget (concurrency/F residents): the
        # oversubscribed engine reclaims the full slot count, the
        # baseline queues — completed-tokens/s is the comparison
        base = max(1, int(round(args.concurrency / args.oversubscribe)))
        for over in (1.0, args.oversubscribe):
            r = bench_one(model, params, cfg, args.backend,
                          args.concurrency, n_requests, args.prompt_len,
                          args.gen, max_len, mesh=mesh,
                          chunk_tokens=args.chunk_tokens,
                          token_budget=args.token_budget,
                          image_every=args.image_every,
                          priority_every=args.priority_every,
                          dram_budget_slots=base, oversubscribe=over,
                          idle_offload_steps=args.idle_offload_steps)
            results.append(r)
            show(f"dram-budget={base} oversubscribe={over:g}", r)
        speedup = results[1]["tok_per_s"] / max(results[0]["tok_per_s"],
                                                1e-9)
        print(f"[bench] oversubscription x{args.oversubscribe:g} buys "
              f"x{speedup:.2f} completed tok/s over the "
              f"admission-blocked baseline")
    elif args.weight_stream:
        # streamed-vs-resident weight comparison over the SAME stream at
        # the same slot count: resident weights are the parity oracle
        # (tokens must match exactly); the streamed run shrinks the DRAM
        # weight working set to embeddings + head + per-unit sliding
        # windows and pays the per-layer RRAM fetch energy in the sim
        for label, w in (("resident", 0),
                         (f"streamed W={args.weight_stream}",
                          args.weight_stream)):
            r = bench_one(model, params, cfg, args.backend,
                          args.concurrency, n_requests, args.prompt_len,
                          args.gen, max_len, mesh=mesh,
                          chunk_tokens=args.chunk_tokens,
                          token_budget=args.token_budget,
                          image_every=args.image_every,
                          priority_every=args.priority_every,
                          spill_compress=args.spill_compress,
                          idle_offload_steps=args.idle_offload_steps,
                          fused_decode=args.fused_decode,
                          sparse_read=args.sparse_read,
                          weight_stream=w)
            results.append(r)
            show(f"weights {label}", r)
        res, st = results
        print(f"[bench] weight streaming W={st['weight_stream']}: DRAM "
              f"weight working set {st['weight_bytes_dram']} B vs "
              f"resident {res['weight_bytes_dram']} B "
              f"({st['weight_bytes_rram']} B homed in RRAM); sim energy "
              f"{st['sim']['sim_energy_j']:.3f} J vs "
              f"{res['sim']['sim_energy_j']:.3f} J resident")
    else:
        for c in sorted({1, args.concurrency}):
            r = bench_one(model, params, cfg, args.backend, c, n_requests,
                          args.prompt_len, args.gen, max_len, mesh=mesh,
                          chunk_tokens=args.chunk_tokens,
                          token_budget=args.token_budget,
                          image_every=args.image_every,
                          priority_every=args.priority_every,
                          spill_compress=args.spill_compress,
                          idle_offload_steps=args.idle_offload_steps,
                          fused_decode=args.fused_decode,
                          sparse_read=args.sparse_read)
            results.append(r)
            show(f"concurrency={c:3d}", r)
        if len(results) == 2:
            speedup = results[1]["tok_per_s"] / max(
                results[0]["tok_per_s"], 1e-9)
            print(f"[bench] aggregate throughput x{speedup:.2f} at "
                  f"concurrency {args.concurrency} vs 1")
    if not args.no_json:
        append_bench_json({
            "ts": time.strftime("%Y-%m-%dT%H:%M:%S"),
            "arch": args.arch,
            "kv_policy": args.kv_policy,
            "hot_window": args.hot_window,
            "prompt_len": args.prompt_len,
            "gen": args.gen,
            "chunk_tokens": results[-1].get("chunk_tokens",
                                            args.chunk_tokens or 0),
            "image_every": args.image_every,
            "prefix_share": args.prefix_share or 0,
            "oversubscribe": args.oversubscribe or 0,
            "spill_compress": bool(args.spill_compress),
            "idle_offload_steps": args.idle_offload_steps or 0,
            "fused_decode": bool(args.fused_decode),
            "sparse_read": args.sparse_read or 0.0,
            "weight_stream": args.weight_stream or 0,
            "runs": results,
        })
        print(f"[bench] appended to {BENCH_JSON}")
    return results


if __name__ == "__main__":
    main()
