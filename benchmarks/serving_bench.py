"""Serving throughput vs. concurrency: does continuous batching over the
shared tiered KV pool actually buy aggregate tok/s?

    PYTHONPATH=src python benchmarks/serving_bench.py --concurrency 8

For each slot count in {1, --concurrency} the bench drains the SAME
request stream (2x the slot count, so slots recycle) through a fresh
engine twice — the first pass pays jit compilation, the second is timed —
and reports aggregate decode throughput, per-request latency, the
simulated CHIME tokens/J for the served trace, and the endurance audit
(write-once discipline must survive slot recycling).
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs.base import get_config
from repro.models import Model
from repro.serving import (Engine, aggregate_metrics,
                           make_synthetic_requests, simulated_efficiency)


def bench_one(model, params, cfg, concurrency: int, n_requests: int,
              prompt_len: int, gen: int, max_len: int) -> dict:
    engine = Engine(model, params, num_slots=concurrency, max_len=max_len)

    def stream(seed):
        return make_synthetic_requests(cfg, n_requests, prompt_len, gen,
                                       seed=seed)

    engine.run(stream(0))                      # warm-up: pays compilation
    t0 = time.perf_counter()
    done = engine.run(stream(1))
    wall = time.perf_counter() - t0
    m = aggregate_metrics(done, wall)
    m["concurrency"] = concurrency
    m["endurance"] = engine.endurance_report()
    m["sim"] = simulated_efficiency(cfg, done)
    return m


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--full", action="store_true",
                    help="full-size config (default: reduced)")
    ap.add_argument("--concurrency", type=int, default=8)
    ap.add_argument("--requests", type=int, default=0,
                    help="requests per run (0 = 2x concurrency)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--kv-policy", default="tiered",
                    choices=["flat", "tiered"])
    ap.add_argument("--hot-window", type=int, default=8)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=not args.full).replace(
        param_dtype="float32", compute_dtype="float32", remat="none",
        kv_policy=args.kv_policy, kv_hot_window=args.hot_window)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_requests = args.requests or 2 * args.concurrency
    max_len = args.prompt_len + args.gen

    print(f"[bench] arch={args.arch} kv={args.kv_policy} "
          f"requests={n_requests} prompt={args.prompt_len} gen={args.gen}")
    results = []
    for c in sorted({1, args.concurrency}):
        r = bench_one(model, params, cfg, c, n_requests,
                      args.prompt_len, args.gen, max_len)
        results.append(r)
        rep = r["endurance"]
        print(f"[bench] concurrency={c:3d}: {r['tok_per_s']:8.1f} tok/s  "
              f"mean_latency={r['mean_latency_s']:.3f}s  "
              f"sim={r['sim']['sim_tokens_per_j']:.1f} tok/J  "
              f"endurance max writes/block="
              f"{rep['max_writes_per_cold_slot']:.2f} "
              f"({'OK' if rep['write_once_ok'] else 'VIOLATED'})")
    if len(results) == 2:
        speedup = results[1]["tok_per_s"] / max(results[0]["tok_per_s"],
                                                1e-9)
        print(f"[bench] aggregate throughput x{speedup:.2f} at "
              f"concurrency {args.concurrency} vs 1")
    return results


if __name__ == "__main__":
    main()
