"""Aggregate the dry-run JSONs into the §Roofline table (EXPERIMENTS.md).

Reads experiments/dryrun/*.json (produced by repro.launch.dryrun) and
prints, per (arch x shape x mesh): the three roofline terms, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs, and what would move the dominant term.
"""

from __future__ import annotations

import json
import pathlib

DRYRUN_DIR = pathlib.Path(__file__).resolve().parents[1] \
    / "experiments" / "dryrun"

ADVICE = {
    "compute": "increase arithmetic intensity (fuse, larger tiles) or "
               "accept: compute-bound is the roofline target",
    "memory": "cut HBM traffic: FUSED_ATTN_STREAM keeps S^2 scores in "
              "VMEM; int8 cold-KV/FFN weights halve weight bytes",
    "collective": "reshard to cut gathers (seq-parallel residual, "
                  "reduce-scatter grads), overlap collectives with compute",
}


def load_all(include_tagged: bool = False) -> list[dict]:
    rows = []
    for p in sorted(DRYRUN_DIR.glob("*.json")):
        d = json.loads(p.read_text())
        if not include_tagged and len(d["cell"].split("@")) > 3:
            continue  # hillclimb variants reported by benchmarks/hillclimb
        rows.append(d)
    return rows


def main():
    rows = load_all()
    if not rows:
        print("# no dry-run results yet — run "
              "`python -m repro.launch.dryrun --all --both-meshes`")
        return
    print("\n# §Roofline — per (arch x shape x mesh), terms in seconds "
          "per step (per device)")
    print("cell,compute_s,memory_s,collective_s,dominant,"
          "model_flops,useful_ratio,peak_gb_dev")
    for d in rows:
        r = d.get("roofline", {})
        if not r:
            continue
        print(f"{d['cell']},{r['compute_s']:.4f},{r['memory_s']:.4f},"
              f"{r['collective_s']:.4f},{r['dominant']},"
              f"{r['model_flops']:.3e},"
              f"{(r['useful_flops_ratio'] or 0):.3f},"
              f"{d['memory']['peak_bytes'] / 1e9:.2f}")
    doms = {}
    for d in rows:
        dom = d.get("roofline", {}).get("dominant")
        doms[dom] = doms.get(dom, 0) + 1
    print(f"# dominant-term histogram: {doms}")
    for k, v in ADVICE.items():
        print(f"# if {k}-bound: {v}")
    # §Perf hillclimb summary
    try:
        from benchmarks import hillclimb
        hillclimb.report()
    except Exception as e:  # noqa: BLE001
        print(f"# hillclimb report unavailable: {e}")


if __name__ == "__main__":
    main()
