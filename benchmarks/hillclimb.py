"""§Perf hillclimbing driver: run tagged dry-run variants of the chosen
cells and print before/after roofline deltas.

    PYTHONPATH=src:. python -m benchmarks.hillclimb --cell <arch@shape> \
        --variant <tag>
    PYTHONPATH=src:. python -m benchmarks.hillclimb --report

Each variant is a (hypothesis, config-delta) pair; results are written as
tagged JSONs next to the baselines and summarized by --report.
"""

from __future__ import annotations

import argparse
import json
import pathlib

DRYRUN_DIR = pathlib.Path(__file__).resolve().parents[1] \
    / "experiments" / "dryrun"

# hypothesis -> config delta, per hillclimbed cell (see EXPERIMENTS.md §Perf
# for the napkin math behind each)
EXPERIMENTS: dict[str, dict[str, dict]] = {
    # paper-technique representative: VLM decode with the CHIME KV tiers
    "paligemma-3b@decode_32k": {
        "tiered": {"kv_policy": "tiered"},
        "tiered_hot1k": {"kv_policy": "tiered", "kv_hot_window": 1024},
        "tiered_hot8k": {"kv_policy": "tiered", "kv_hot_window": 8192},
        "tiered_bf16s": {"kv_policy": "tiered",
                         "attn_scores_dtype": "bfloat16"},
        "tiered_int8ffn": {"kv_policy": "tiered",
                           "attn_scores_dtype": "bfloat16",
                           "ffn_weight_store": "int8"},
    },
    # worst roofline fraction / memory-bound: MLA decode
    "deepseek-v2-lite@decode_32k": {
        "absorbed": {"mla_absorbed": True},
        "absorbed_tiered": {"mla_absorbed": True, "kv_policy": "tiered"},
        "absorbed_tiered_bf16s": {"mla_absorbed": True,
                                  "kv_policy": "tiered",
                                  "attn_scores_dtype": "bfloat16"},
    },
    # most collective-bound: MoE decode. "kvseq" is a pure code fix (keep
    # the cache's seq sharding through the GQA broadcast) — the tag runs
    # the same config on the fixed code; moeff adds the expert layout.
    "llama4-maverick-400b@decode_32k": {
        "kvseq": {},
        "kvseq_tiered": {"kv_policy": "tiered"},
        "moeff": {"moe_ff_fsdp": True},
    },
    # collective-bound training at pod scale
    "nemotron-4-340b@train_4k": {
        "mb4": {"microbatches": 4},
        "mb8": {"microbatches": 8},
        "mb4_dots": {"microbatches": 4, "remat": "save_dots"},
        "mb4_bf16s": {"microbatches": 4,
                      "attn_scores_dtype": "bfloat16"},
    },
    # worst useful-flops ratio: unshardable 36-head attention at 32k
    "starcoder2-7b@prefill_32k": {
        "seqsp": {"seq_sharding": True},
        "seqsp_bf16s": {"seq_sharding": True,
                        "attn_scores_dtype": "bfloat16"},
    },
    # collective-bound MoE prefill: partial-sum all-reduces of (tokens, D)
    # f32 activations (52 GB/layer) — Megatron-SP turns them into
    # reduce-scatter + gather (the full fix is shard_map all-to-all
    # dispatch, out of scope here and noted in DESIGN.md)
    "deepseek-v2-lite@prefill_32k": {
        "seqsp": {"seq_sharding": True},
    },
}


def run(cell: str, variant: str, multi_pod: bool = False):
    from repro.launch import dryrun
    arch, shape = cell.split("@")
    overrides = EXPERIMENTS[cell][variant]
    res = dryrun.run_cell(arch, shape, multi_pod, overrides, tag=variant)
    dryrun.save_result(res)
    return res


def report():
    for cell, variants in EXPERIMENTS.items():
        arch, shape = cell.split("@")
        base_p = DRYRUN_DIR / f"{arch}@{shape}@pod16x16.json"
        if not base_p.exists():
            continue
        base = json.loads(base_p.read_text())
        br = base["roofline"]
        print(f"\n== {cell} (dominant: {br['dominant']}) ==")
        print(f"{'variant':24s} {'compute_s':>10s} {'memory_s':>10s} "
              f"{'coll_s':>10s} {'bound_s':>10s} {'Δbound':>8s} "
              f"{'peakGB':>7s}")
        print(f"{'baseline':24s} {br['compute_s']:10.3f} "
              f"{br['memory_s']:10.3f} {br['collective_s']:10.3f} "
              f"{br['bound_s']:10.3f} {'—':>8s} "
              f"{base['memory']['peak_bytes'] / 1e9:7.1f}")
        for tag in variants:
            p = DRYRUN_DIR / f"{arch}@{shape}@pod16x16@{tag}.json"
            if not p.exists():
                print(f"{tag:24s} (not run)")
                continue
            d = json.loads(p.read_text())
            r = d["roofline"]
            delta = (br["bound_s"] - r["bound_s"]) / br["bound_s"] * 100
            print(f"{tag:24s} {r['compute_s']:10.3f} {r['memory_s']:10.3f} "
                  f"{r['collective_s']:10.3f} {r['bound_s']:10.3f} "
                  f"{delta:+7.1f}% "
                  f"{d['memory']['peak_bytes'] / 1e9:7.1f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell")
    ap.add_argument("--variant")
    ap.add_argument("--all-variants", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--report", action="store_true")
    args = ap.parse_args()
    if args.report:
        report()
        return
    if args.all_variants:
        for v in EXPERIMENTS[args.cell]:
            run(args.cell, v, args.multi_pod)
        return
    run(args.cell, args.variant, args.multi_pod)


if __name__ == "__main__":
    main()
