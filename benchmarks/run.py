"""Benchmark harness entry point: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run
"""

from __future__ import annotations


def main() -> None:
    from benchmarks import kernel_bench, paper_figs, roofline
    paper_figs.main()
    kernel_bench.main()
    roofline.main()


if __name__ == "__main__":
    main()
