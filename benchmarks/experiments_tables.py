"""Generate the EXPERIMENTS.md §Dry-run/§Roofline markdown tables from
experiments/dryrun/*.json.

    PYTHONPATH=src:. python -m benchmarks.experiments_tables [--update]

--update rewrites the AUTOGEN block in EXPERIMENTS.md in place.
"""

from __future__ import annotations

import argparse
import json
import pathlib

ROOT = pathlib.Path(__file__).resolve().parents[1]
DRYRUN_DIR = ROOT / "experiments" / "dryrun"

BEGIN = "<!-- AUTOGEN:ROOFLINE BEGIN -->"
END = "<!-- AUTOGEN:ROOFLINE END -->"


def one_liner(d: dict) -> str:
    r = d["roofline"]
    dom = r["dominant"]
    move = {
        "compute": "raise arithmetic intensity / accept (at roofline)",
        "memory": "cut HBM bytes: stream attention (VMEM scores), int8 "
                  "cold-KV + int8 FFN store",
        "collective": "reshard: seq-parallel residual, fewer gathers, "
                      "overlap with compute",
    }[dom]
    return move


def rows(only_mesh: str | None = None, tag: str | None = None):
    out = []
    for p in sorted(DRYRUN_DIR.glob("*.json")):
        d = json.loads(p.read_text())
        parts = d["cell"].split("@")
        cell_tag = parts[3] if len(parts) > 3 else ""
        if tag is not None and cell_tag != tag:
            continue
        if tag is None and cell_tag:
            continue
        if only_mesh and d["mesh"] != only_mesh:
            continue
        out.append(d)
    return out


def markdown(tag: str | None = None) -> str:
    lines = []
    lines.append(
        "| cell | mesh | compute_s | memory_s | collective_s | dominant | "
        "MODEL_FLOPS | useful ratio | peak GB/dev | compile_s |")
    lines.append("|---|---|---|---|---|---|---|---|---|---|")
    for d in rows(tag=tag):
        r = d["roofline"]
        lines.append(
            f"| {d['arch']}@{d['shape']} | {d['mesh']} "
            f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} "
            f"| {r['collective_s']:.3f} | **{r['dominant']}** "
            f"| {r['model_flops']:.2e} "
            f"| {(r['useful_flops_ratio'] or 0):.2f} "
            f"| {d['memory']['peak_bytes'] / 1e9:.1f} "
            f"| {d['compile_s']:.0f} |")
    doms = {}
    for d in rows(tag=tag):
        k = d["roofline"]["dominant"]
        doms[k] = doms.get(k, 0) + 1
    lines.append("")
    lines.append(f"Dominant-term histogram: `{doms}`.")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--update", action="store_true")
    ap.add_argument("--tag", default=None)
    args = ap.parse_args()
    md = markdown(args.tag)
    if args.update:
        exp = ROOT / "EXPERIMENTS.md"
        text = exp.read_text()
        pre, rest = text.split(BEGIN)
        _, post = rest.split(END)
        exp.write_text(pre + BEGIN + "\n" + md + "\n" + END + post)
        print(f"updated EXPERIMENTS.md with {len(rows(tag=args.tag))} rows")
    else:
        print(md)


if __name__ == "__main__":
    main()
