"""Table I fused-kernel microbenchmarks: wall-time of the jnp oracle path
(the dry-run execution path) on this host, plus the kernels' analytic VMEM
working sets. Real-TPU kernel timing is out of scope in this container; the
Pallas kernels are validated in interpret mode (tests/test_kernels.py)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.attn_stream import attn_stream_vmem_bytes
from repro.kernels.ffn_act import ffn_vmem_bytes


def _time(fn, *args, iters: int = 5) -> float:
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def main():
    print("\n# Table I — fused kernel microbench (jnp oracle path, host)")
    print("name,us_per_call,derived")
    key = jax.random.PRNGKey(0)
    B, H, S, D = 1, 8, 1024, 64
    q = jax.random.normal(key, (B, H, S, D), jnp.float32)
    k = jax.random.normal(key, (B, H, S, D), jnp.float32)
    v = jax.random.normal(key, (B, H, S, D), jnp.float32)
    us = _time(jax.jit(lambda a, b, c: ref.attn_stream_ref(a, b, c)),
               q, k, v)
    fl = 4 * B * H * S * S * D
    print(f"FUSED_ATTN_STREAM,{us:.0f},{fl / us * 1e-3:.2f}GFLOP/s_host"
          f"|vmem={attn_stream_vmem_bytes(128, 128, D) / 1024:.0f}KiB")

    M, Dm, F = 2048, 1024, 4096
    x = jax.random.normal(key, (M, Dm), jnp.float32)
    w1 = jax.random.normal(key, (Dm, F), jnp.float32) * 0.02
    wg = jax.random.normal(key, (Dm, F), jnp.float32) * 0.02
    w2 = jax.random.normal(key, (F, Dm), jnp.float32) * 0.02
    us = _time(jax.jit(lambda a, b, c, d: ref.ffn_act_ref(
        a, b, c, d, "silu_gated")), x, w1, wg, w2)
    fl = 2 * M * Dm * F * 3
    print(f"FUSED_FFN_ACT,{us:.0f},{fl / us * 1e-3:.2f}GFLOP/s_host"
          f"|vmem={ffn_vmem_bytes(128, 512, Dm) / 1024:.0f}KiB")

    w = jax.random.normal(key, (Dm, 3 * Dm), jnp.float32) * 0.02
    us = _time(jax.jit(lambda a, b: ref.qkv_proj_ref(a, b, None)), x, w)
    fl = 2 * M * Dm * 3 * Dm
    print(f"FUSED_QKV_PROJ,{us:.0f},{fl / us * 1e-3:.2f}GFLOP/s_host")

    s = jnp.ones((Dm,), jnp.float32)
    us = _time(jax.jit(lambda a, b: ref.fused_norm_ref(a, b, None, 'rms')),
               x, s)
    print(f"FUSED_NORM,{us:.0f},{M * Dm * 4 * 2 / us * 1e-3:.2f}GB/s_host")


if __name__ == "__main__":
    main()
