"""Benchmarks reproducing the paper's tables/figures from the analytical
simulator. Each function prints a CSV block and returns rows."""

from __future__ import annotations

from repro.configs.base import PAPER_MODELS, get_config
from repro.simulator import (
    CHIME, DRAM_ONLY, FACIL, JETSON_ORIN_NX, simulate)
from repro.simulator.chime_sim import Workload
from repro.simulator.hardware import TABLE_V_STATIC

PAPER_CLAIMS = {
    "speedup": (31.0, 54.0),
    "energy_eff": (113.0, 246.0),
    "chime_tps": (233.0, 533.0),
    "chime_tok_per_j": (116.5, 266.5),
    "jetson_tps": (7.4, 11.0),
    "dram_only_speedup": (2.38, 2.49),
    "dram_only_energy": (1.04, 1.07),
}


def fig6_speedup_energy():
    """Fig 6: speedup + energy efficiency vs Jetson Orin NX per model."""
    print("\n# Fig 6 — CHIME vs Jetson Orin NX "
          "(paper: 31-54x speedup, 113-246x energy eff)")
    print("model,chime_tps,chime_tok_per_j,chime_w,jetson_tps,"
          "jetson_tok_per_j,speedup_x,energy_eff_x")
    rows = []
    for m in PAPER_MODELS:
        cfg = get_config(m)
        c = simulate(cfg, CHIME)
        j = simulate(cfg, JETSON_ORIN_NX)
        row = dict(model=m, chime_tps=c.tps, chime_tok_per_j=c.tokens_per_j,
                   chime_w=c.avg_power_w, jetson_tps=j.tps,
                   jetson_tok_per_j=j.tokens_per_j,
                   speedup=j.total_s / c.total_s,
                   energy_eff=j.energy_j / c.energy_j)
        rows.append(row)
        print(f"{m},{c.tps:.1f},{c.tokens_per_j:.1f},{c.avg_power_w:.2f},"
              f"{j.tps:.1f},{j.tokens_per_j:.2f},{row['speedup']:.1f},"
              f"{row['energy_eff']:.1f}")
    sp = [r["speedup"] for r in rows]
    ee = [r["energy_eff"] for r in rows]
    print(f"# mean speedup {sum(sp) / len(sp):.1f}x (paper ~41x); "
          f"mean energy eff {sum(ee) / len(ee):.1f}x (paper ~185x)")
    return rows


def table5_platforms():
    """Table V: cross-platform comparison (FACIL rows are published)."""
    print("\n# Table V — edge AI platform comparison")
    print("platform,tps_range,tok_per_j_range,power_w,source")
    tps = []
    tpj = []
    for m in PAPER_MODELS:
        r = simulate(get_config(m), CHIME)
        tps.append(r.tps)
        tpj.append(r.tokens_per_j)
    print(f"CHIME (ours),{min(tps):.0f}-{max(tps):.0f},"
          f"{min(tpj):.1f}-{max(tpj):.1f},~2-6,simulated")
    for name, row in TABLE_V_STATIC.items():
        print(f"{name},{row['tps'][0]}-{row['tps'][1]},"
              f"{row['tok_per_j'][0]}-{row['tok_per_j'][1]},"
              f"{row['power_w']},published")
    fac_hi = FACIL["throughput_tps"][1]
    print(f"# CHIME vs FACIL throughput: {min(tps) / fac_hi:.1f}x - "
          f"{max(tps) / FACIL['throughput_tps'][0]:.1f}x "
          f"(paper: 12.1-69.2x)")
    return {"tps": tps, "tok_per_j": tpj}


def fig8_seqlen():
    """Fig 8: latency + energy vs input length 128..4k (linear growth)."""
    print("\n# Fig 8 — sequence-length sensitivity (CHIME)")
    print("model,text_tokens,latency_ms,energy_j")
    rows = []
    for m in PAPER_MODELS:
        cfg = get_config(m)
        for n in (128, 256, 512, 1024, 2048, 4096):
            r = simulate(cfg, CHIME, Workload(text_tokens=n))
            rows.append((m, n, r.total_s * 1e3, r.energy_j))
            print(f"{m},{n},{r.total_s * 1e3:.1f},{r.energy_j:.3f}")
    # linearity check: latency(4k)/latency(128) should be O(10) not O(1000)
    for m in PAPER_MODELS:
        sub = [r for r in rows if r[0] == m]
        ratio = sub[-1][2] / sub[0][2]
        print(f"# {m}: 128->4k latency ratio {ratio:.1f}x "
              "(paper: ~order of magnitude, linear-ish)")
    return rows


def fig9_memconfig():
    """Fig 9: CHIME vs M3D-DRAM-only (paper: 2.38-2.49x speedup,
    1.04-1.07x energy)."""
    print("\n# Fig 9 — heterogeneous vs DRAM-only")
    print("model,speedup_x,energy_eff_x")
    rows = []
    for m in PAPER_MODELS:
        cfg = get_config(m)
        c = simulate(cfg, CHIME)
        d = simulate(cfg, DRAM_ONLY)
        rows.append((m, d.total_s / c.total_s, d.energy_j / c.energy_j))
        print(f"{m},{rows[-1][1]:.2f},{rows[-1][2]:.2f}")
    return rows


def fig7_breakdown():
    """Fig 7(c)/(d): power/time breakdown — which domain dominates."""
    print("\n# Fig 7 — per-domain decode-time breakdown (CHIME)")
    print("model,dram_ms_tok,attn_kv_ms_tok,rram_ms_tok,ucie_ms_tok,"
          "overhead_ms_tok")
    for m in ("fastvlm-0.6b", "mobilevlm-1.7b"):
        cfg = get_config(m)
        r = simulate(cfg, CHIME)
        n = 488
        b = r.breakdown
        print(f"{m},{b['dram_s'] / n * 1e3:.3f},"
              f"{b['attn_kv_s'] / n * 1e3:.3f},"
              f"{b['rram_s'] / n * 1e3:.3f},{b['ucie_s'] / n * 1e3:.3f},"
              f"{b['overhead_s'] / n * 1e3:.3f}")
        dom = "rram" if b["rram_s"] > b["dram_s"] else "dram"
        print(f"# {m}: {dom} dominates (paper: RRAM dominates — it runs "
              "the data-intensive FFN)")


def validate_against_claims() -> dict:
    """Machine-checkable validation summary for EXPERIMENTS.md."""
    res = {}
    sp, ee, ct, cj, jt = [], [], [], [], []
    do_s, do_e = [], []
    for m in PAPER_MODELS:
        cfg = get_config(m)
        c = simulate(cfg, CHIME)
        j = simulate(cfg, JETSON_ORIN_NX)
        d = simulate(cfg, DRAM_ONLY)
        sp.append(j.total_s / c.total_s)
        ee.append(j.energy_j / c.energy_j)
        ct.append(c.tps)
        cj.append(c.tokens_per_j)
        jt.append(j.tps)
        do_s.append(d.total_s / c.total_s)
        do_e.append(d.energy_j / c.energy_j)

    def band(x):
        return (min(x), max(x))
    res["speedup"] = band(sp)
    res["energy_eff"] = band(ee)
    res["chime_tps"] = band(ct)
    res["chime_tok_per_j"] = band(cj)
    res["jetson_tps"] = band(jt)
    res["dram_only_speedup"] = band(do_s)
    res["dram_only_energy"] = band(do_e)
    print("\n# Validation vs paper claims")
    print("metric,ours,paper")
    for k, v in res.items():
        pc = PAPER_CLAIMS[k]
        print(f"{k},{v[0]:.2f}-{v[1]:.2f},{pc[0]}-{pc[1]}")
    return res


def main():
    fig6_speedup_energy()
    table5_platforms()
    fig8_seqlen()
    fig9_memconfig()
    fig7_breakdown()
    validate_against_claims()


if __name__ == "__main__":
    main()
