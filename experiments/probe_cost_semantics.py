import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding

mesh = jax.make_mesh((2, 4), ("data", "model"))
N = 512

def plain(x, w):
    return x @ w

def scanned(x, ws):
    def body(c, w):
        return c @ w, None
    y, _ = jax.lax.scan(body, x, ws)
    return y

with mesh:
    xs = jax.ShapeDtypeStruct((N, N), jnp.float32)
    ws = jax.ShapeDtypeStruct((N, N), jnp.float32)
    wss = jax.ShapeDtypeStruct((10, N, N), jnp.float32)
    sh = NamedSharding(mesh, P("data", "model"))
    c1 = jax.jit(plain, in_shardings=(sh, None)).lower(xs, ws).compile()
    c2 = jax.jit(scanned, in_shardings=(sh, None)).lower(xs, wss).compile()
    f1 = c1.cost_analysis()["flops"]
    f2 = c2.cost_analysis()["flops"]
    print("plain flops:", f1, "expected/dev:", 2 * N**3 / 8)
    print("scan x10 flops:", f2, "ratio scan/plain:", f2 / f1)
    print("plain bytes:", c1.cost_analysis()["bytes accessed"])
    print("scan bytes:", c2.cost_analysis()["bytes accessed"])
    m2 = c2.memory_analysis()
    print("scan temp bytes:", m2.temp_size_in_bytes,
          "arg:", m2.argument_size_in_bytes)
