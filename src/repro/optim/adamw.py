"""AdamW with production knobs:

* configurable optimizer-state dtype (bf16 states fit nemotron-340B +
  optimizer in 16 GB/chip HBM at 512 chips — an 8-bit-Adam-style
  distributed-optimization trick),
* global-norm clipping,
* warmup+cosine schedule,
* optional int8 gradient compression hook for the cross-pod all-reduce
  (used by the shard_map training variant in runtime/overlap.py).

Pure-pytree implementation (no optax dependency in this offline container).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"      # "bfloat16" for huge models
    warmup_steps: int = 100
    total_steps: int = 10_000


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    mu: Any
    nu: Any
    step: jax.Array


def adamw_init(params, cfg: AdamWConfig) -> TrainState:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)  # noqa: E731
    return TrainState(
        params=params,
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def clip_by_global_norm(grads, max_norm: float):
    gnorm = jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), \
        gnorm


def adamw_update(state: TrainState, grads, cfg: AdamWConfig
                 ) -> tuple[TrainState, dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    sdt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = m.astype(jnp.float32) * b1 + gf * (1 - b1)
        vf = v.astype(jnp.float32) * b2 + jnp.square(gf) * (1 - b2)
        mhat = mf / (1 - b1 ** step.astype(jnp.float32))
        vhat = vf / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (delta + cfg.weight_decay * pf)
        return pf.astype(p.dtype), mf.astype(sdt), vf.astype(sdt)

    flat_p = jax.tree.leaves(state.params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    treedef = jax.tree.structure(state.params)
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return TrainState(new_p, new_m, new_v, step), metrics
