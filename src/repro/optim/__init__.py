from repro.optim.adamw import (  # noqa: F401
    AdamWConfig, TrainState, adamw_init, adamw_update, clip_by_global_norm,
    lr_schedule,
)
