"""Hardware models for the CHIME analytical simulator (paper §IV-A2).

Device parameters are from Tables III/IV of the paper. Two constants are
*calibrated* (the paper's in-house simulator is not public):

* ``internal_bw`` — near-memory streaming bandwidth seen by the NMP.
  For M3D DRAM the paper exposes 16 channels x 16 banks with 32 Kb row
  buffers over dense MIVs; we model 1.6 TB/s aggregate (≈100 GB/s/channel
  via vertical MIV stitching — the M3D selling point vs ~8 GB/s/channel
  external DDR pins). For M3D RRAM the 512 GB/s figure in Table III is the
  controller interface; per-tile H-trees (64 per tile, 256 macros) feed
  the PU cluster at an aggregate we model as 1.28 TB/s.
* ``layer_overhead_s`` — per-transformer-layer serialization residual
  (row-activation chains, tier access latency 3+0.8L ns, SFPE softmax
  serialization, UCIe hop). Calibrated at 45 µs so absolute TPS for the
  4 evaluated models lands in the paper's reported 233-533 tok/s band;
  the *relative* trends (model scaling, heterogeneous-vs-DRAM-only,
  sequence-length linearity) come out of the first-principles terms.

Energy: DRAM 0.429 pJ/bit R/W (Table IV); RRAM 0.4 pJ/bit read, 1.33 pJ/bit
write (Table III); UCIe 0.6 pJ/bit [ISSCC'25 ref 23]; compute 0.3 pJ/FLOP
at 7 nm FP16; static = peak power of each NMP die.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MemoryDomain:
    name: str
    internal_bw: float          # B/s seen by near-memory compute
    peak_flops: float           # NMP FLOP/s
    read_energy_pj_bit: float
    write_energy_pj_bit: float
    static_power_w: float
    capacity_bytes: float


@dataclasses.dataclass(frozen=True)
class Platform:
    name: str
    domains: dict[str, MemoryDomain]
    cross_domain_bw: float      # UCIe B/s (0 => single domain)
    cross_domain_pj_bit: float
    layer_overhead_s: float
    compute_pj_flop: float
    # for monolithic (GPU-style) platforms
    fixed_token_overhead_s: float = 0.0
    power_w: float | None = None


M3D_DRAM = MemoryDomain(
    name="m3d_dram",
    internal_bw=1.6e12,
    peak_flops=2e12,            # Table IV: 2 TFLOPS FP16
    read_energy_pj_bit=0.429,   # Table IV
    write_energy_pj_bit=0.429,
    static_power_w=0.671,       # Table IV peak power
    capacity_bytes=6.25e9,      # 5 tiers x 1.25 GB
)

M3D_RRAM = MemoryDomain(
    name="m3d_rram",
    internal_bw=1.6e12,
    peak_flops=32e12,           # Table III: 32 TFLOPS
    read_energy_pj_bit=0.4,     # Table III
    write_energy_pj_bit=1.33,
    static_power_w=2.584,       # Table III peak power
    capacity_bytes=2e9,
)

CHIME = Platform(
    name="CHIME",
    domains={"dram": M3D_DRAM, "rram": M3D_RRAM},
    cross_domain_bw=128e9,      # UCIe x64 @ 32 GT/s [23]
    cross_domain_pj_bit=0.6,    # [23]
    layer_overhead_s=45e-6,     # calibrated — see module docstring
    compute_pj_flop=0.3,
)

# Fig. 9 ablation: FFN lives in (a second) M3D DRAM stack; attention and
# FFN contend for DRAM bandwidth and the FFN runs on the 2 TFLOPS DRAM NMP.
DRAM_ONLY = Platform(
    name="M3D-DRAM-only",
    domains={"dram": dataclasses.replace(
                 M3D_DRAM, internal_bw=0.8e12),
             "rram": dataclasses.replace(
                 M3D_DRAM, name="m3d_dram_ffn",
                 # FFN shares the one stack: attention traffic contends
                 # (both kernel classes see ~half the stream bandwidth),
                 # and the FFN weights spill to the upper, slower tiers of
                 # the 200-layer stack (read latency 3+0.8L ns/row) —
                 # paper: "FFN weights overwhelm DRAM-centric M3D DRAM".
                 internal_bw=0.4e12, peak_flops=2e12)},
    cross_domain_bw=0.0,
    cross_domain_pj_bit=0.0,
    layer_overhead_s=45e-6,
    compute_pj_flop=0.3,
)

JETSON_ORIN_NX = Platform(
    name="Jetson Orin NX",
    domains={"dram": MemoryDomain(
        name="lpddr5",
        internal_bw=102.4e9 * 0.85,   # datasheet BW x streaming util
        peak_flops=17e12,             # FP16 dense
        read_energy_pj_bit=18.0,      # off-chip LPDDR5 access
        write_energy_pj_bit=18.0,
        static_power_w=8.0,
        capacity_bytes=16e9,
    )},
    cross_domain_bw=0.0,
    cross_domain_pj_bit=0.0,
    layer_overhead_s=0.0,
    compute_pj_flop=1.3,              # 8 nm GPU
    # measured edge-stack dispatch/graph-launch overhead per token
    # (calibrated so TPS spans the paper's narrow 7.4-11 band across
    # 0.6B-3B — small models are overhead-bound on Jetson, which is
    # exactly the paper's motivation)
    fixed_token_overhead_s=80e-3,
    power_w=10.0,
)

# background controller + UCIe PHY power while the accelerator is active
# (paper Fig. 7: "the UCIe link draws about 1 W")
CHIME_UNCORE_W = 1.0

# FACIL [30] is compared via its published Table V numbers, not simulated.
FACIL = {
    "name": "FACIL",
    "throughput_tps": (7.7, 19.3),
    "power_w": (5.7, 38.5),
    "energy_token_j": (0.50, 1.35),
    "die_area_mm2": 200.0,
}

# Table V context rows
TABLE_V_STATIC = {
    "Jetson Orin NX": {"node_nm": 8, "freq_ghz": 0.92, "area_mm2": 200.0,
                       "power_w": (10, 40), "tps": (7.4, 11),
                       "tok_per_j": (0.28, 0.74)},
    "FACIL": {"node_nm": 15, "freq_ghz": 3.2, "area_mm2": 200.0,
              "power_w": (5.7, 38.5), "tps": (7.7, 19.3),
              "tok_per_j": (0.50, 1.35)},
    "CHIME (paper)": {"node_nm": (28, 35), "freq_ghz": 1.0,
                      "area_mm2": (28.71, 24.85), "power_w": 2.0,
                      "tps": (233, 533), "tok_per_j": (116.5, 266.5)},
}
