from repro.simulator.chime_sim import simulate  # noqa: F401
from repro.simulator.hardware import (  # noqa: F401
    CHIME, DRAM_ONLY, FACIL, JETSON_ORIN_NX, Platform)
