"""CHIME analytical simulator — the paper-fidelity instrument (§IV).

Simulates end-to-end VQA inference (image -> visual tokens -> prefill ->
decode) per platform, at the granularity of the fused kernels in Table I,
with operator placement taken from the SAME MappingPlan the JAX runtime
executes (core/planner.py). Per kernel:

    t = max(flops / domain.peak_flops, bytes / domain.internal_bw)
    e = bytes * read_pj_bit + flops * pj_flop (+ write energy for KV/cut
        tensors, + UCIe energy at the two cut points)

Decode is sequential per the paper's dataflow: attention(t+1) waits for
FFN(t); exactly AttnOut/FFNOut cross UCIe per layer.
"""

from __future__ import annotations

import dataclasses
import math
import typing

from repro.configs.base import ModelConfig
from repro.core.planner import plan_for
from repro.models.counting import (kv_bytes_per_token, param_dtype_bytes,
                                   streamed_unit_indices)
from repro.simulator.hardware import CHIME, Platform


@dataclasses.dataclass
class Workload:
    text_tokens: int = 128
    output_tokens: int = 488
    image: bool = True            # 512x512 astronaut (paper default)


@dataclasses.dataclass
class SimResult:
    platform: str
    model: str
    prefill_s: float
    decode_s: float
    total_s: float
    energy_j: float
    tps: float                    # output tokens / total time
    tokens_per_j: float
    avg_power_w: float
    breakdown: dict


def _layer_kernels(cfg: ModelConfig) -> list[dict]:
    """Per-layer fused kernels with per-token flops/bytes (decode GEMV).
    Layers of a streamed scan unit (``cfg.weight_stream_layers``) carry
    ``streamed=True`` so the weight-stream pricing knows whose projection
    weights live in the RRAM tier."""
    D = cfg.d_model
    streamed = set(streamed_unit_indices(cfg))
    out = []
    for uidx, unit_plan in enumerate(plan_for(cfg).layers):
        for _ in range(unit_plan.repeats):
            kerns = []
            if unit_plan.mixer in ("attn", "attn_shared"):
                qkv = D * (cfg.num_heads + 2 * cfg.num_kv_heads) \
                    * cfg.head_dim
                o = cfg.num_heads * cfg.head_dim * D
                kerns.append(("FUSED_QKV_PROJ", "dram", 2 * qkv, 2 * qkv))
                kerns.append(("ATTN_OUT_PROJ", "dram", 2 * o, 2 * o))
                kerns.append(("FUSED_ATTN_STREAM", "dram", 0, 0))  # KV below
            elif unit_plan.mixer == "mla":
                m = cfg.mla
                qk = m.qk_nope_head_dim + m.qk_rope_head_dim
                w = (D * cfg.num_heads * qk + D * m.kv_lora_rank
                     + D * m.qk_rope_head_dim
                     + m.kv_lora_rank * cfg.num_heads
                     * (m.qk_nope_head_dim + m.v_head_dim)
                     + cfg.num_heads * m.v_head_dim * D)
                kerns.append(("MLA_PROJ", "dram", 2 * w, 2 * w))
                kerns.append(("FUSED_ATTN_STREAM", "dram", 0, 0))
            elif unit_plan.mixer == "rwkv6":
                w = 3 * D * D + D * D + D * D
                kerns.append(("RWKV6_TIMEMIX", "dram", 2 * w, 2 * w))
            elif unit_plan.mixer == "mamba2":
                d_inner = cfg.ssm.expand * D
                w = D * (2 * d_inner + 2 * cfg.ssm.state_dim) + d_inner * D
                kerns.append(("MAMBA2_SSD", "dram", 2 * w, 2 * w))
            blk = unit_plan
            has_ffn = any(p.op in ("ffn", "moe_ffn", "channel_mix")
                          for p in blk.placements)
            if has_ffn:
                if cfg.mlp_type == "moe" and cfg.moe:
                    m = cfg.moe
                    w = m.top_k * 3 * D * m.d_ff_expert \
                        + m.num_shared_experts * 3 * D * (
                            m.d_ff_shared or m.d_ff_expert)
                elif cfg.mlp_type in ("silu_gated", "gelu_gated"):
                    w = 3 * D * cfg.d_ff
                elif cfg.mlp_type == "rwkv_cm":
                    w = 2 * D * cfg.d_ff + D * D
                else:
                    w = 2 * D * cfg.d_ff
                kerns.append(("FUSED_FFN_ACT", "rram", 2 * w, 2 * w))
            out.append({"kernels": kerns,
                        "has_attn": unit_plan.mixer in (
                            "attn", "attn_shared", "mla"),
                        "has_ffn": has_ffn,
                        "streamed": uidx in streamed})
    return out


def _kernel_time_energy(domain, flops: float, bytes_r: float,
                        pj_flop: float, weight_dtype_bytes: float = 2.0
                        ) -> tuple[float, float]:
    """Time/energy of one kernel on ``domain``. The kernel table's static
    byte counts assume bf16 weights; ``weight_dtype_bytes`` rescales them
    to the stored dtype (1.0 for int8, 4.0 for f32) before pricing."""
    bytes_r = bytes_r * (weight_dtype_bytes / 2.0)
    t = max(flops / domain.peak_flops, bytes_r / domain.internal_bw)
    e = bytes_r * 8 * domain.read_energy_pj_bit * 1e-12 \
        + flops * pj_flop * 1e-12
    return t, e


def visual_tokens(cfg: ModelConfig) -> int:
    return cfg.frontend.num_tokens if cfg.frontend else 0


class CostTerm(typing.NamedTuple):
    """One atomic priced event: a kernel's memory stream, its MACs, a UCIe
    cut, a KV append write, a spill transfer, or a closing static-power
    charge. Every simulated cost in this module decomposes into a flat
    list of these, and every aggregate is a `math.fsum` over them — fsum
    is correctly rounded and therefore order-independent, so two code
    paths that price the SAME multiset of terms (e.g. the serving
    telemetry ledger step-by-step vs. `simulated_efficiency` end-of-run)
    produce bitwise-identical totals."""

    name: str
    domain: str        # dram|rram|compute|ucie|kv_write|overhead|encoder
    #                  # |spill|prefix|static|skipped|weight_stream
    time_s: float
    energy_j: float
    bytes_moved: float


# Deterministic priced skip fraction of the cold-tier read under the
# SLIM-style sparse read (sparse_tau > 0). The kernel's measured skip rate
# is data-dependent (it compares per-page score upper bounds against the
# live running max), which an analytical cost model cannot see; the ledger
# and `simulated_efficiency` both price this MODELED fraction of the cold
# bytes as skipped (zero time, zero energy, bytes under the `skipped`
# domain) so the two stay reconciled bit-for-bit. Benchmarks report the
# modeled figure — README documents the contract.
SPARSE_READ_PRICED_SKIP = 0.5


def _hot_itemsize(cfg: ModelConfig) -> int:
    """Bytes per hot-ring element (the telemetry ledger's accounting)."""
    if cfg.compute_dtype == "bfloat16":
        return 2
    import numpy as np
    return int(np.dtype(cfg.compute_dtype).itemsize)


def cost_layers(cfg: ModelConfig) -> list[dict]:
    """Public handle on the per-layer fused-kernel table so callers that
    price many events (the telemetry ledger, `simulated_efficiency`) can
    plan once and share."""
    return _layer_kernels(cfg)


def _kernel_terms(name: str, dom_name: str, dom, flops: float,
                  bytes_r: float, pj_flop: float) -> list[CostTerm]:
    """A fused kernel as two terms: the near-memory stream (carries the
    kernel's time and byte energy on its home domain) and the MACs
    (energy only, attributed to `compute`)."""
    t = max(flops / dom.peak_flops, bytes_r / dom.internal_bw)
    return [
        CostTerm(name, dom_name, t,
                 bytes_r * 8 * dom.read_energy_pj_bit * 1e-12,
                 float(bytes_r)),
        CostTerm(name + "/mac", "compute", 0.0,
                 flops * pj_flop * 1e-12, 0.0),
    ]


def _layer_weight_raw_bytes(lay: dict) -> float:
    """One layer's static projection-weight bytes as the kernel table
    states them (bf16): the DRAM-domain weight kernels. FFN weights are
    excluded — they already live beside the RRAM near-memory compute and
    never cross a tier. The attention KV stream is not a weight read."""
    return float(sum(b for (name, dom, _f, b) in lay["kernels"]
                     if dom == "dram" and name != "FUSED_ATTN_STREAM"))


def layer_stream_bytes(cfg: ModelConfig, lay: dict) -> float:
    """Dtype-correct bytes of ONE streamed layer's RRAM weight read,
    rescaled from the kernel table's bf16 assumption to the stored
    param dtype."""
    return _layer_weight_raw_bytes(lay) * (param_dtype_bytes(cfg) / 2.0)


def weight_stream_layer_terms(cfg: ModelConfig, platform: Platform,
                              lay: dict, hide_s: float) -> list[CostTerm]:
    """The weight-stream cost of ONE streamed layer in one step: the RRAM
    read of the layer's projection-weight slice (dtype-correct via the
    honored `_kernel_time_energy`) plus its UCIe hop into the DRAM
    prefetch window. The layer-ahead prefetch overlaps the fetch with the
    layer's own compute/stream time (``hide_s``), so only the residual
    stall carries time; the read/transfer ENERGY is paid in full —
    overlap hides latency, not joules. The UCIe term carries zero bytes
    (the read term already counts the slice once — the spill ``/ucie``
    convention)."""
    rram = platform.domains.get("rram", platform.domains["dram"])
    raw = _layer_weight_raw_bytes(lay)
    wdt = float(param_dtype_bytes(cfg))
    rt, re = _kernel_time_energy(rram, 0.0, raw, platform.compute_pj_flop,
                                 weight_dtype_bytes=wdt)
    wb = raw * (wdt / 2.0)
    hop_t = hop_e = 0.0
    if platform.cross_domain_bw:
        hop_t = wb / platform.cross_domain_bw
        hop_e = wb * 8 * platform.cross_domain_pj_bit * 1e-12
    stall = max(0.0, rt + hop_t - hide_s)
    terms = [CostTerm("WEIGHT_STREAM", "weight_stream", stall, re, wb)]
    if hop_e:
        terms.append(CostTerm("WEIGHT_STREAM/ucie", "weight_stream",
                              0.0, hop_e, 0.0))
    return terms


def decode_token_terms(cfg: ModelConfig, platform: Platform, ctx: int,
                       layers: list[dict] | None = None,
                       fused: bool = False,
                       sparse_tau: float = 0.0,
                       weight_stream: bool = False) -> list[CostTerm]:
    """The cost terms of ONE decode step at context length ``ctx``.

    ``fused`` prices the fused paged-decode kernel over a tiered store:
    the hot ring streams full-precision from DRAM while the cold pages
    stream int8 (+ f32 scales) from the RRAM tier — exactly the byte
    split the telemetry ledger's hot/cold row counters report, so the
    two reconcile. With ``sparse_tau`` > 0 the modeled
    `SPARSE_READ_PRICED_SKIP` fraction of the cold bytes moves to a
    zero-cost `skipped` term. A fused FLAT store touches the same bytes
    as the unfused path and is priced identically.

    ``weight_stream`` adds, per layer flagged ``streamed`` in the table,
    the RRAM weight-read + UCIe terms of `weight_stream_layer_terms` —
    the projection-weight slice fetched into the DRAM window every step
    (the window is transit storage, so a streamed unit refetches all its
    repeats per token). The resident kernels are left untouched: the
    compute side still reads the staged slice from DRAM exactly as the
    resident model does, so streamed pricing is resident + fetch."""
    if layers is None:
        layers = _layer_kernels(cfg)
    n_layers = len(layers)
    dram = platform.domains["dram"]
    rram = platform.domains["rram"] if "rram" in platform.domains else dram
    D = cfg.d_model
    ucie_t_per_cut = (2 * D / platform.cross_domain_bw
                      if platform.cross_domain_bw else 0.0)
    ucie_e_per_cut = (2 * D * 8 * platform.cross_domain_pj_bit * 1e-12
                      if platform.cross_domain_bw else 0.0)
    kv_tok = kv_bytes_per_token(cfg)
    n_attn = max(sum(1 for l in layers if l["has_attn"]), 1)
    fused_tiered = fused and cfg.kv_policy == "tiered"
    if fused_tiered:
        from repro.models.counting import (kv_elems_per_token,
                                           kv_scale_elems_per_token)
        W = cfg.kv_hot_window
        hot_b = kv_elems_per_token(cfg) * min(ctx, W) * _hot_itemsize(cfg)
        cold_b = max(ctx - W, 0) * (kv_elems_per_token(cfg)
                                    + 4 * kv_scale_elems_per_token(cfg))
        skip_b = cold_b * SPARSE_READ_PRICED_SKIP if sparse_tau > 0 else 0.0
        touched_b = cold_b - skip_b
    terms: list[CostTerm] = []
    for lay in layers:
        lay_start = len(terms)
        for name, dom_name, flops, bytes_r in lay["kernels"]:
            dom = dram if dom_name == "dram" else rram
            if name == "FUSED_ATTN_STREAM":
                if fused_tiered:
                    hb, cb, sb = (hot_b / n_attn, touched_b / n_attn,
                                  skip_b / n_attn)
                    terms += _kernel_terms(
                        "FUSED_PAGED_DECODE", "dram", dram, hb, hb,
                        platform.compute_pj_flop)
                    terms += _kernel_terms(
                        "FUSED_PAGED_DECODE/cold", "rram", rram, cb, cb,
                        platform.compute_pj_flop)
                    if sb:
                        terms.append(CostTerm(
                            "FUSED_PAGED_DECODE/skip", "skipped",
                            0.0, 0.0, sb))
                    continue
                # stream the KV cache for this layer
                bytes_r = kv_tok / n_attn * ctx
                flops = bytes_r  # ~1 MAC per cached byte at fp16
            terms += _kernel_terms(name, dom_name, dom, flops, bytes_r,
                                   platform.compute_pj_flop)
        if lay["has_ffn"]:
            # AttnOut -> RRAM and FFNOut -> DRAM cross UCIe (2 cuts)
            terms.append(CostTerm(
                "UCIE_CUT", "ucie", 2 * ucie_t_per_cut, 2 * ucie_e_per_cut,
                2 * 2 * D if platform.cross_domain_bw else 0.0))
        # KV append write energy (DRAM tier-0; write-once discipline)
        terms.append(CostTerm(
            "KV_APPEND", "kv_write", 0.0,
            kv_tok / max(n_layers, 1) * 8
            * dram.write_energy_pj_bit * 1e-12,
            kv_tok / max(n_layers, 1)))
        if weight_stream and lay.get("streamed"):
            hide = math.fsum(t.time_s for t in terms[lay_start:])
            terms += weight_stream_layer_terms(cfg, platform, lay, hide)
    terms.append(CostTerm(
        "STEP_OVERHEAD", "overhead",
        platform.layer_overhead_s * n_layers
        + platform.fixed_token_overhead_s, 0.0, 0.0))
    return terms


def prefill_terms(cfg: ModelConfig, platform: Platform, text_tokens: int,
                  image: bool,
                  layers: list[dict] | None = None,
                  cached_prefix: int = 0,
                  weight_stream: bool = False) -> list[CostTerm]:
    """The cost terms of one whole-prompt prefill (weights read once per
    layer and reused across prompt tokens; compute scales with prompt).

    ``cached_prefix`` > 0 prices a prefix-cache hit: only the
    ``prompt - cached_prefix`` tail tokens run through the projection /
    mixer kernels (the hit positions' KV is adopted from the shared
    block store — priced separately by `prefix_adopt_terms`), while the
    attention stream still reads the FULL prompt's KV for the tail's
    attention. ``cached_prefix=0`` is term-for-term identical to the
    historical whole-prompt pricing.

    ``weight_stream`` adds one `weight_stream_layer_terms` fetch per
    streamed layer (weights cross the tier once per prefill, whatever
    the prompt length — the same read-once shape as the resident
    kernels). Chunked prefills are priced whole-prompt at commit, so the
    fetch is charged exactly once per request either way."""
    if layers is None:
        layers = _layer_kernels(cfg)
    n_layers = len(layers)
    dram = platform.domains["dram"]
    rram = platform.domains["rram"] if "rram" in platform.domains else dram
    D = cfg.d_model
    vis = visual_tokens(cfg) if image else 0
    prompt = vis + text_tokens
    cached = min(max(int(cached_prefix), 0), prompt)
    tail = prompt - cached
    kv_tok = kv_bytes_per_token(cfg)
    terms: list[CostTerm] = []
    for lay in layers:
        lay_start = len(terms)
        for name, dom_name, flops, bytes_r in lay["kernels"]:
            dom = dram if dom_name == "dram" else rram
            if name == "FUSED_ATTN_STREAM":
                flops = 2.0 * tail * prompt * D
                bytes_r = prompt * kv_tok / max(n_layers, 1)
            else:
                flops = flops * tail
            terms += _kernel_terms(name, dom_name, dom, flops, bytes_r,
                                   platform.compute_pj_flop)
        if weight_stream and lay.get("streamed"):
            hide = math.fsum(t.time_s for t in terms[lay_start:])
            terms += weight_stream_layer_terms(cfg, platform, lay, hide)
    # vision encoder stub cost: FastViT/ViT on 512^2 ~ 10-40 GFLOP.
    # A cache hit covering the whole visual span skips the encoder —
    # the shared image was encoded when its blocks were registered.
    if image and cfg.frontend is not None and cached < vis:
        enc_flops = 20e9
        terms.append(CostTerm(
            "VISION_ENCODER", "encoder", enc_flops / dram.peak_flops,
            enc_flops * platform.compute_pj_flop * 1e-12, 0.0))
    terms.append(CostTerm(
        "PREFILL_OVERHEAD", "overhead",
        platform.layer_overhead_s * n_layers
        + platform.fixed_token_overhead_s, 0.0, 0.0))
    return terms


def spill_terms(cfg: ModelConfig, platform: Platform, ctx: int,
                restore: bool = False,
                compressed: bool = False) -> list[CostTerm]:
    """The cost terms of moving ONE request's ``ctx``-token KV image
    between the DRAM stack and the RRAM spill store across UCIe — the
    RRAM write (spill) or read (restore) plus the UCIe transfer, both
    under the `spill` domain so spill traffic stays separable from model
    compute in every energy split."""
    per_tok = kv_bytes_per_token(cfg)
    if compressed and cfg.kv_policy == "tiered":
        from repro.models.counting import (kv_elems_per_token,
                                           kv_scale_elems_per_token)
        per_tok = kv_elems_per_token(cfg) \
            + 4 * kv_scale_elems_per_token(cfg)
    kv_bytes = per_tok * max(ctx, 0)
    rram = platform.domains.get("rram", platform.domains["dram"])
    bw = rram.internal_bw
    ucie_e = 0.0
    if platform.cross_domain_bw:
        bw = min(bw, platform.cross_domain_bw)
        ucie_e = kv_bytes * 8 * platform.cross_domain_pj_bit * 1e-12
    pj_bit = (rram.read_energy_pj_bit if restore
              else rram.write_energy_pj_bit)
    name = "KV_RESTORE" if restore else "KV_SPILL"
    terms = [CostTerm(name, "spill", kv_bytes / bw if bw else 0.0,
                      kv_bytes * 8 * pj_bit * 1e-12, float(kv_bytes))]
    if ucie_e:
        terms.append(CostTerm(name + "/ucie", "spill", 0.0, ucie_e, 0.0))
    return terms


def prefix_adopt_terms(cfg: ModelConfig, platform: Platform,
                       tokens: int) -> list[CostTerm]:
    """The cost terms of gathering ``tokens`` cached prefix positions
    from the shared RRAM-resident block store into a fresh prefill
    workspace on admission — the traffic a prefix-cache hit pays INSTEAD
    of recomputing those positions. Priced like a spill restore (RRAM
    read + UCIe transfer, bounded by the slower link) but under its own
    ``prefix`` domain so skipped-prefill traffic stays separable in
    every energy split."""
    kv_bytes = kv_bytes_per_token(cfg) * max(int(tokens), 0)
    rram = platform.domains.get("rram", platform.domains["dram"])
    bw = rram.internal_bw
    ucie_e = 0.0
    if platform.cross_domain_bw:
        bw = min(bw, platform.cross_domain_bw)
        ucie_e = kv_bytes * 8 * platform.cross_domain_pj_bit * 1e-12
    terms = [CostTerm("PREFIX_ADOPT", "prefix",
                      kv_bytes / bw if bw else 0.0,
                      kv_bytes * 8 * rram.read_energy_pj_bit * 1e-12,
                      float(kv_bytes))]
    if ucie_e:
        terms.append(CostTerm("PREFIX_ADOPT/ucie", "prefix", 0.0,
                              ucie_e, 0.0))
    return terms


def closing_terms(platform: Platform,
                  terms: list[CostTerm]) -> list[CostTerm]:
    """Static/uncore power charges that close out a priced term stream.

    Monolithic platforms (``power_w`` set) charge board power over the
    whole busy wall; the chiplet platform duty-cycles NMP static power
    over each domain's busy time plus the always-on uncore (paper Fig. 7:
    ~1 W). Spill- and prefix-domain terms are excluded — that traffic
    happens off the critical decode path and `simulated_efficiency` has
    always priced it additively, outside the per-request closing
    charge."""
    total = math.fsum(t.time_s for t in terms
                      if t.domain not in ("spill", "prefix"))
    if platform.power_w is not None:
        return [CostTerm("BOARD_STATIC", "static", 0.0,
                         platform.power_w * total, 0.0)]
    from repro.simulator.hardware import CHIME_UNCORE_W
    dram = platform.domains["dram"]
    rram = platform.domains.get("rram", dram)
    busy_d = math.fsum(t.time_s for t in terms if t.domain == "dram")
    busy_r = math.fsum(t.time_s for t in terms if t.domain == "rram")
    return [
        CostTerm("DRAM_STATIC", "static", 0.0,
                 dram.static_power_w * busy_d, 0.0),
        CostTerm("RRAM_STATIC", "static", 0.0,
                 rram.static_power_w * busy_r, 0.0),
        CostTerm("UNCORE", "static", 0.0, CHIME_UNCORE_W * total, 0.0),
    ]


def request_terms(cfg: ModelConfig, platform: Platform, text_tokens: int,
                  output_tokens: int, image: bool,
                  layers: list[dict] | None = None,
                  cached_prefix: int = 0,
                  fused: bool = False,
                  sparse_tau: float = 0.0,
                  weight_stream: bool = False) -> list[CostTerm]:
    """Every cost term of one served request: prefill (tail-only when
    ``cached_prefix`` positions came from the shared prefix store, plus
    the adoption transfer), each decode step at its growing context, and
    the closing static charge — the unit `simulated_efficiency` and the
    telemetry ledger both sum. ``fused``/``sparse_tau`` select the fused
    paged-decode pricing for the decode steps (see `decode_token_terms`);
    ``weight_stream`` adds the RRAM weight-fetch terms of the streamed
    layers to prefill and every decode step."""
    if layers is None:
        layers = _layer_kernels(cfg)
    terms = prefill_terms(cfg, platform, text_tokens, image, layers,
                          cached_prefix=cached_prefix,
                          weight_stream=weight_stream)
    if cached_prefix > 0:
        terms += prefix_adopt_terms(cfg, platform, cached_prefix)
    prompt = (visual_tokens(cfg) if image else 0) + text_tokens
    for step in range(output_tokens):
        terms += decode_token_terms(cfg, platform, prompt + step, layers,
                                    fused=fused, sparse_tau=sparse_tau,
                                    weight_stream=weight_stream)
    terms += closing_terms(platform, terms)
    return terms


def sum_terms(terms: list[CostTerm]) -> dict:
    """Order-independent aggregate of a term stream: total simulated
    energy/time, the spill share, and the per-domain energy split. Both
    `simulated_efficiency` and the telemetry `TierLedger` report THIS —
    identical term multisets reconcile bit-for-bit."""
    split: dict[str, list[float]] = {}
    for tm in terms:
        split.setdefault(tm.domain, []).append(tm.energy_j)
    return {
        "sim_energy_j": math.fsum(tm.energy_j for tm in terms),
        "sim_total_s": math.fsum(tm.time_s for tm in terms),
        "sim_spill_energy_j": math.fsum(split.get("spill", ())),
        "sim_spill_s": math.fsum(tm.time_s for tm in terms
                                 if tm.domain == "spill"),
        "sim_energy_split_j": {d: math.fsum(v)
                               for d, v in sorted(split.items())},
    }


def decode_token_cost(cfg: ModelConfig, platform: Platform, ctx: int,
                      layers: list[dict] | None = None
                      ) -> tuple[float, float, dict]:
    """Analytical (time_s, energy_j, breakdown) of ONE decode step at
    context length ``ctx`` — the per-step cost term. `simulate` sums it
    over a growing context; the serving metrics feed it measured per-slot
    step counts instead. Backed by `decode_token_terms` — same multiset
    of priced events, folded into the legacy breakdown shape."""
    terms = decode_token_terms(cfg, platform, ctx, layers)
    tok_t = energy = 0.0
    br = {"dram_s": 0.0, "rram_s": 0.0, "attn_kv_s": 0.0, "ucie_s": 0.0,
          "busy_dram": 0.0, "busy_rram": 0.0}
    for tm in terms:
        tok_t += tm.time_s
        energy += tm.energy_j
        if tm.domain in ("dram", "rram"):
            br["busy_" + tm.domain] += tm.time_s
            if tm.name == "FUSED_ATTN_STREAM":
                br["attn_kv_s"] += tm.time_s
            elif tm.domain == "dram":
                br["dram_s"] += tm.time_s
            else:
                br["rram_s"] += tm.time_s
        elif tm.domain == "ucie":
            br["ucie_s"] += tm.time_s
    return tok_t, energy, br


def kv_spill_cost(cfg: ModelConfig, platform: Platform, ctx: int,
                  restore: bool = False,
                  compressed: bool = False) -> tuple[float, float]:
    """Analytical (time_s, energy_j) of moving ONE request's ``ctx``-token
    KV image between the DRAM stack and the RRAM spill store across UCIe
    — the per-event cost of a serving preemption or idle offload.
    Mirrors `decode_token_cost`'s terms: bytes from the same
    `kv_bytes_per_token` the capacity admission uses, time bounded by the
    slower of the UCIe link and the RRAM interface, energy from the RRAM
    write (spill) or read (restore) energy plus the UCIe transfer.
    ``compressed`` prices the int8 spill-lane codec instead: one byte per
    cached element plus the f32 per-(token, head) scales — the same byte
    math `serving.kv_pool.spill_lane_bytes` charges the RRAM budget. A
    flat (untiered) cache has no hot ring to compress, so its lanes are
    always verbatim and the flag is ignored (mirroring the backend).
    Backed by `spill_terms` — same priced events, folded to a pair."""
    terms = spill_terms(cfg, platform, ctx, restore=restore,
                        compressed=compressed)
    return (math.fsum(tm.time_s for tm in terms),
            math.fsum(tm.energy_j for tm in terms))


def simulate(cfg: ModelConfig, platform: Platform = CHIME,
             wl: Workload = Workload()) -> SimResult:
    layers = _layer_kernels(cfg)
    n_layers = len(layers)
    prompt = (visual_tokens(cfg) if wl.image else 0) + wl.text_tokens

    dram = platform.domains["dram"]
    rram = platform.domains["rram"] if "rram" in platform.domains else dram

    # ---- decode: per output token t (context grows) -------------------
    decode_s = 0.0
    energy = 0.0
    t_dram = t_rram = t_ucie = t_attn_kv = 0.0
    busy = {"dram": 0.0, "rram": 0.0}
    for step in range(wl.output_tokens):
        tok_t, tok_e, br = decode_token_cost(cfg, platform, prompt + step,
                                             layers)
        decode_s += tok_t
        energy += tok_e
        t_dram += br["dram_s"]
        t_rram += br["rram_s"]
        t_attn_kv += br["attn_kv_s"]
        t_ucie += br["ucie_s"]
        busy["dram"] += br["busy_dram"]
        busy["rram"] += br["busy_rram"]

    # ---- prefill (+ encoder/connector, paper: <15% of runtime) --------
    # weights read once per layer, reused across prompt tokens (batched
    # GEMM); compute scales with prompt length — priced by the same
    # `prefill_terms` the serving telemetry ledger records
    pre = prefill_terms(cfg, platform, wl.text_tokens, wl.image, layers)
    prefill_s = math.fsum(tm.time_s for tm in pre)
    energy += math.fsum(tm.energy_j for tm in pre)
    busy["dram"] += math.fsum(tm.time_s for tm in pre
                              if tm.domain == "dram")
    busy["rram"] += math.fsum(tm.time_s for tm in pre
                              if tm.domain == "rram")

    total = prefill_s + decode_s
    if platform.power_w is not None:
        # monolithic platform (GPU): board power over wall time
        energy += platform.power_w * total
    else:
        # chiplet platform: NMP dies power-gate when idle (duty-cycled
        # static power) + always-on uncore/UCIe (paper Fig. 7: ~1 W)
        from repro.simulator.hardware import CHIME_UNCORE_W
        energy += dram.static_power_w * busy["dram"] \
            + rram.static_power_w * busy["rram"] \
            + CHIME_UNCORE_W * total
    tps = wl.output_tokens / total
    return SimResult(
        platform=platform.name,
        model=cfg.name,
        prefill_s=prefill_s,
        decode_s=decode_s,
        total_s=total,
        energy_j=energy,
        tps=tps,
        tokens_per_j=wl.output_tokens / energy,
        avg_power_w=energy / total,
        breakdown={"dram_s": t_dram, "rram_s": t_rram,
                   "attn_kv_s": t_attn_kv, "ucie_s": t_ucie,
                   "overhead_s": platform.layer_overhead_s * n_layers
                   * wl.output_tokens
                   + platform.fixed_token_overhead_s * wl.output_tokens},
    )
