"""CHIME analytical simulator — the paper-fidelity instrument (§IV).

Simulates end-to-end VQA inference (image -> visual tokens -> prefill ->
decode) per platform, at the granularity of the fused kernels in Table I,
with operator placement taken from the SAME MappingPlan the JAX runtime
executes (core/planner.py). Per kernel:

    t = max(flops / domain.peak_flops, bytes / domain.internal_bw)
    e = bytes * read_pj_bit + flops * pj_flop (+ write energy for KV/cut
        tensors, + UCIe energy at the two cut points)

Decode is sequential per the paper's dataflow: attention(t+1) waits for
FFN(t); exactly AttnOut/FFNOut cross UCIe per layer.
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig
from repro.core.planner import plan_for
from repro.models.counting import kv_bytes_per_token
from repro.simulator.hardware import CHIME, Platform


@dataclasses.dataclass
class Workload:
    text_tokens: int = 128
    output_tokens: int = 488
    image: bool = True            # 512x512 astronaut (paper default)


@dataclasses.dataclass
class SimResult:
    platform: str
    model: str
    prefill_s: float
    decode_s: float
    total_s: float
    energy_j: float
    tps: float                    # output tokens / total time
    tokens_per_j: float
    avg_power_w: float
    breakdown: dict


def _layer_kernels(cfg: ModelConfig) -> list[dict]:
    """Per-layer fused kernels with per-token flops/bytes (decode GEMV)."""
    D = cfg.d_model
    out = []
    for unit_plan in plan_for(cfg).layers:
        for _ in range(unit_plan.repeats):
            kerns = []
            if unit_plan.mixer in ("attn", "attn_shared"):
                qkv = D * (cfg.num_heads + 2 * cfg.num_kv_heads) \
                    * cfg.head_dim
                o = cfg.num_heads * cfg.head_dim * D
                kerns.append(("FUSED_QKV_PROJ", "dram", 2 * qkv, 2 * qkv))
                kerns.append(("ATTN_OUT_PROJ", "dram", 2 * o, 2 * o))
                kerns.append(("FUSED_ATTN_STREAM", "dram", 0, 0))  # KV below
            elif unit_plan.mixer == "mla":
                m = cfg.mla
                qk = m.qk_nope_head_dim + m.qk_rope_head_dim
                w = (D * cfg.num_heads * qk + D * m.kv_lora_rank
                     + D * m.qk_rope_head_dim
                     + m.kv_lora_rank * cfg.num_heads
                     * (m.qk_nope_head_dim + m.v_head_dim)
                     + cfg.num_heads * m.v_head_dim * D)
                kerns.append(("MLA_PROJ", "dram", 2 * w, 2 * w))
                kerns.append(("FUSED_ATTN_STREAM", "dram", 0, 0))
            elif unit_plan.mixer == "rwkv6":
                w = 3 * D * D + D * D + D * D
                kerns.append(("RWKV6_TIMEMIX", "dram", 2 * w, 2 * w))
            elif unit_plan.mixer == "mamba2":
                d_inner = cfg.ssm.expand * D
                w = D * (2 * d_inner + 2 * cfg.ssm.state_dim) + d_inner * D
                kerns.append(("MAMBA2_SSD", "dram", 2 * w, 2 * w))
            blk = unit_plan
            has_ffn = any(p.op in ("ffn", "moe_ffn", "channel_mix")
                          for p in blk.placements)
            if has_ffn:
                if cfg.mlp_type == "moe" and cfg.moe:
                    m = cfg.moe
                    w = m.top_k * 3 * D * m.d_ff_expert \
                        + m.num_shared_experts * 3 * D * (
                            m.d_ff_shared or m.d_ff_expert)
                elif cfg.mlp_type in ("silu_gated", "gelu_gated"):
                    w = 3 * D * cfg.d_ff
                elif cfg.mlp_type == "rwkv_cm":
                    w = 2 * D * cfg.d_ff + D * D
                else:
                    w = 2 * D * cfg.d_ff
                kerns.append(("FUSED_FFN_ACT", "rram", 2 * w, 2 * w))
            out.append({"kernels": kerns,
                        "has_attn": unit_plan.mixer in (
                            "attn", "attn_shared", "mla"),
                        "has_ffn": has_ffn})
    return out


def _kernel_time_energy(domain, flops: float, bytes_r: float,
                        pj_flop: float, weight_dtype_bytes: float = 2.0
                        ) -> tuple[float, float]:
    t = max(flops / domain.peak_flops, bytes_r / domain.internal_bw)
    e = bytes_r * 8 * domain.read_energy_pj_bit * 1e-12 \
        + flops * pj_flop * 1e-12
    return t, e


def visual_tokens(cfg: ModelConfig) -> int:
    return cfg.frontend.num_tokens if cfg.frontend else 0


def decode_token_cost(cfg: ModelConfig, platform: Platform, ctx: int,
                      layers: list[dict] | None = None
                      ) -> tuple[float, float, dict]:
    """Analytical (time_s, energy_j, breakdown) of ONE decode step at
    context length ``ctx`` — the per-step cost term. `simulate` sums it
    over a growing context; the serving metrics feed it measured per-slot
    step counts instead."""
    if layers is None:
        layers = _layer_kernels(cfg)
    n_layers = len(layers)
    dram = platform.domains["dram"]
    rram = platform.domains["rram"] if "rram" in platform.domains else dram
    D = cfg.d_model
    ucie_t_per_cut = (2 * D / platform.cross_domain_bw
                      if platform.cross_domain_bw else 0.0)
    ucie_e_per_cut = (2 * D * 8 * platform.cross_domain_pj_bit * 1e-12
                      if platform.cross_domain_bw else 0.0)
    kv_tok = kv_bytes_per_token(cfg)
    n_attn = max(sum(1 for l in layers if l["has_attn"]), 1)
    tok_t = energy = 0.0
    br = {"dram_s": 0.0, "rram_s": 0.0, "attn_kv_s": 0.0, "ucie_s": 0.0,
          "busy_dram": 0.0, "busy_rram": 0.0}
    for lay in layers:
        for name, dom_name, flops, bytes_r in lay["kernels"]:
            dom = dram if dom_name == "dram" else rram
            if name == "FUSED_ATTN_STREAM":
                # stream the KV cache for this layer
                bytes_r = kv_tok / n_attn * ctx
                flops = bytes_r  # ~1 MAC per cached byte at fp16
            t, e = _kernel_time_energy(dom, flops, bytes_r,
                                       platform.compute_pj_flop)
            tok_t += t
            energy += e
            br["busy_" + dom_name] += t
            if dom_name == "dram" or name == "FUSED_ATTN_STREAM":
                if name == "FUSED_ATTN_STREAM":
                    br["attn_kv_s"] += t
                else:
                    br["dram_s"] += t
            else:
                br["rram_s"] += t
        if lay["has_ffn"]:
            tok_t += 2 * ucie_t_per_cut
            br["ucie_s"] += 2 * ucie_t_per_cut
            energy += 2 * ucie_e_per_cut
        # KV append write energy (DRAM tier-0; write-once discipline)
        energy += kv_tok / max(n_layers, 1) * 8 \
            * dram.write_energy_pj_bit * 1e-12
    tok_t += platform.layer_overhead_s * n_layers \
        + platform.fixed_token_overhead_s
    return tok_t, energy, br


def kv_spill_cost(cfg: ModelConfig, platform: Platform, ctx: int,
                  restore: bool = False,
                  compressed: bool = False) -> tuple[float, float]:
    """Analytical (time_s, energy_j) of moving ONE request's ``ctx``-token
    KV image between the DRAM stack and the RRAM spill store across UCIe
    — the per-event cost of a serving preemption or idle offload.
    Mirrors `decode_token_cost`'s terms: bytes from the same
    `kv_bytes_per_token` the capacity admission uses, time bounded by the
    slower of the UCIe link and the RRAM interface, energy from the RRAM
    write (spill) or read (restore) energy plus the UCIe transfer.
    ``compressed`` prices the int8 spill-lane codec instead: one byte per
    cached element plus the f32 per-(token, head) scales — the same byte
    math `serving.kv_pool.spill_lane_bytes` charges the RRAM budget. A
    flat (untiered) cache has no hot ring to compress, so its lanes are
    always verbatim and the flag is ignored (mirroring the backend)."""
    per_tok = kv_bytes_per_token(cfg)
    if compressed and cfg.kv_policy == "tiered":
        from repro.models.counting import (kv_elems_per_token,
                                           kv_scale_elems_per_token)
        per_tok = kv_elems_per_token(cfg) \
            + 4 * kv_scale_elems_per_token(cfg)
    kv_bytes = per_tok * max(ctx, 0)
    rram = platform.domains.get("rram", platform.domains["dram"])
    bw = rram.internal_bw
    ucie_e = 0.0
    if platform.cross_domain_bw:
        bw = min(bw, platform.cross_domain_bw)
        ucie_e = kv_bytes * 8 * platform.cross_domain_pj_bit * 1e-12
    pj_bit = (rram.read_energy_pj_bit if restore
              else rram.write_energy_pj_bit)
    t = kv_bytes / bw if bw else 0.0
    e = kv_bytes * 8 * pj_bit * 1e-12 + ucie_e
    return t, e


def simulate(cfg: ModelConfig, platform: Platform = CHIME,
             wl: Workload = Workload()) -> SimResult:
    D = cfg.d_model
    layers = _layer_kernels(cfg)
    n_layers = len(layers)
    vis = visual_tokens(cfg) if wl.image else 0
    prompt = vis + wl.text_tokens

    dram = platform.domains["dram"]
    rram = platform.domains["rram"] if "rram" in platform.domains else dram
    ucie_t_per_cut = (2 * D / platform.cross_domain_bw
                      if platform.cross_domain_bw else 0.0)
    ucie_e_per_cut = (2 * D * 8 * platform.cross_domain_pj_bit * 1e-12
                      if platform.cross_domain_bw else 0.0)

    # ---- decode: per output token t (context grows) -------------------
    decode_s = 0.0
    energy = 0.0
    t_dram = t_rram = t_ucie = t_attn_kv = 0.0
    busy = {"dram": 0.0, "rram": 0.0}
    kv_tok = kv_bytes_per_token(cfg)
    for step in range(wl.output_tokens):
        tok_t, tok_e, br = decode_token_cost(cfg, platform, prompt + step,
                                             layers)
        decode_s += tok_t
        energy += tok_e
        t_dram += br["dram_s"]
        t_rram += br["rram_s"]
        t_attn_kv += br["attn_kv_s"]
        t_ucie += br["ucie_s"]
        busy["dram"] += br["busy_dram"]
        busy["rram"] += br["busy_rram"]

    # ---- prefill (+ encoder/connector, paper: <15% of runtime) --------
    # weights read once per layer, reused across prompt tokens (batched
    # GEMM); compute scales with prompt length
    prefill_s = 0.0
    for lay in layers:
        for name, dom_name, flops, bytes_r in lay["kernels"]:
            dom = dram if dom_name == "dram" else rram
            if name == "FUSED_ATTN_STREAM":
                flops = 2.0 * prompt * prompt * D
                bytes_r = prompt * kv_tok / max(n_layers, 1)
            else:
                flops = flops * prompt
            t, e = _kernel_time_energy(dom, flops, bytes_r,
                                       platform.compute_pj_flop)
            prefill_s += t
            energy += e
            busy[dom_name] += t
    # vision encoder stub cost: FastViT/ViT on 512^2 ~ 10-40 GFLOP
    if wl.image and cfg.frontend is not None:
        enc_flops = 20e9
        prefill_s += enc_flops / dram.peak_flops
        energy += enc_flops * platform.compute_pj_flop * 1e-12
    prefill_s += platform.layer_overhead_s * n_layers \
        + platform.fixed_token_overhead_s

    total = prefill_s + decode_s
    if platform.power_w is not None:
        # monolithic platform (GPU): board power over wall time
        energy += platform.power_w * total
    else:
        # chiplet platform: NMP dies power-gate when idle (duty-cycled
        # static power) + always-on uncore/UCIe (paper Fig. 7: ~1 W)
        from repro.simulator.hardware import CHIME_UNCORE_W
        energy += dram.static_power_w * busy["dram"] \
            + rram.static_power_w * busy["rram"] \
            + CHIME_UNCORE_W * total
    tps = wl.output_tokens / total
    return SimResult(
        platform=platform.name,
        model=cfg.name,
        prefill_s=prefill_s,
        decode_s=decode_s,
        total_s=total,
        energy_j=energy,
        tps=tps,
        tokens_per_j=wl.output_tokens / energy,
        avg_power_w=energy / total,
        breakdown={"dram_s": t_dram, "rram_s": t_rram,
                   "attn_kv_s": t_attn_kv, "ucie_s": t_ucie,
                   "overhead_s": platform.layer_overhead_s * n_layers
                   * wl.output_tokens
                   + platform.fixed_token_overhead_s * wl.output_tokens},
    )
