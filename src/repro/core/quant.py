"""Blockwise quantization — the TPU realization of CHIME's RRAM storage.

RRAM's value proposition in the paper is *dense, cheap-to-read, expensive-to-
write* storage for read-mostly tensors (FFN weights; frozen cold KV blocks).
On TPU the analogous denser/cheaper-to-read representation is low-bit
storage with on-the-fly dequantization fused into the consuming GEMM:
an int8 weight halves the HBM bytes of the memory-roofline term, exactly as
RRAM halves pressure on the DRAM chiplet. Writes to these stores are
expensive (requantization) and the KV frozen tier is written once — the
endurance discipline survives the port.

Also hosts int8 gradient compression for cross-pod all-reduce
(distributed-optimization trick; see optim/).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class QTensor:
    """Blockwise-quantized tensor: q int8/int4(in int8 carrier), scales f32.
    Quantized along the *last* axis in blocks of ``block``."""
    q: jax.Array
    scale: jax.Array
    bits: int = 8

    def tree_flatten(self):
        return (self.q, self.scale), (self.bits,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0])


jax.tree_util.register_pytree_node(
    QTensor, QTensor.tree_flatten, QTensor.tree_unflatten)


def quantize(x: jax.Array, bits: int = 8, block: int = 256) -> QTensor:
    """Symmetric blockwise quantization along the last axis."""
    *lead, d = x.shape
    if d % block != 0:
        block = d
    xb = x.reshape(*lead, d // block, block).astype(jnp.float32)
    maxv = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    qmax = 2.0 ** (bits - 1) - 1.0
    scale = jnp.where(maxv > 0, maxv / qmax, 1.0)
    q = jnp.clip(jnp.round(xb / scale), -qmax, qmax).astype(jnp.int8)
    return QTensor(q.reshape(*lead, d),
                   scale[..., 0].reshape(*lead, d // block), bits)


def dequantize(t: QTensor, dtype=jnp.bfloat16) -> jax.Array:
    *lead, d = t.q.shape
    nb = t.scale.shape[-1]
    block = d // nb
    xb = t.q.reshape(*lead, nb, block).astype(jnp.float32) \
        * t.scale[..., None]
    return xb.reshape(*lead, d).astype(dtype)


def quantize_per_token(x: jax.Array, bits: int = 8
                       ) -> tuple[jax.Array, jax.Array]:
    """Per-(token, head) symmetric quantization over the trailing feature
    dim — the KV cold-tier format. Returns (q int8, scale f32[..., 1])."""
    xf = x.astype(jnp.float32)
    maxv = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    qmax = 2.0 ** (bits - 1) - 1.0
    scale = jnp.where(maxv > 0, maxv / qmax, 1.0)
    q = jnp.clip(jnp.round(xf / scale), -qmax, qmax).astype(jnp.int8)
    return q, scale


def dequantize_per_token(q: jax.Array, scale: jax.Array,
                         dtype=jnp.bfloat16) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# RRAM spill-lane hot-ring codec (serving: compressed cold-KV offload).
#
# A spill lane parks a whole slot image in the dense RRAM tier. The cold
# tier is already int8 (written once, read rarely) and rides verbatim,
# but the hot ring is full precision — the dominant lane bytes. The
# opt-in compressed lane re-quantizes the hot window with the SAME
# per-(token, head) symmetric int8 scheme as the cold tier, so one codec
# (and one error contract) covers both representations.
#
# Tolerance contract: for each feature row r (the trailing axis that
# shares one scale), symmetric int8 round-to-nearest guarantees
#
#     |x - decompress(compress(x))| <= max|r| / 254      elementwise
#
# (scale = max|r|/127 and rounding error <= scale/2; an all-zero row is
# reconstructed exactly). `spill_codec_bound` materializes that bound;
# the hypothesis codec suite holds the round trip to it over random
# shapes/scales, and tests/test_serving_spill.py holds the end-to-end
# logit drift of a restored compressed lane to the documented
# SPILL_COMPRESS tolerances in that file.
# ---------------------------------------------------------------------------
SPILL_CODEC_QMAX = 127.0  # int8 symmetric levels per polarity


def compress_spill_hot(hot: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Hot-ring -> (int8, f32 scale[..., 1]) lane form (per-(token, head)
    symmetric, the cold-tier scheme; see the codec contract above)."""
    return quantize_per_token(hot)


def decompress_spill_hot(q: jax.Array, scale: jax.Array,
                         dtype=jnp.bfloat16) -> jax.Array:
    """Requantization-aware restore of a compressed hot ring: dequantize
    back to the cache dtype; error bounded by `spill_codec_bound`."""
    return dequantize_per_token(q, scale, dtype)


def spill_codec_bound(x: jax.Array) -> jax.Array:
    """Elementwise reconstruction-error bound of the spill codec for
    input ``x``: max|feature row| / 254 (broadcast over the row)."""
    xf = x.astype(jnp.float32)
    return jnp.max(jnp.abs(xf), axis=-1, keepdims=True) \
        / (2.0 * SPILL_CODEC_QMAX) * jnp.ones_like(xf)


# ---------------------------------------------------------------------------
# gradient compression (cross-pod int8 all-reduce)
# ---------------------------------------------------------------------------
def grad_scale(g: jax.Array) -> jax.Array:
    """Per-tensor symmetric int8 scale (max|g|/127; 1.0 for an all-zero
    tensor). Split out so a collective can agree on a SHARED scale
    (e.g. pmax over pods) before anything quantizes."""
    maxv = jnp.max(jnp.abs(g))
    return jnp.where(maxv > 0, maxv / 127.0, 1.0)


def compress_grad(g: jax.Array, scale: jax.Array | None = None
                  ) -> tuple[jax.Array, jax.Array]:
    """Per-tensor int8 with stochastic-free symmetric scaling; the all-reduce
    then moves 1/4 of the bf16 bytes over the pod axis. ``scale`` imposes
    an externally-agreed grid (a shared cross-pod scale); None derives the
    tensor's own `grad_scale`."""
    if scale is None:
        scale = grad_scale(g)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_grad(q: jax.Array, scale: jax.Array,
                    dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)
