"""CHIME kernel locality-aware fusion (paper §III-C ③, Table I).

This is the fusion registry: the model calls these entry points and the
registry picks the execution strategy —

  * pure-jnp oracle (XLA fuses; this is also what the dry-run lowers so
    cost_analysis reflects the shipped HLO),
  * Pallas TPU kernel (``cfg.use_pallas_kernels`` on a TPU backend; the
    near-memory PE/SFPE pipeline of the paper mapped to MXU/VPU with
    explicit VMEM BlockSpecs),
  * int8 "RRAM-domain" weight store (``cfg.ffn_weight_store == 'int8'`` —
    FFN weights held as QTensor; dequant fused into the GEMM).

Fusion boundaries coincide with memory-domain boundaries (the paper's key
rule): a fused kernel never spans the attention-domain/FFN-domain cut, so
per layer exactly two activations (AttnOut, FFNOut) cross domains —
core/dataflow.py audits the lowered HLO for this.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.quant import QTensor, dequantize
from repro.models import layers as L
from repro.models import attention as A


import os


def _use_pallas(cfg: ModelConfig) -> bool:
    if not cfg.use_pallas_kernels:
        return False
    # REPRO_PALLAS_INTERPRET=1 lets CPU tests exercise the kernel path
    # end-to-end through the model (kernels run in interpret mode)
    return (jax.default_backend() == "tpu"
            or os.environ.get("REPRO_PALLAS_INTERPRET") == "1")


def _use_fused_decode(cfg: ModelConfig) -> bool:
    """Opt-in fused paged-decode attention (kernels/paged_decode.py).
    Unlike _use_pallas this doesn't require cfg.use_pallas_kernels: the
    kernel interprets on CPU, so enabling the knob is always exercisable
    (CI runs the whole serving stack through it). The unfused two-segment
    merge stays the parity oracle."""
    if getattr(cfg, "fused_decode", False):
        return True
    return os.environ.get("REPRO_SERVE_FUSED_DECODE", "") not in ("", "0")


def _sparse_read_tau(cfg: ModelConfig) -> float:
    """SLIM-style sparse-read threshold: cfg wins, else the env knob.
    0 disables (exact kernel). Malformed env values read as off — the
    serving engine warns rather than crashes on bad knobs."""
    tau = float(getattr(cfg, "sparse_read_tau", 0.0) or 0.0)
    if tau > 0.0:
        return tau
    raw = os.environ.get("REPRO_SERVE_SPARSE_READ", "")
    try:
        return max(float(raw), 0.0) if raw else 0.0
    except ValueError:
        return 0.0


# ---------------------------------------------------------------------------
# FUSED_FFN_ACT
# ---------------------------------------------------------------------------
def apply_ffn(p: dict, cfg: ModelConfig, x: jax.Array, rules,
              mlp_type: str | None = None, d_ff: int | None = None,
              dropless_moe: bool = False) -> jax.Array:
    kind = mlp_type or cfg.mlp_type
    if kind == "moe":
        return L.apply_moe(p, cfg, x, rules, dropless=dropless_moe)
    if kind == "rwkv_cm":
        raise ValueError("rwkv_cm is stateful; handled in model block")
    if isinstance(p.get("w_up"), QTensor):
        p = dict(p)
        for k in ("w_up", "w_gate", "w_down"):
            if isinstance(p.get(k), QTensor):
                p[k] = dequantize(p[k], jnp.dtype(cfg.compute_dtype))
    if _use_pallas(cfg) and kind in ("gelu", "silu_gated", "gelu_gated",
                                     "relu2") and "b_up" not in p:
        from repro.kernels import ops
        return ops.ffn_act(
            x, p["w_up"], p.get("w_gate"), p["w_down"], kind)
    return L.apply_mlp(p, cfg, x, rules, mlp_type=kind)


# ---------------------------------------------------------------------------
# FUSED_QKV_PROJ + FUSED_ATTN_STREAM
# ---------------------------------------------------------------------------
def apply_attention_seq(p: dict, cfg: ModelConfig, x: jax.Array,
                        positions: jax.Array, rules, causal: bool,
                        build_cache: bool = False, max_len: int = 0,
                        length=None) -> tuple[jax.Array, dict | None]:
    """Full-sequence attention (train / prefill / encoder). When
    ``build_cache``, the post-RoPE K/V are absorbed into KV stores
    (flat or CHIME-tiered per cfg.kv_policy). ``length`` (traced scalar,
    default S) is the number of VALID prompt tokens: the serving engine
    right-pads prompts to a bucket length, and the tiered store's hot ring
    and validity masks must follow the true length, not the padded shape."""
    from repro.core import kv_tiers as KT
    q, k, v = A.qkv_proj(p, cfg, x, positions, rules)
    S = x.shape[1]
    if _use_pallas(cfg) and causal:
        from repro.kernels import ops
        o = ops.attn_stream(q, k, v, causal=True)
    else:
        mask = A.causal_mask(S, S) if causal else None
        o = A.gqa_scores_softmax_pv(
            q, k, v, mask, rules=rules,
            scores_dtype=jnp.dtype(cfg.attn_scores_dtype))
    cache = None
    if build_cache:
        ln = S if length is None else length
        cache = {
            "k": KT.store_from_full(k, cfg.kv_policy, cfg.kv_hot_window,
                                    ln, max_len),
            "v": KT.store_from_full(v, cfg.kv_policy, cfg.kv_hot_window,
                                    ln, max_len),
        }
    return A.attn_out(p, cfg, o, rules), cache


def apply_attention_extend(p: dict, cfg: ModelConfig, x: jax.Array,
                           positions: jax.Array, cache: dict, pos, length,
                           rules, commit: bool
                           ) -> tuple[jax.Array, dict]:
    """Chunk-resumable prefill attention (serving `Model.extend`).

    ``cache`` is the workspace form {"k_ws","v_ws"}: full-precision
    (B, max_len, Hkv, D) buffers accumulating the post-RoPE K/V of every
    chunk so far. The chunk's queries (absolute positions ``positions`` =
    pos + arange(C)) attend causally over the workspace — the exact rows
    of the whole-prompt attention matrix, at full precision, which is what
    makes chunked prefill token-for-token identical to `Model.prefill`.

    With ``commit`` (the prompt's final chunk) the workspace is folded
    into the regular flat/CHIME-tiered stores via the same
    `store_from_full` whole-prompt prefill uses, so the committed cache is
    bit-identical too. ``length`` counts the chunk's VALID rows: rows
    beyond it are padding whose K/V land past the committed length and are
    never attendable."""
    from repro.core import kv_tiers as KT
    q, k, v = A.qkv_proj(p, cfg, x, positions, rules)
    kf = jax.lax.dynamic_update_slice(
        cache["k_ws"], k.astype(cache["k_ws"].dtype), (0, pos, 0, 0))
    vf = jax.lax.dynamic_update_slice(
        cache["v_ws"], v.astype(cache["v_ws"].dtype), (0, pos, 0, 0))
    kj = jnp.arange(kf.shape[1])[None, :]
    mask = (kj <= positions[0][:, None])[None, None]   # (1,1,C,max_len)
    o = A.gqa_scores_softmax_pv(
        q, kf, vf, mask, rules=rules,
        scores_dtype=jnp.dtype(cfg.attn_scores_dtype),
        kv_logical=("batch", "kv_seq_shard", "heads", None))
    out = A.attn_out(p, cfg, o, rules)
    if commit:
        ln = pos + (x.shape[1] if length is None else length)
        max_len = kf.shape[1]
        return out, {
            "k": KT.store_from_full(kf, cfg.kv_policy, cfg.kv_hot_window,
                                    ln, max_len),
            "v": KT.store_from_full(vf, cfg.kv_policy, cfg.kv_hot_window,
                                    ln, max_len),
        }
    return out, {"k_ws": kf, "v_ws": vf}


def apply_attention_decode(p: dict, cfg: ModelConfig, x: jax.Array,
                           cache: dict, pos, rules
                           ) -> tuple[jax.Array, dict]:
    """One-token decode over flat or CHIME-tiered KV stores."""
    from repro.core import kv_tiers as KT
    positions = jnp.full((x.shape[0], 1), pos, jnp.int32)
    q, k_new, v_new = A.qkv_proj(p, cfg, x, positions, rules)
    ck = KT.store_append(cache["k"], k_new, pos)
    cv = KT.store_append(cache["v"], v_new, pos)
    if "hot" in ck:
        if _use_fused_decode(cfg):
            # fused paged decode: online softmax streams hot + cold pages
            # straight from the store layouts (block-table indirection,
            # in-kernel int8 dequant) — no store_read materialization
            from repro.kernels import ops
            o = ops.paged_decode_tiered(cfg, q, ck, cv, pos,
                                        tau=_sparse_read_tau(cfg))
        else:
            # tiered: two-segment flash merge — int8 cold tier read
            # directly (scales factored into the dots), no concat
            o = A.attend_tiered(cfg, q, ck, cv, pos)
    elif _use_fused_decode(cfg):
        from repro.kernels import ops
        o = ops.paged_decode_flat(cfg, q, ck, cv, pos)
    else:
        cd = jnp.dtype(cfg.compute_dtype)
        kv, valid = KT.store_read(ck, pos, cd)
        vv, _ = KT.store_read(cv, pos, cd)
        # decode: the broadcast K/V must KEEP the cache's seq sharding —
        # constraining seq to replicated force-gathers the whole cache
        # every step (observed: 2x 5.4 GB/layer/step on llama4)
        o = A.gqa_scores_softmax_pv(
            q, kv, vv, valid[None, None, None, :], rules=rules,
            scores_dtype=jnp.dtype(cfg.attn_scores_dtype),
            kv_logical=("batch", "kv_seq_shard", "heads", None))
    return A.attn_out(p, cfg, o, rules), {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# MLA (latent cache — flat or tiered, same stores)
# ---------------------------------------------------------------------------
def apply_mla_seq(p: dict, cfg: ModelConfig, x: jax.Array,
                  positions: jax.Array, rules, causal: bool,
                  build_cache: bool = False, max_len: int = 0,
                  length=None) -> tuple[jax.Array, dict | None]:
    from repro.core import kv_tiers as KT
    S = x.shape[1]
    c_kv, k_rope = A.mla_latents(p, cfg, x, positions)
    q_nope, q_rope = A.mla_queries(p, cfg, x, positions)
    mask = (A.causal_mask(S, S) if causal else None)
    out = A.mla_attention(p, cfg, q_nope, q_rope, c_kv, k_rope, mask,
                          absorbed=cfg.mla_absorbed)
    cache = None
    if build_cache:
        ln = S if length is None else length
        cache = {
            "c_kv": KT.store_from_full(c_kv, cfg.kv_policy,
                                       cfg.kv_hot_window, ln, max_len),
            "k_rope": KT.store_from_full(k_rope, cfg.kv_policy,
                                         cfg.kv_hot_window, ln, max_len),
        }
    return out, cache


def apply_mla_extend(p: dict, cfg: ModelConfig, x: jax.Array,
                     positions: jax.Array, cache: dict, pos, length,
                     rules, commit: bool) -> tuple[jax.Array, dict]:
    """Chunk-resumable MLA prefill: the workspace {"c_kv_ws","k_rope_ws"}
    accumulates full-precision latents; the chunk attends causally over it
    (exact rows of `apply_mla_seq`), and ``commit`` folds the workspace
    into the flat/tiered latent stores via `store_from_full`."""
    from repro.core import kv_tiers as KT
    c_kv, k_rope = A.mla_latents(p, cfg, x, positions)
    q_nope, q_rope = A.mla_queries(p, cfg, x, positions)
    cf = jax.lax.dynamic_update_slice(
        cache["c_kv_ws"], c_kv.astype(cache["c_kv_ws"].dtype), (0, pos, 0))
    rf = jax.lax.dynamic_update_slice(
        cache["k_rope_ws"], k_rope.astype(cache["k_rope_ws"].dtype),
        (0, pos, 0))
    kj = jnp.arange(cf.shape[1])[None, :]
    mask = (kj <= positions[0][:, None])[None, None]
    out = A.mla_attention(p, cfg, q_nope, q_rope, cf, rf, mask,
                          absorbed=cfg.mla_absorbed)
    if commit:
        ln = pos + (x.shape[1] if length is None else length)
        max_len = cf.shape[1]
        return out, {
            "c_kv": KT.store_from_full(cf, cfg.kv_policy,
                                       cfg.kv_hot_window, ln, max_len),
            "k_rope": KT.store_from_full(rf, cfg.kv_policy,
                                         cfg.kv_hot_window, ln, max_len),
        }
    return out, {"c_kv_ws": cf, "k_rope_ws": rf}


def apply_mla_decode(p: dict, cfg: ModelConfig, x: jax.Array,
                     cache: dict, pos, rules) -> tuple[jax.Array, dict]:
    from repro.core import kv_tiers as KT
    positions = jnp.full((x.shape[0], 1), pos, jnp.int32)
    c_new, r_new = A.mla_latents(p, cfg, x, positions)
    q_nope, q_rope = A.mla_queries(p, cfg, x, positions)
    cc = KT.store_append(cache["c_kv"], c_new, pos)
    cr = KT.store_append(cache["k_rope"], r_new, pos)
    # fused paged decode is GQA-only for now: MLA's two-latent score sum
    # (nope + rope per token) doesn't fit the single-K-page kernel shape,
    # so the fused_decode knob leaves MLA on the unfused oracle (the
    # serving parity tests pin knob-on == knob-off for MLA archs).
    if "hot" in cc:
        out = A.mla_attend_tiered(p, cfg, q_nope, q_rope, cc, cr, pos)
    else:
        cd = jnp.dtype(cfg.compute_dtype)
        c_all, valid = KT.store_read(cc, pos, cd)
        r_all, _ = KT.store_read(cr, pos, cd)
        mask = valid[None, None, None, :]
        out = A.mla_attention(p, cfg, q_nope, q_rope, c_all, r_all, mask,
                              absorbed=cfg.mla_absorbed)
    return out, {"c_kv": cc, "k_rope": cr}


# ---------------------------------------------------------------------------
# FUSED_NORM
# ---------------------------------------------------------------------------
def apply_norm(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if _use_pallas(cfg):
        from repro.kernels import ops
        if cfg.norm_type == "rmsnorm":
            return ops.fused_norm(x, p["scale"], None, kind="rms")
        return ops.fused_norm(x, p["scale"], p["bias"], kind="layer")
    return L.apply_norm(p, cfg, x)


# ---------------------------------------------------------------------------
# "RRAM" weight placement (planner hook)
# ---------------------------------------------------------------------------
_FFN_KEYS = ("w_up", "w_gate", "w_down")


def place_ffn_weights_int8(params, path: tuple = ()):
    """Convert every dense-FFN weight leaf to an int8 QTensor store. Walks
    the params pytree looking for mlp scopes — the planner's 'move FFN
    weights into the RRAM domain' step."""
    if isinstance(params, dict):
        out = {}
        for k, v in params.items():
            if k in _FFN_KEYS and isinstance(v, jax.Array) and v.ndim >= 2 \
                    and path and path[-1] in ("mlp", "shared"):
                from repro.core.quant import quantize
                out[k] = quantize(v)
            else:
                out[k] = place_ffn_weights_int8(v, path + (k,))
        return out
    return params
