"""CHIME mapping framework (paper §III-C): workload-aware data layout.

Assigns every operator class of a model to a memory domain and emits the
execution plan the runtime and the analytical simulator share:

  DRAM domain ("latency-critical"): image preprocessing/connector, QKV
    projection, attention, KV cache, norms — everything except the FFN.
  RRAM domain ("dense read-mostly storage"): FFN weights + the fused FFN
    kernel; MoE expert banks; the frozen (write-once) cold KV tier.

The plan records the two cut points per layer (AttnOut ->, <- FFNOut) and
the fused-kernel choice per op, and computes the per-step cross-domain
traffic — the quantity CHIME minimizes. ``audit`` verifies the two-cut-point
invariant against the model structure; core/dataflow.py verifies the HLO.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

from repro.configs.base import ModelConfig
from repro.models.model import build_plan

Domain = Literal["dram", "rram"]


@dataclasses.dataclass(frozen=True)
class OpPlacement:
    op: str                    # e.g. "attn", "ffn", "norm", "connector"
    domain: Domain
    fused_kernel: str | None   # Table I kernel implementing it


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    mixer: str
    placements: tuple[OpPlacement, ...]
    cut_points: tuple[str, ...]          # activation tensors crossing domains
    repeats: int


@dataclasses.dataclass(frozen=True)
class MappingPlan:
    arch: str
    layers: tuple[LayerPlan, ...]
    kv_tiering: bool                     # technique T2 applicable?
    kv_policy: str
    notes: tuple[str, ...]

    def cross_domain_tensors_per_layer(self) -> dict[str, int]:
        return {f"{lp.mixer}x{lp.repeats}": len(lp.cut_points)
                for lp in self.layers}

    def cross_domain_bytes_per_token(self, cfg: ModelConfig,
                                     dtype_bytes: int = 2) -> int:
        """AttnOut + FFNOut bytes per generated token across all layers —
        the UCIe traffic CHIME's layout minimizes."""
        total = 0
        for lp in self.layers:
            total += len(lp.cut_points) * cfg.d_model * dtype_bytes \
                * lp.repeats
        return total

    def audit(self) -> None:
        """The paper's invariant: <= 2 activation-only cross-domain
        transfers per layer, and fusion boundaries never split a kernel."""
        for lp in self.layers:
            if len(lp.cut_points) > 2:
                raise AssertionError(
                    f"{lp.mixer}: {len(lp.cut_points)} cut points > 2")
            domains = [p.domain for p in lp.placements]
            # cut points must equal the number of domain switches in the
            # op sequence (fusion boundaries == domain boundaries)
            switches = sum(1 for a, b in zip(domains, domains[1:])
                           if a != b)
            # closing the loop back to DRAM for the next layer
            if domains and domains[-1] != domains[0]:
                switches += 1
            if switches != len(lp.cut_points):
                raise AssertionError(
                    f"{lp.mixer}: {switches} domain switches vs "
                    f"{len(lp.cut_points)} declared cut points")


def plan_for(cfg: ModelConfig) -> MappingPlan:
    """Derive the CHIME mapping for any model config (paper Fig. 5(b))."""
    notes: list[str] = []
    layers: list[LayerPlan] = []
    for unit in build_plan(cfg):
        b = unit.block
        placements: list[OpPlacement] = []
        cuts: list[str] = []
        placements.append(OpPlacement("norm", "dram", "FUSED_NORM"))
        if b.mixer in ("attn", "attn_shared"):
            placements.append(
                OpPlacement("qkv_proj", "dram", "FUSED_QKV_PROJ"))
            placements.append(
                OpPlacement("attention", "dram", "FUSED_ATTN_STREAM"))
        elif b.mixer == "mla":
            placements.append(
                OpPlacement("mla_latents", "dram", "FUSED_QKV_PROJ"))
            placements.append(
                OpPlacement("mla_attention", "dram", "FUSED_ATTN_STREAM"))
        elif b.mixer == "rwkv6":
            placements.append(OpPlacement("rwkv6_timemix", "dram", None))
        elif b.mixer == "mamba2":
            placements.append(OpPlacement("mamba2_ssd", "dram", None))
        if b.mlp is not None:
            placements.append(OpPlacement("norm2", "dram", "FUSED_NORM"))
            if b.mlp == "moe":
                placements.append(
                    OpPlacement("moe_ffn", "rram", "FUSED_FFN_ACT"))
            elif b.mlp == "rwkv_cm":
                placements.append(
                    OpPlacement("channel_mix", "rram", "FUSED_FFN_ACT"))
            else:
                placements.append(
                    OpPlacement("ffn", "rram", "FUSED_FFN_ACT"))
            cuts = ["AttnOut", "FFNOut"]
        else:
            notes.append(f"{b.mixer}: mixer-only block — no FFN, no "
                         "cross-domain transfer (stays in DRAM domain)")
        layers.append(LayerPlan(b.mixer, tuple(placements), tuple(cuts),
                                unit.repeats))

    has_kv = any(u.block.mixer in ("attn", "attn_shared", "mla")
                 for u in build_plan(cfg))
    if not has_kv:
        notes.append("attention-free: KV tiering (T2) inapplicable; "
                     "recurrent state is Tier-0-resident by construction")
    if cfg.is_encoder:
        notes.append("encoder-only: no autoregressive cache; KV tiering "
                     "inapplicable")
    return MappingPlan(
        arch=cfg.name,
        layers=tuple(layers),
        kv_tiering=has_kv and not cfg.is_encoder,
        kv_policy=cfg.kv_policy,
        notes=tuple(notes),
    )
