"""CHIME KV-cache tiered scheduling (paper §III-C ②), TPU realization.

The M3D DRAM stack's vertical latency gradient (read = 3 + 0.8·L ns) becomes
a *precision/bandwidth* gradient on TPU:

  Tier-0 (hot)    : the most recent ``hot_window`` tokens, full precision
                    (bf16) — these dominate attention mass in decoding and
                    are what the Pallas attention kernel streams first.
  Tiers 1-3 (cold): older tokens, int8 per-(token,head) quantized — half the
                    HBM bytes per decode step, the dominant decode cost.
  Tier-4 (frozen) : the paper's write-once RRAM offload. Cold slots are
                    written exactly once, when a token ages out of the hot
                    window; per-block write counters assert the endurance
                    discipline (tests/test_kv_tiers.py proves writes==1).

The cache is a plain pytree usable inside jit/pjit serve_step; every update
is functional. Works for GQA K/V tensors and MLA latents alike (anything of
shape (B, L, ...)).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant import (compress_spill_hot, decompress_spill_hot,
                              dequantize_per_token, quantize_per_token)

ENDURANCE_BLOCK = 128  # tokens per endurance-accounting block


def init_tiered(batch: int, max_len: int, inner: tuple[int, ...],
                hot_window: int, dtype=jnp.bfloat16) -> dict:
    """A tiered store for one cached tensor of per-token shape ``inner``."""
    W = min(hot_window, max_len)
    return {
        "hot": jnp.zeros((batch, W) + inner, dtype),
        "cold_q": jnp.zeros((batch, max_len) + inner, jnp.int8),
        "cold_scale": jnp.ones((batch, max_len) + inner[:-1] + (1,),
                               jnp.float32),
        # per-sequence (= per serving slot) endurance counters, so a
        # multi-request pool can prove writes<=1-per-cold-slot for each
        # occupancy independently and reset them on slot recycling
        "writes": jnp.zeros(
            (batch, (max_len + ENDURANCE_BLOCK - 1) // ENDURANCE_BLOCK),
            jnp.int32),
    }


def tiered_logical(inner_logical: tuple[str | None, ...]) -> dict:
    seq_ax = ("batch", "kv_seq_shard") + inner_logical
    return {
        "hot": ("batch", None) + inner_logical,
        "cold_q": seq_ax,
        "cold_scale": ("batch", "kv_seq_shard") + inner_logical[:-1] + (None,),
        "writes": ("batch", None),
    }


def hot_window_of(cache: dict) -> int:
    return cache["hot"].shape[1]


def tiered_from_full(full: jax.Array, hot_window: int, length,
                     max_len: int) -> dict:
    """Prefill path: build a tiered store from a fully-materialized
    (B, S, ...) tensor whose first ``length`` positions are valid. The cold
    prefix is quantized in one shot (each slot written once — the paper's
    'one-shot, write-once' RRAM offload); the last W tokens land in the hot
    ring at slot p % W."""
    B, S = full.shape[:2]
    W = min(hot_window, max_len)
    assert S <= max_len
    q, scale = quantize_per_token(full)
    cold_q = jnp.zeros((B, max_len) + full.shape[2:], jnp.int8)
    cold_q = jax.lax.dynamic_update_slice(
        cold_q, q, (0,) * cold_q.ndim)
    cold_scale = jnp.ones((B, max_len) + full.shape[2:-1] + (1,),
                          jnp.float32)
    cold_scale = jax.lax.dynamic_update_slice(
        cold_scale, scale, (0,) * cold_scale.ndim)
    # hot ring: position p -> slot p % W; fill from the last W valid tokens
    pos = jnp.arange(W)
    # slot i holds absolute position: largest p < length with p % W == i
    abs_pos = (length - 1) - ((length - 1 - pos) % W)
    abs_pos = jnp.clip(abs_pos, 0, S - 1)
    hot = jnp.take(full, abs_pos, axis=1)
    writes = jnp.zeros_like(init_tiered(B, max_len, full.shape[2:],
                                        W)["writes"])
    n_cold_blocks = jnp.maximum(length - W, 0) // ENDURANCE_BLOCK
    writes = jnp.where(
        jnp.arange(writes.shape[1])[None, :] < n_cold_blocks, 1, writes)
    return {"hot": hot, "cold_q": cold_q, "cold_scale": cold_scale,
            "writes": writes}


def tiered_append(cache: dict, new: jax.Array, pos) -> dict:
    """Decode step: write token ``pos`` (shape (B, 1, ...)) into the hot
    ring; the evicted token (pos - W) is quantized into its cold slot —
    written exactly once in the cache's lifetime (endurance-aware)."""
    W = hot_window_of(cache)
    slot = pos % W
    evict_pos = pos - W
    evicted = jax.lax.dynamic_slice_in_dim(cache["hot"], slot, 1, axis=1)
    q, scale = quantize_per_token(evicted)
    do_evict = evict_pos >= 0
    safe_evict = jnp.maximum(evict_pos, 0)
    old_q = jax.lax.dynamic_slice_in_dim(
        cache["cold_q"], safe_evict, 1, axis=1)
    old_s = jax.lax.dynamic_slice_in_dim(
        cache["cold_scale"], safe_evict, 1, axis=1)
    cold_q = jax.lax.dynamic_update_slice_in_dim(
        cache["cold_q"], jnp.where(do_evict, q, old_q), safe_evict, axis=1)
    cold_scale = jax.lax.dynamic_update_slice_in_dim(
        cache["cold_scale"], jnp.where(do_evict, scale, old_s),
        safe_evict, axis=1)
    hot = jax.lax.dynamic_update_slice_in_dim(
        cache["hot"], new.astype(cache["hot"].dtype), slot, axis=1)
    blk = safe_evict // ENDURANCE_BLOCK
    writes = cache["writes"].at[:, blk].add(
        jnp.where(do_evict, 1, 0))
    return {"hot": hot, "cold_q": cold_q, "cold_scale": cold_scale,
            "writes": writes}


def tiered_read(cache: dict, pos, dtype=jnp.bfloat16
                ) -> tuple[jax.Array, jax.Array]:
    """Materialize the attendable store as (values, valid_mask) along a
    combined length axis [cold(max_len) ++ hot(W)].

    Positions < pos - W + 1 read from the int8 cold tier (half the HBM
    bytes); the hot window reads bf16. The consuming attention masks
    invalid slots. XLA fuses the dequant into the score GEMM, so the cold
    tier's HBM traffic really is the int8 array.
    """
    W = hot_window_of(cache)
    max_len = cache["cold_q"].shape[1]
    cold = dequantize_per_token(cache["cold_q"], cache["cold_scale"], dtype)
    cold_valid = jnp.arange(max_len) <= (pos - W)
    hot_pos = hot_ring_positions(pos, W)
    hot_valid = (hot_pos >= 0) & (hot_pos <= pos)
    values = jnp.concatenate([cold, cache["hot"].astype(dtype)], axis=1)
    valid = jnp.concatenate([cold_valid, hot_valid], axis=0)
    return values, valid


def n_cold_pages(max_len: int, block_k: int) -> int:
    """Grid entries needed to cover a max_len cold tier in block_k pages."""
    return -(-max_len // block_k)


def cold_page_table(pos, hot_window: int, max_len: int,
                    block_k: int) -> jax.Array:
    """Identity block table for the fused paged-decode kernel: entry j maps
    logical cold page j (tokens [j*block_k, (j+1)*block_k)) to physical
    page j, or -1 when the page holds no attendable token (a dead page the
    kernel never touches). A token is attendable cold when its position
    <= pos - hot_window; passing hot_window=0 describes a flat store,
    where validity is simply position <= pos."""
    j = jnp.arange(n_cold_pages(max_len, block_k), dtype=jnp.int32)
    live = j * block_k <= pos - hot_window
    return jnp.where(live, j, -1).astype(jnp.int32)


def hot_ring_positions(pos, W: int) -> jax.Array:
    """Absolute position held by each hot slot, given current write pos."""
    i = jnp.arange(W)
    return pos - ((pos - i) % W)


def combined_positions(cache: dict, pos) -> jax.Array:
    """Absolute positions along the combined [cold ++ hot] axis (for masks
    or position-dependent logic)."""
    W = hot_window_of(cache)
    max_len = cache["cold_q"].shape[1]
    return jnp.concatenate(
        [jnp.arange(max_len), hot_ring_positions(pos, W)], axis=0)


# ---------------------------------------------------------------------------
# generic cached-tensor store: {"flat": arr} or a tiered dict.
# One abstraction for GQA K/V tensors and MLA latents alike.
# ---------------------------------------------------------------------------
def store_init(batch: int, max_len: int, inner: tuple[int, ...],
               policy: str, hot_window: int, dtype=jnp.bfloat16) -> dict:
    if policy == "tiered":
        return init_tiered(batch, max_len, inner, hot_window, dtype)
    return {"flat": jnp.zeros((batch, max_len) + inner, dtype)}


def store_logical(inner_logical: tuple[str | None, ...],
                  policy: str) -> dict:
    if policy == "tiered":
        return tiered_logical(inner_logical)
    return {"flat": ("batch", "kv_seq_shard") + inner_logical}


def store_from_full(full: jax.Array, policy: str, hot_window: int,
                    length, max_len: int) -> dict:
    """Prefill: absorb a (B, S, ...) tensor (first ``length`` valid)."""
    if policy == "tiered":
        return tiered_from_full(full, hot_window, length, max_len)
    B = full.shape[0]
    flat = jnp.zeros((B, max_len) + full.shape[2:], full.dtype)
    flat = jax.lax.dynamic_update_slice(flat, full, (0,) * flat.ndim)
    return {"flat": flat}


def store_append(store: dict, new: jax.Array, pos) -> dict:
    if "hot" in store:
        return tiered_append(store, new, pos)
    return {"flat": jax.lax.dynamic_update_slice_in_dim(
        store["flat"], new.astype(store["flat"].dtype), pos, axis=1)}


def store_read(store: dict, pos, dtype=jnp.bfloat16
               ) -> tuple[jax.Array, jax.Array]:
    """-> (values (B, L', ...), valid (L',)) where L' = max_len (flat) or
    max_len + W (tiered, [cold ++ hot])."""
    if "hot" in store:
        return tiered_read(store, pos, dtype)
    L = store["flat"].shape[1]
    return store["flat"].astype(dtype), jnp.arange(L) <= pos


# ---------------------------------------------------------------------------
# RRAM spill store accounting (serving preemption).
#
# When the serving engine preempts a request, the victim slot's cache is
# packed verbatim into an RRAM-backed spill lane (the cold int8 tier is
# already RRAM-resident form; the hot ring, scales and recurrent states
# ride along so the later restore is bit-exact). Like the one-shot
# `tiered_from_full` cold write, a spill is a single front-to-back pass
# over the packed image, so it writes every endurance block that holds a
# valid position exactly once. Lane counters are CUMULATIVE across spill
# events — RRAM wear does not reset when a lane is recycled — which is
# exactly what an endurance budget must track.
# ---------------------------------------------------------------------------
def n_endurance_blocks(max_len: int) -> int:
    return (max_len + ENDURANCE_BLOCK - 1) // ENDURANCE_BLOCK


def init_spill_writes(n_lanes: int, max_len: int) -> jax.Array:
    """Per-(lane, block) RRAM write counters for a spill store."""
    return jnp.zeros((n_lanes, n_endurance_blocks(max_len)), jnp.int32)


def spill_block_writes(n_blocks: int, length) -> jax.Array:
    """Per-block writes of ONE packed spill of a ``length``-token context:
    blocks [0, ceil(length / ENDURANCE_BLOCK)) are each written once (a
    partially-filled tail block is still a physical block write)."""
    blk = jnp.arange(n_blocks)
    touched = (length + ENDURANCE_BLOCK - 1) // ENDURANCE_BLOCK
    return jnp.where(blk < touched, 1, 0).astype(jnp.int32)


def bump_spill_writes(writes: jax.Array, lane, length) -> jax.Array:
    """Record one spill of a ``length``-token context into ``lane``."""
    return writes.at[lane].add(spill_block_writes(writes.shape[1], length))


def expected_spill_block_writes(n_blocks: int, lengths) -> jax.Array:
    """Expected cumulative per-block writes of ONE lane that absorbed a
    sequence of spills with context lengths ``lengths`` — the oracle the
    endurance regression test holds `bump_spill_writes` to exactly."""
    out = jnp.zeros((n_blocks,), jnp.int32)
    for ln in lengths:
        out = out + spill_block_writes(n_blocks, ln)
    return out


# ---------------------------------------------------------------------------
# Compressed spill lanes (opt-in, serving --spill-compress).
#
# A verbatim lane mirrors the slot's tiered store exactly. A COMPRESSED
# lane replaces the full-precision hot ring with the int8 codec form
# (core.quant.compress_spill_hot): "hot" becomes "hot_q" (int8, same
# shape) + "hot_scale" (f32, trailing axis 1). Everything else — cold
# int8 tier, cold scales, endurance counters, flat stores, recurrent
# states — rides verbatim, so only the hot window pays the (bounded,
# documented) requantization error on restore; a flat-policy spill stays
# bit-exact even with compression enabled. Endurance accounting is
# unchanged: a spill is still one write per touched ENDURANCE_BLOCK of
# the packed image, whatever the representation.
# ---------------------------------------------------------------------------
def spill_store_compress(store: dict) -> dict:
    """Pack one tiered store into compressed-lane form (jit-safe)."""
    out = {k: v for k, v in store.items() if k != "hot"}
    out["hot_q"], out["hot_scale"] = compress_spill_hot(store["hot"])
    return out


def spill_store_decompress(store: dict, dtype=jnp.bfloat16) -> dict:
    """Requantization-aware restore of a compressed-lane store."""
    out = {k: v for k, v in store.items()
           if k not in ("hot_q", "hot_scale")}
    out["hot"] = decompress_spill_hot(store["hot_q"], store["hot_scale"],
                                      dtype)
    return out


def spill_store_template(store: dict) -> dict:
    """Zero compressed-lane arrays shaped after a full-precision store
    (arrays or ShapeDtypeStructs) — the lazy lane materialization."""
    out = {k: v for k, v in store.items() if k != "hot"}
    hot = store["hot"]
    out["hot_q"] = jnp.zeros(hot.shape, jnp.int8)
    out["hot_scale"] = jnp.ones(hot.shape[:-1] + (1,), jnp.float32)
    return out


def spill_store_meta(store: dict) -> dict:
    """Mirror per-leaf metadata (slot-axis indices, shardings) onto the
    compressed layout: the hot entry serves both hot_q (same shape) and
    hot_scale (same leading axes; the trailing scale axis is size 1 and
    never sharded)."""
    out = {k: v for k, v in store.items() if k != "hot"}
    out["hot_q"] = store["hot"]
    out["hot_scale"] = store["hot"]
    return out


def endurance_report(cache: dict) -> dict:
    """Aggregate endurance counters. ``writes`` is (batch, n_blocks): each
    entry counts cold-slot writes binned by endurance block for that
    sequence (serving: that pool slot)."""
    w = cache["writes"]
    return {"max_writes_per_block": jnp.max(w),
            "total_cold_writes": jnp.sum(w),
            "per_slot_writes": jnp.sum(w, axis=tuple(range(1, w.ndim)))}


def expected_block_writes(n_blocks: int, hot_window: int, prefill_len,
                          total_len) -> jax.Array:
    """Expected per-block write count for ONE sequence that absorbed
    ``prefill_len`` tokens via the one-shot cold write (tiered_from_full)
    and then decoded up to ``total_len`` total tokens via tiered_append.

    The one-shot prefill counts 1 per *full* cold block; each decode
    eviction counts 1 per position. A cache whose counters exceed this
    vector anywhere has written some cold slot more than once — the
    endurance violation the RRAM tier forbids.
    """
    W = hot_window
    n_cold_prefill = jnp.maximum(prefill_len - W, 0)
    full_blocks = n_cold_prefill // ENDURANCE_BLOCK
    blk = jnp.arange(n_blocks)
    lo, hi = blk * ENDURANCE_BLOCK, (blk + 1) * ENDURANCE_BLOCK
    # decode evictions cover positions [prefill_len - W, total_len - W)
    ev_lo = jnp.maximum(prefill_len - W, 0)
    ev_hi = jnp.maximum(total_len - W, 0)
    appends = jnp.clip(jnp.minimum(hi, ev_hi) - jnp.maximum(lo, ev_lo),
                       0, ENDURANCE_BLOCK)
    return jnp.where(blk < full_blocks, 1, 0) + appends
