"""Two-cut-point dataflow verification against the lowered program.

CHIME's rule: per transformer layer, exactly two activation tensors cross
the memory-domain boundary (AttnOut, FFNOut). In the TPU port the domain
boundary maps to the tensor-parallel collective boundary: the attention
block ends with one partial-sum reduction (after the out-projection) and
the FFN block with one (after the down-projection) — collectives must not
fire *inside* a fused region.

``audit_layer_collectives`` lowers a single layer the way the model runs it
and counts collective ops in the resulting HLO, asserting the invariant.
Used by tests/test_dataflow.py; the full-model dry-run JSONs record the same
per-layer collective counts at scale.
"""

from __future__ import annotations

import re

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import Model

_COLLECTIVE_RE = re.compile(
    r"=\s+\S+\s+(all-gather|all-reduce|reduce-scatter|all-to-all"
    r"|collective-permute)(-start)?\(")


def count_collectives(hlo_text: str) -> dict[str, int]:
    """Count collective op *definitions* (not name references) in HLO."""
    counts: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if m:
            counts[m.group(1)] = counts.get(m.group(1), 0) + 1
    return counts


def lower_single_layer_hlo(cfg: ModelConfig, mesh, batch: int = 4,
                           seq: int = 32) -> str:
    """Lower one full forward of a single-layer variant of ``cfg`` on
    ``mesh`` and return optimized HLO text."""
    from repro.sharding import ShardingRules
    one = cfg.replace(num_layers=len(cfg.segments[0].pattern),
                      segments=(cfg.segments[0].__class__(
                          cfg.segments[0].pattern, 1),),
                      remat="none")
    rules = ShardingRules(mesh)
    model = Model(one, rules)
    specs = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
    if one.frontend is not None and one.family != "audio":
        tv = one.frontend.num_tokens
        specs = {
            "tokens": jax.ShapeDtypeStruct((batch, seq - tv), jnp.int32),
            "patches": jax.ShapeDtypeStruct(
                (batch, tv, one.frontend.frontend_dim), jnp.float32)}
    elif one.family == "audio":
        specs = {"frames": jax.ShapeDtypeStruct(
            (batch, seq, one.frontend.frontend_dim), jnp.float32)}
    with mesh:
        p_sds, _ = model.abstract_params()
        p_sh = model.param_shardings(rules)
        lowered = jax.jit(model.forward, in_shardings=(p_sh, None)) \
            .lower(p_sds, specs)
        return lowered.compile().as_text()
