"""CHIME core: planner, kv_tiers, quant, fusion, dataflow (import submodules directly to avoid import cycles with repro.models)."""
