"""Compute/communication overlap + compressed cross-pod gradient exchange.

Two distributed-optimization mechanisms beyond plain pjit:

1. ``compressed_pod_allreduce`` — shard_map over the 'pod' axis: gradients
   are int8-quantized per tensor before the cross-pod psum and dequantized
   after, cutting the slow inter-pod link traffic 4x (bf16->int8 + scale).
   Intra-pod reductions stay full precision (XLA ICI collectives).

2. ``prefetch_hint`` — double-buffering marker for weight all-gathers under
   FSDP: we lean on XLA's latency-hiding scheduler (async collectives are
   enabled by default on TPU) and keep the per-layer weight gathers inside
   the scan body so gather(layer l+1) overlaps compute(layer l). The knob
   here is the scan unroll factor: unroll=2 gives the scheduler a window.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.quant import compress_grad, decompress_grad, grad_scale


def compressed_pod_allreduce(grads, mesh: Mesh):
    """int8-compressed mean-reduction of a grad pytree over the 'pod' axis.
    Layout inside each pod is untouched (specs preserved per leaf).

    Every pod quantizes onto the SAME int8 grid: the per-pod scales are
    pmax-reduced first and that shared (truly conservative) scale is used
    both to quantize and to dequantize the int32 payload sum. Summing
    payloads quantized with *different* per-pod scales and dequantizing
    with their mean is wrong whenever pod magnitudes differ — the mean is
    a scale no pod actually used."""
    if "pod" not in mesh.shape:
        return grads
    npods = mesh.shape["pod"]

    def one(g):
        def body(gl):
            shared = jax.lax.pmax(grad_scale(gl), "pod")
            q, _ = compress_grad(gl, scale=shared)
            qsum = jax.lax.psum(q.astype(jnp.int32), "pod")
            return decompress_grad(qsum, shared, gl.dtype) / npods

        spec = P(*([None] * g.ndim))
        return shard_map(body, mesh=mesh, in_specs=spec, out_specs=spec,
                         check_rep=False)(g)

    return jax.tree.map(one, grads)


def unrolled_scan(body, carry, xs, unroll: int = 2):
    """lax.scan with partial unroll — the window the latency-hiding
    scheduler uses to overlap the next iteration's weight all-gather with
    the current iteration's compute."""
    return jax.lax.scan(body, carry, xs, unroll=unroll)


@functools.partial(jax.jit, static_argnames=("axis",))
def straggler_allreduce_timeout_stub(x, axis: str = "pod"):
    """Placeholder for bounded-staleness collectives (gradient exchange
    that proceeds with N-1 pods if one exceeds the deadline). XLA exposes
    no timeout collectives; the fault loop (runtime/fault.py) provides the
    recovery path instead. Kept as the documented integration point."""
    return x
