"""Fault tolerance, straggler mitigation, and elastic re-meshing.

At thousands of nodes the failure model is: (a) a host dies mid-step,
(b) a host straggles (slow NIC/thermal throttle), (c) a pod drops and the
job must continue on fewer pods. The policies here are the orchestration
layer over the substrate primitives that make each recoverable:

  (a) crash     -> CheckpointManager (atomic publish) + seekable data
                   pipeline: restart replays from the last step exactly.
  (b) straggler -> per-step deadline watchdog; on trip, the step is
                   abandoned and retried; repeated trips mark the host
                   suspect and trigger (c).
  (c) elasticity-> re-mesh to a smaller 'data'/'pod' extent. Because ALL
                   sharding in this framework is resolved from logical
                   axis rules at mesh-bind time (repro/sharding.py), a new
                   mesh re-derives every NamedSharding mechanically; the
                   checkpoint is resharded on restore (numpy leaves are
                   mesh-agnostic).

The watchdog/elastic loop runs in-process here (single-host container);
on a real cluster the same state machine runs in the job coordinator.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

from repro.checkpoint import CheckpointManager


@dataclasses.dataclass
class FaultPolicy:
    step_deadline_s: float = 300.0      # straggler trip wire
    max_retries_per_step: int = 2       # then escalate to elastic re-mesh
    checkpoint_every: int = 50
    suspect_threshold: int = 3          # trips before a host is evicted


@dataclasses.dataclass
class StepReport:
    step: int
    duration_s: float
    retries: int
    deadline_trip: bool


class FaultTolerantLoop:
    """Wraps a step callable with watchdog + checkpoint + resume logic."""

    def __init__(self, step_fn: Callable, ckpt: CheckpointManager,
                 policy: FaultPolicy = FaultPolicy()):
        self.step_fn = step_fn
        self.ckpt = ckpt
        self.policy = policy
        self.trips: dict[int, int] = {}
        self.reports: list[StepReport] = []

    def resume_or_init(self, state):
        latest = self.ckpt.latest_step()
        if latest is None:
            return state, 0
        state, step = self.ckpt.restore(state, latest)
        return state, step + 1

    def run(self, state, batches: Callable[[int], dict], start_step: int,
            num_steps: int, on_metrics: Callable | None = None):
        step = start_step
        end = start_step + num_steps
        while step < end:
            t0 = time.time()
            retries = 0
            while True:
                try:
                    state, metrics = self.step_fn(state, batches(step))
                    break
                except Exception:  # noqa: BLE001 — host fault surface
                    retries += 1
                    if retries > self.policy.max_retries_per_step:
                        # escalate: restore last checkpoint (simulated
                        # re-mesh entry point on a real cluster)
                        state, ck_step = self.ckpt.restore(state)
                        step = ck_step + 1
                        retries = 0
            dur = time.time() - t0
            trip = dur > self.policy.step_deadline_s
            if trip:
                self.trips[step] = self.trips.get(step, 0) + 1
            self.reports.append(StepReport(step, dur, retries, trip))
            if on_metrics is not None:
                on_metrics(step, metrics)
            if (step + 1) % self.policy.checkpoint_every == 0:
                self.ckpt.save(state, step)
            step += 1
        self.ckpt.save(state, step - 1)
        return state, step


def shrink_mesh_axes(n_pods_alive: int, multi_pod_shape=(2, 16, 16)):
    """Elastic re-mesh decision: drop the dead pod(s), keep (data, model)
    intact so only the batch section changes. Returns the new mesh shape —
    sharding rules re-resolve everything else."""
    pod, data, model = multi_pod_shape
    alive = max(1, min(n_pods_alive, pod))
    if alive == 1:
        return (data, model), ("data", "model")
    return (alive, data, model), ("pod", "data", "model")
