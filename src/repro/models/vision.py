"""Modality frontends (STUBS per assignment: input_specs() provides
precomputed patch/frame embeddings) and the MLLM connector.

This mirrors the paper's Fig. 5(a) decomposition: encoder -> connector ->
backbone, with the paper's profiling insight that encoder+connector are
<15% of runtime. The connector (MLP projector producing pseudo-tokens) is
implemented in full — it is one of the "latency-critical kernels" CHIME
places in the DRAM domain.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ParamBuilder, embed_axis


def init_frontend(b: ParamBuilder, cfg: ModelConfig):
    f = cfg.frontend
    e = embed_axis(cfg)
    if f.connector == "mlp":
        b.param("w1", (f.frontend_dim, cfg.d_model), (None, e))
        b.param("b1", (cfg.d_model,), (None,), init="zeros")
        b.param("w2", (cfg.d_model, cfg.d_model), (e, None))
        b.param("b2", (cfg.d_model,), (None,), init="zeros")
    else:
        b.param("w1", (f.frontend_dim, cfg.d_model), (None, e))
        b.param("b1", (cfg.d_model,), (None,), init="zeros")


def apply_connector(p: dict, cfg: ModelConfig, feats: jax.Array) -> jax.Array:
    """Project precomputed frontend embeddings into backbone pseudo-tokens.
    feats: (B, T, frontend_dim) -> (B, T, d_model)."""
    cd = cfg.compute_dtype
    h = jnp.einsum("btf,fd->btd", feats.astype(cd), p["w1"].astype(cd)) \
        + p["b1"].astype(cd)
    if "w2" in p:
        h = jax.nn.gelu(h)
        h = jnp.einsum("btd,de->bte", h, p["w2"].astype(cd)) \
            + p["b2"].astype(cd)
    return h
