"""Unified, config-driven model: every assigned architecture and the paper's
MLLMs instantiate this one class.

Execution modes:
  * full    — whole-sequence forward (training loss fwd / encoder inference)
  * prefill — whole-sequence forward that also builds the KV/state caches
  * decode  — one token against the caches (serve_step)

Layers are grouped into scan *units* (homogeneous repeated blocks); each
unit's params/caches carry a leading repeat axis and are scanned with
configurable remat — this keeps the lowered HLO compact even for
nemotron-340b's 96 layers on a 512-device mesh. Zamba2's shared attention
block is closed over (not scanned) so its single weight set is reused by all
applications, faithful to the architecture.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import fusion, kv_tiers as KT
from repro.models import attention as A
from repro.models import layers as L
from repro.models import ssm as S
from repro.models import vision as V
from repro.runtime.overlap import unrolled_scan
from repro.sharding import ShardingRules, logical_constraint, tree_shardings

MAX_LEARNED_POS = 32_768


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    mixer: str                 # attn | attn_shared | mla | rwkv6 | mamba2
    mlp: Optional[str]         # mlp kind or None (mixer-only block)
    d_ff: int


@dataclasses.dataclass(frozen=True)
class UnitSpec:
    block: BlockSpec
    repeats: int


def build_plan(cfg: ModelConfig) -> list[UnitSpec]:
    """Flatten segments into per-layer BlockSpecs, then compress consecutive
    identical specs into scanable units."""
    specs: list[BlockSpec] = []
    idx = 0
    for seg in cfg.segments:
        for _ in range(seg.repeats):
            for mixer in seg.pattern:
                if mixer == "mamba2" and cfg.family == "hybrid":
                    mlp = None
                elif mixer == "rwkv6":
                    mlp = "rwkv_cm"
                elif cfg.mlp_type == "moe":
                    if cfg.moe and idx < cfg.moe.first_dense_layers:
                        mlp = "dense_first"
                    else:
                        mlp = "moe"
                else:
                    mlp = cfg.mlp_type
                specs.append(BlockSpec(mixer, mlp, cfg.d_ff))
                idx += 1
    units: list[UnitSpec] = []
    for s in specs:
        if units and units[-1].block == s:
            units[-1] = UnitSpec(s, units[-1].repeats + 1)
        else:
            units.append(UnitSpec(s, 1))
    return units


class Model:
    """See module docstring. ``rules`` (ShardingRules) is optional: None for
    single-device smoke tests, a mesh-bound resolver for pjit execution."""

    def __init__(self, cfg: ModelConfig, rules: ShardingRules | None = None):
        self.cfg = cfg
        self.rules = rules
        self.plan = build_plan(cfg)
        self.has_shared_attn = any(
            u.block.mixer == "attn_shared" for u in self.plan)
        # pad vocab (Megatron-style) so embeddings/logits shard over 'model'
        m = cfg.vocab_pad_multiple
        self.padded_vocab = ((cfg.vocab_size + m - 1) // m) * m
        self._stream_units = frozenset(self.streamed_units())

    def streamed_units(self) -> tuple[int, ...]:
        """Plan-unit indices whose per-layer weight slices live in the
        simulated RRAM tier under ``cfg.weight_stream_layers`` (W): a
        unit streams iff it is scanned (repeats > 1 with scan_layers),
        carries its own per-layer params (shared-attention units do
        not), and its repeat count exceeds the W-repeat DRAM sliding
        window — otherwise the whole unit already fits the window and
        stays resident."""
        W = int(getattr(self.cfg, "weight_stream_layers", 0) or 0)
        if W < 1 or not self.cfg.scan_layers:
            return ()
        return tuple(ui for ui, u in enumerate(self.plan)
                     if u.repeats > W
                     and u.block.mixer != "attn_shared")

    # ------------------------------------------------------------------
    # parameters
    # ------------------------------------------------------------------
    def _init_block(self, b: L.ParamBuilder, spec: BlockSpec):
        cfg = self.cfg
        ln1 = b.scope("ln1")
        L.init_norm(ln1, cfg)
        mix = b.scope("mixer")
        if spec.mixer in ("attn", "attn_shared"):
            A.init_attn(mix, cfg)
        elif spec.mixer == "mla":
            A.init_mla(mix, cfg)
        elif spec.mixer == "rwkv6":
            S.init_rwkv6(mix, cfg)
        elif spec.mixer == "mamba2":
            S.init_mamba2(mix, cfg)
        else:
            raise ValueError(spec.mixer)
        if spec.mlp is not None:
            ln2 = b.scope("ln2")
            L.init_norm(ln2, cfg)
            mlp = b.scope("mlp")
            if spec.mlp == "moe":
                L.init_moe(mlp, cfg)
            elif spec.mlp == "dense_first":
                L.init_mlp(mlp, cfg, d_ff=cfg.moe.d_ff_dense,
                           mlp_type="silu_gated")
            elif spec.mlp == "rwkv_cm":
                L.init_rwkv_cm(mlp, cfg)
            else:
                L.init_mlp(mlp, cfg, mlp_type=spec.mlp)

    def _build(self, rng, abstract: bool):
        cfg = self.cfg
        dt = jnp.dtype(cfg.param_dtype)
        b = L.ParamBuilder(rng, dt, abstract=abstract)
        e = L.embed_axis(cfg)
        if cfg.family != "audio":
            emb = b.scope("embed")
            emb.param("table", (self.padded_vocab, cfg.d_model),
                      ("vocab", e), scale=1.0)
        if cfg.pos_emb == "learned":
            b.param("pos_emb", (MAX_LEARNED_POS, cfg.d_model), (None, e),
                    scale=0.02)
        if cfg.frontend is not None:
            fe = b.scope("frontend")
            V.init_frontend(fe, cfg)
        units = b.scope("units")
        for ui, unit in enumerate(self.plan):
            if unit.block.mixer == "attn_shared":
                continue  # shared weights live at top level
            if unit.repeats == 1:
                ub = units.scope(f"u{ui}")
                self._init_block(ub, unit.block)
            else:
                if abstract:
                    ub = L.ParamBuilder(None, dt, abstract=True)
                    self._init_block(ub, unit.block)
                    stacked = jax.tree.map(
                        lambda s: jax.ShapeDtypeStruct(
                            (unit.repeats,) + s.shape, s.dtype), ub.params)
                    units.params[f"u{ui}"] = stacked
                    units.axes[f"u{ui}"] = jax.tree.map(
                        lambda ax: (None,) + ax, ub.axes,
                        is_leaf=lambda x: isinstance(x, tuple))
                else:
                    rngs = jax.random.split(b._split(), unit.repeats)

                    def one(r):
                        bb = L.ParamBuilder(r, dt)
                        self._init_block(bb, unit.block)
                        return bb.params
                    units.params[f"u{ui}"] = jax.vmap(one)(rngs)
                    ab = L.ParamBuilder(None, dt, abstract=True)
                    self._init_block(ab, unit.block)
                    units.axes[f"u{ui}"] = jax.tree.map(
                        lambda ax: (None,) + ax, ab.axes,
                        is_leaf=lambda x: isinstance(x, tuple))
        if self.has_shared_attn:
            sb = b.scope("shared_attn")
            self._init_block(
                sb, BlockSpec("attn", self.cfg.mlp_type, self.cfg.d_ff))
        fn = b.scope("final_norm")
        L.init_norm(fn, cfg)
        if not cfg.tie_embeddings:
            b.param("lm_head", (cfg.d_model, self.padded_vocab),
                    (e, "vocab"), scale=cfg.d_model ** -0.5)
        return b.params, b.axes

    def init(self, rng) -> dict:
        params, _ = self._build(rng, abstract=False)
        return params

    def abstract_params(self) -> tuple[dict, dict]:
        """(ShapeDtypeStruct tree, logical-axes tree) without allocation."""
        return self._build(None, abstract=True)

    def param_shardings(self, rules: ShardingRules):
        shapes, axes = self.abstract_params()
        return jax.tree.map(
            lambda sd, ax: rules.sharding(ax, sd.shape), shapes, axes,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))

    # ------------------------------------------------------------------
    # caches
    # ------------------------------------------------------------------
    def _block_cache_abstract(self, spec: BlockSpec, batch: int,
                              max_len: int) -> tuple[Any, Any]:
        """(shape tree, logical tree) for one block's cache."""
        cfg = self.cfg
        pol = cfg.kv_policy
        W = cfg.kv_hot_window
        cd = jnp.dtype(cfg.compute_dtype)
        if spec.mixer in ("attn", "attn_shared"):
            inner = (cfg.num_kv_heads, cfg.head_dim)
            shp = {
                "k": KT.store_init(batch, max_len, inner, pol, W, cd),
                "v": KT.store_init(batch, max_len, inner, pol, W, cd),
            }
            lg = {"k": KT.store_logical(("kv_heads", None), pol),
                  "v": KT.store_logical(("kv_heads", None), pol)}
        elif spec.mixer == "mla":
            m = cfg.mla
            shp = {
                "c_kv": KT.store_init(batch, max_len, (m.kv_lora_rank,),
                                      pol, W, cd),
                "k_rope": KT.store_init(batch, max_len,
                                        (m.qk_rope_head_dim,), pol, W, cd),
            }
            lg = {"c_kv": KT.store_logical((None,), pol),
                  "k_rope": KT.store_logical((None,), pol)}
        elif spec.mixer == "rwkv6":
            shp = {"tm": S.init_rwkv6_state(cfg, batch),
                   "cm_x_prev": jnp.zeros((batch, cfg.d_model), cd)}
            lg = {"tm": S.rwkv6_state_logical(),
                  "cm_x_prev": ("batch", None)}
        elif spec.mixer == "mamba2":
            shp = S.init_mamba2_state(cfg, batch)
            lg = S.mamba2_state_logical()
        else:
            shp, lg = {}, {}
        return shp, lg

    def init_cache(self, batch: int, max_len: int) -> dict:
        cache = {}
        for ui, unit in enumerate(self.plan):
            shp, _ = self._block_cache_abstract(unit.block, batch, max_len)
            if unit.repeats > 1:
                shp = jax.tree.map(
                    lambda a: jnp.broadcast_to(
                        a[None], (unit.repeats,) + a.shape), shp)
            cache[f"u{ui}"] = shp
        return cache

    def cache_spec(self, batch: int, max_len: int) -> tuple[dict, dict]:
        """(ShapeDtypeStruct tree, logical tree) for the full cache."""
        shapes, logical = {}, {}
        for ui, unit in enumerate(self.plan):
            shp, lg = self._block_cache_abstract(unit.block, batch, max_len)
            shp = jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), shp)
            if unit.repeats > 1:
                shp = jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct(
                        (unit.repeats,) + s.shape, s.dtype), shp)
                lg = jax.tree.map(
                    lambda ax: (None,) + ax, lg,
                    is_leaf=lambda x: isinstance(x, tuple))
            shapes[f"u{ui}"], logical[f"u{ui}"] = shp, lg
        return shapes, logical

    def cache_shardings(self, rules: ShardingRules, batch: int,
                        max_len: int):
        """NamedSharding tree for a ``batch``-slot decode cache: the slot
        axis resolves over 'data', cold kv_seq / kv heads over 'model'
        (divisibility permitting) — the layout the sharded serving
        backend pins its KV pool to."""
        shapes, logical = self.cache_spec(batch, max_len)
        return tree_shardings(rules, logical, shapes)

    # ------------------------------------------------------------------
    # extend (chunked-prefill) caches
    # ------------------------------------------------------------------
    def _block_extend_abstract(self, spec: BlockSpec, batch: int,
                               max_len: int) -> tuple[Any, Any]:
        """(shape tree, logical tree) of one block's chunk-resumable
        extend state. Attention/MLA blocks carry a full-precision
        workspace (the accumulated post-RoPE K/V / latents of the chunks
        so far — the same tensor whole-prompt prefill materializes
        transiently); recurrent blocks' regular decode states are already
        chunk-resumable and are reused verbatim."""
        cfg = self.cfg
        cd = jnp.dtype(cfg.compute_dtype)
        if spec.mixer in ("attn", "attn_shared"):
            inner = (cfg.num_kv_heads, cfg.head_dim)
            shp = {"k_ws": jnp.zeros((batch, max_len) + inner, cd),
                   "v_ws": jnp.zeros((batch, max_len) + inner, cd)}
            ax = ("batch", "kv_seq_shard", "kv_heads", None)
            lg = {"k_ws": ax, "v_ws": ax}
        elif spec.mixer == "mla":
            m = cfg.mla
            shp = {"c_kv_ws": jnp.zeros((batch, max_len, m.kv_lora_rank),
                                        cd),
                   "k_rope_ws": jnp.zeros(
                       (batch, max_len, m.qk_rope_head_dim), cd)}
            lg = {"c_kv_ws": ("batch", "kv_seq_shard", None),
                  "k_rope_ws": ("batch", "kv_seq_shard", None)}
        else:
            shp, lg = self._block_cache_abstract(spec, batch, max_len)
        return shp, lg

    def init_extend_cache(self, batch: int, max_len: int) -> dict:
        """Fresh (zero) chunk-resumable prefill state for `extend`."""
        cache = {}
        for ui, unit in enumerate(self.plan):
            shp, _ = self._block_extend_abstract(unit.block, batch, max_len)
            if unit.repeats > 1:
                shp = jax.tree.map(
                    lambda a: jnp.broadcast_to(
                        a[None], (unit.repeats,) + a.shape), shp)
            cache[f"u{ui}"] = shp
        return cache

    def extend_spec(self, batch: int, max_len: int) -> tuple[dict, dict]:
        """(ShapeDtypeStruct tree, logical tree) for the extend state."""
        shapes, logical = {}, {}
        for ui, unit in enumerate(self.plan):
            shp, lg = self._block_extend_abstract(unit.block, batch,
                                                  max_len)
            shp = jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), shp)
            if unit.repeats > 1:
                shp = jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct(
                        (unit.repeats,) + s.shape, s.dtype), shp)
                lg = jax.tree.map(
                    lambda ax: (None,) + ax, lg,
                    is_leaf=lambda x: isinstance(x, tuple))
            shapes[f"u{ui}"], logical[f"u{ui}"] = shp, lg
        return shapes, logical

    def extend_shardings(self, rules: ShardingRules, batch: int,
                         max_len: int):
        """NamedSharding tree for the extend state (workspace kv_seq over
        'model', divisibility permitting) — what the sharded backend pins
        its in-flight prefill lane to."""
        shapes, logical = self.extend_spec(batch, max_len)
        return tree_shardings(rules, logical, shapes)

    # ------------------------------------------------------------------
    # forward
    # ------------------------------------------------------------------
    def _embed(self, params: dict, batch: dict, pos,
               ramp: bool = False) -> tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        cd = jnp.dtype(cfg.compute_dtype)
        if cfg.family == "audio":
            x = V.apply_connector(params["frontend"], cfg, batch["frames"])
        elif cfg.frontend is not None and "patches" in batch:
            vis = V.apply_connector(params["frontend"], cfg,
                                    batch["patches"])
            if "tokens" in batch:
                txt = jnp.take(params["embed"]["table"], batch["tokens"],
                               axis=0).astype(cd)
                x = jnp.concatenate([vis, txt], axis=1)
            else:
                # patches-only extend chunk (a VQA prompt's visual span)
                x = vis
        else:
            x = jnp.take(params["embed"]["table"], batch["tokens"],
                         axis=0).astype(cd)
        B, Sq = x.shape[:2]
        if pos is None:
            positions = jnp.broadcast_to(jnp.arange(Sq), (B, Sq))
        elif ramp:
            # extend chunk: rows sit at absolute positions pos..pos+Sq-1
            positions = jnp.broadcast_to(
                pos + jnp.arange(Sq, dtype=jnp.int32), (B, Sq))
        else:
            positions = jnp.full((B, Sq), pos, jnp.int32)
        if cfg.pos_emb == "learned":
            x = x + jnp.take(params["pos_emb"],
                             jnp.minimum(positions, MAX_LEARNED_POS - 1),
                             axis=0).astype(cd)
        if self.rules is not None:
            x = logical_constraint(self.rules, x, self._res_axes(x))
        return x, positions

    def _res_axes(self, x) -> tuple:
        """Residual-stream logical axes; seq_sharding (Megatron-SP) shards
        the seq dim over 'model' so saved activations scale with TP."""
        seq_ax = "seq_sp" if (self.cfg.seq_sharding
                              and x.shape[1] > 1) else None
        return ("batch", seq_ax, None)

    def _run_block(self, spec: BlockSpec, bp: dict, shared_p: dict | None,
                   x: jax.Array, positions: jax.Array, bcache: dict,
                   pos, mode: str, plen=None, commit: bool = False
                   ) -> tuple[jax.Array, dict, jax.Array]:
        cfg = self.cfg
        rules = self.rules
        aux = jnp.zeros((), jnp.float32)
        p = shared_p if spec.mixer == "attn_shared" else bp
        build_cache = (mode == "prefill")
        # extend dispatches on the cache form: workspace dicts ({"k_ws"} /
        # {"c_kv_ws"}) mean chunk-resumable prefill; the regular store form
        # means a committed request, where extend-by-1 IS the decode step
        ext_prefill = mode == "extend" and bcache is not None and (
            "k_ws" in bcache or "c_kv_ws" in bcache)
        # pre-norm -> mixer -> residual
        h = fusion.apply_norm(p["ln1"], cfg, x)
        new_cache = dict(bcache) if bcache else {}
        if spec.mixer in ("attn", "attn_shared"):
            if mode == "extend" and ext_prefill:
                out, new_cache = fusion.apply_attention_extend(
                    p["mixer"], cfg, h, positions, bcache, pos, plen,
                    rules, commit)
            elif mode == "decode" or mode == "extend":
                out, nc = fusion.apply_attention_decode(
                    p["mixer"], cfg, h, bcache, pos, rules)
                new_cache = nc
            else:
                ml = (bcache["k"]["flat"].shape[1] if bcache and
                      "flat" in bcache["k"] else
                      bcache["k"]["cold_q"].shape[1] if bcache else 0)
                out, nc = fusion.apply_attention_seq(
                    p["mixer"], cfg, h, positions, rules,
                    causal=not cfg.is_encoder,
                    build_cache=build_cache and bool(bcache), max_len=ml,
                    length=plen)
                if nc is not None:
                    new_cache = nc
        elif spec.mixer == "mla":
            if mode == "extend" and ext_prefill:
                out, new_cache = fusion.apply_mla_extend(
                    p["mixer"], cfg, h, positions, bcache, pos, plen,
                    rules, commit)
            elif mode == "decode" or mode == "extend":
                out, new_cache = fusion.apply_mla_decode(
                    p["mixer"], cfg, h, bcache, pos, rules)
            else:
                ml = (bcache["c_kv"]["flat"].shape[1] if bcache and
                      "flat" in bcache["c_kv"] else
                      bcache["c_kv"]["cold_q"].shape[1] if bcache else 0)
                out, nc = fusion.apply_mla_seq(
                    p["mixer"], cfg, h, positions, rules,
                    causal=not cfg.is_encoder,
                    build_cache=build_cache and bool(bcache), max_len=ml,
                    length=plen)
                if nc is not None:
                    new_cache = nc
        elif spec.mixer == "rwkv6":
            state = bcache.get("tm") if (bcache and mode != "full") else None
            out, tm_state = S.apply_rwkv6(p["mixer"], cfg, h, state)
            if bcache:
                new_cache = dict(new_cache)
                new_cache["tm"] = tm_state
        elif spec.mixer == "mamba2":
            state = bcache if (bcache and mode != "full") else None
            out, m_state = S.apply_mamba2(p["mixer"], cfg, h, state)
            if bcache:
                new_cache = m_state
        else:
            raise ValueError(spec.mixer)
        x = x + out

        # mlp half-block
        if spec.mlp is not None:
            h2 = fusion.apply_norm(p["ln2"], cfg, x)
            if spec.mlp == "rwkv_cm":
                xp = (bcache.get("cm_x_prev")
                      if (bcache and mode != "full") else None)
                out2, cm_prev = L.apply_rwkv_cm(p["mlp"], cfg, h2, rules, xp)
                if bcache:
                    new_cache = dict(new_cache)
                    new_cache["cm_x_prev"] = cm_prev.astype(
                        jnp.dtype(cfg.compute_dtype))
            else:
                d_ff = (cfg.moe.d_ff_dense if spec.mlp == "dense_first"
                        else spec.d_ff)
                kind = ("silu_gated" if spec.mlp == "dense_first"
                        else spec.mlp)
                # inference routing is dropless: capacity competition
                # couples tokens across the batch, which would make
                # chunked prefill depend on the chunking
                out2 = fusion.apply_ffn(p["mlp"], cfg, h2, rules,
                                        mlp_type=kind, d_ff=d_ff,
                                        dropless_moe=(mode != "full"))
                if spec.mlp == "moe" and mode == "full":
                    aux = aux + L.moe_aux_loss(p["mlp"], cfg, h2)
            x = x + out2
        if rules is not None:
            x = logical_constraint(rules, x, self._res_axes(x))
        return x, new_cache, aux

    def _run_unit(self, ui: int, unit: UnitSpec, params: dict,
                  x: jax.Array, positions: jax.Array, ucache: dict,
                  pos, mode: str, plen=None, commit: bool = False
                  ) -> tuple[jax.Array, dict, jax.Array]:
        cfg = self.cfg
        shared_p = params.get("shared_attn")
        up = params["units"].get(f"u{ui}")

        def body(x, bp, bc):
            return self._run_block(unit.block, bp, shared_p, x, positions,
                                   bc, pos, mode, plen, commit)

        if mode == "full" and cfg.remat != "none":
            policy = (jax.checkpoint_policies.checkpoint_dots
                      if cfg.remat == "save_dots" else None)
            body = jax.checkpoint(body, policy=policy)

        if unit.repeats == 1:
            return body(x, up, ucache)

        if not cfg.scan_layers:
            aux_t = jnp.zeros((), jnp.float32)
            ncs = []
            for r in range(unit.repeats):
                bp = (None if up is None else
                      jax.tree.map(lambda a: a[r], up))
                bc = jax.tree.map(lambda a: a[r], ucache)
                x, nc, aux = body(x, bp, bc)
                ncs.append(nc)
                aux_t = aux_t + aux
            stacked = (jax.tree.map(lambda *a: jnp.stack(a), *ncs)
                       if ncs and jax.tree.leaves(ncs[0]) else {})
            return x, stacked, aux_t

        unroll = max(int(getattr(cfg, "scan_unroll", 1) or 1), 1)
        if ui in self._stream_units and up is not None:
            # RRAM weight streaming: the scan carry holds the CURRENT
            # layer's params (the DRAM prefetch buffer) while xs delivers
            # the NEXT layer's slice from the stacked (tier-resident)
            # array — the `runtime/overlap.py` double-buffer shape, so
            # the fetch of layer l+1 sits in the same unrolled window as
            # the compute of layer l. Values and order are untouched:
            # iteration r still computes with up[r], bit-identical to the
            # resident scan below.
            bp0 = jax.tree.map(lambda a: a[0], up)
            nxt = jax.tree.map(lambda a: jnp.roll(a, -1, axis=0), up)

            def stream_body(carry, xs):
                x, aux_t, bp = carry
                bp_next, bc = xs
                x, nc, aux = body(x, bp, bc)
                return (x, aux_t + aux, bp_next), nc

            (x, aux_t, _), new_cache = unrolled_scan(
                stream_body, (x, jnp.zeros((), jnp.float32), bp0),
                (nxt, ucache), unroll=max(unroll, 2))
            return x, new_cache, aux_t

        def scan_body(carry, xs):
            x, aux_t = carry
            bp, bc = xs
            x, nc, aux = body(x, bp, bc)
            return (x, aux_t + aux), nc

        (x, aux_t), new_cache = unrolled_scan(
            scan_body, (x, jnp.zeros((), jnp.float32)), (up, ucache),
            unroll=unroll)
        return x, new_cache, aux_t

    def _forward(self, params: dict, batch: dict, mode: str,
                 cache: dict | None, pos, plen=None, commit: bool = False
                 ) -> tuple[jax.Array, dict, jax.Array]:
        cfg = self.cfg
        x, positions = self._embed(params, batch, pos,
                                   ramp=(mode == "extend"))
        if cache is None:
            cache = {f"u{ui}": {} for ui in range(len(self.plan))}
        new_cache = {}
        aux_total = jnp.zeros((), jnp.float32)
        for ui, unit in enumerate(self.plan):
            x, nc, aux = self._run_unit(
                ui, unit, params, x, positions, cache[f"u{ui}"], pos, mode,
                plen, commit)
            new_cache[f"u{ui}"] = nc
            aux_total = aux_total + aux
        x = fusion.apply_norm(params["final_norm"], cfg, x)
        if mode in ("prefill", "extend"):
            if plen is None:
                x = x[:, -1:]
            else:
                # right-padded prompt/chunk: the last VALID row is plen - 1
                x = jax.lax.dynamic_slice_in_dim(x, plen - 1, 1, axis=1)
        if cfg.tie_embeddings:
            logits = jnp.einsum(
                "bsd,vd->bsv", x,
                params["embed"]["table"].astype(cfg.compute_dtype))
        else:
            logits = jnp.einsum(
                "bsd,dv->bsv", x,
                params["lm_head"].astype(cfg.compute_dtype))
        if self.rules is not None:
            logits = logical_constraint(
                self.rules, logits, ("batch", None, "vocab"))
        return logits, new_cache, aux_total

    # ------------------------------------------------------------------
    # public entry points
    # ------------------------------------------------------------------
    def forward(self, params: dict, batch: dict) -> jax.Array:
        logits, _, _ = self._forward(params, batch, "full", None, None)
        return logits

    def loss(self, params: dict, batch: dict) -> jax.Array:
        logits, _, aux = self._forward(params, batch, "full", None, None)
        labels = batch["labels"]
        # logsumexp formulation: never materializes full log-probs, so the
        # (tokens, vocab) working set stays a single (sharded) tensor
        lf = logits.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(lf, axis=-1)
        picked = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
        ll = picked - lse
        mask = batch.get("loss_mask")
        if mask is None:
            mask = jnp.ones_like(ll)
        loss = -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return loss + 0.01 * aux

    def prefill(self, params: dict, batch: dict, max_len: int,
                length=None) -> tuple[jax.Array, dict]:
        """Returns last-token logits + filled caches. ``length`` (traced
        scalar) is the count of valid prompt tokens when the batch is
        right-padded to a serving bucket; None means the full sequence is
        valid (seed behaviour)."""
        # batch size from any input tensor
        bsz = jax.tree.leaves(batch)[0].shape[0]
        cache = self.init_cache(bsz, max_len)
        logits, new_cache, _ = self._forward(
            params, batch, "prefill", cache, None, plen=length)
        return logits, new_cache

    def extend(self, params: dict, batch: dict, cache: dict, pos,
               length=None, commit: bool = False
               ) -> tuple[jax.Array, dict]:
        """Multi-token cache extension — the unified serving entry point.

        Processes a chunk of the sequence whose rows sit at absolute
        positions ``pos .. pos + C - 1`` (C from the batch shape; the
        first ``length`` rows are valid, the rest padding). Generalizes
        the two-phase serving surface:

        * chunked prefill — ``cache`` is the workspace form from
          `init_extend_cache`: the chunk attends the accumulated
          full-precision workspace causally, so any chunking of a prompt
          is token-for-token identical to whole-prompt `prefill`.
          ``commit=True`` on the final chunk folds the workspace into the
          regular flat/CHIME-tiered stores (ready to scatter into a pool
          slot); recurrent (SSM/RWKV) states are chunk-resumable as-is
          and pass through. Recurrent architectures need exact-length,
          `cfg.ssm.chunk_size`-aligned chunks (see
          `InferenceBackend.requires_exact_prefill` / `chunk_unit`).
        * decode — ``cache`` in the committed store form with a 1-token
          batch is exactly `decode_step` (append at ``pos``, attend the
          tiered/flat stores).

        Returns (logits of the last valid row (B,1,V), new cache)."""
        if self.cfg.is_encoder:
            raise ValueError("encoder-only model cannot extend a cache")
        logits, new_cache, _ = self._forward(
            params, batch, "extend", cache, pos, plen=length,
            commit=commit)
        return logits, new_cache

    def decode_step(self, params: dict, tokens: jax.Array, cache: dict,
                    pos) -> tuple[jax.Array, dict]:
        """One decode step: tokens (B,1) int32, pos scalar int32 = index the
        new token is written at (number of tokens already cached). A thin
        wrapper over `extend` (extend-by-1 on a committed cache)."""
        return self.extend(params, {"tokens": tokens}, cache, pos,
                           length=1)
