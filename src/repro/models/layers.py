"""Shared layers: param builder, norms, rotary, MLP variants, MoE.

All parameters are built through ``ParamBuilder`` which records, next to every
array, its *logical sharding axes* — the single source of truth the launcher
uses to derive NamedShardings for any mesh (see repro/sharding.py).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


# ---------------------------------------------------------------------------
# parameter builder
# ---------------------------------------------------------------------------
class ParamBuilder:
    """Builds a params pytree and a parallel pytree of logical-axis tuples."""

    def __init__(self, rng: jax.Array | None, dtype: jnp.dtype,
                 abstract: bool = False):
        self.rng = rng
        self.dtype = dtype
        self.abstract = abstract
        self.params: dict = {}
        self.axes: dict = {}

    def _split(self) -> jax.Array | None:
        if self.abstract:
            return None
        self.rng, sub = jax.random.split(self.rng)
        return sub

    def scope(self, name: str) -> "ParamBuilder":
        sub = ParamBuilder(self._split(), self.dtype, self.abstract)
        self.params[name] = sub.params
        self.axes[name] = sub.axes
        return sub

    def param(self, name: str, shape: tuple[int, ...],
              logical: tuple[str | None, ...],
              init: str = "normal", scale: float | None = None) -> None:
        assert len(shape) == len(logical), (name, shape, logical)
        if self.abstract:
            self.params[name] = jax.ShapeDtypeStruct(shape, self.dtype)
            self.axes[name] = logical
            return
        if init == "zeros":
            arr = jnp.zeros(shape, self.dtype)
        elif init == "ones":
            arr = jnp.ones(shape, self.dtype)
        else:
            fan_in = shape[0] if len(shape) > 1 else max(shape[0], 1)
            std = scale if scale is not None else fan_in ** -0.5
            arr = (jax.random.normal(self._split(), shape, jnp.float32)
                   * std).astype(self.dtype)
        self.params[name] = arr
        self.axes[name] = logical


def embed_axis(cfg: ModelConfig) -> str:
    """Weight-storage axis for the d_model dim: FSDP shards it over 'data'."""
    return "fsdp_embed" if getattr(cfg, "fsdp", False) else "embed"


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def init_norm(b: ParamBuilder, cfg: ModelConfig, dim: int | None = None):
    d = dim or cfg.d_model
    b.param("scale", (d,), (None,), init="ones")
    if cfg.norm_type == "layernorm":
        b.param("bias", (d,), (None,), init="zeros")


def apply_norm(p: dict, cfg: ModelConfig, x: jax.Array,
               eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    else:
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = ((xf - mean) * jax.lax.rsqrt(var + eps)
               * p["scale"].astype(jnp.float32)
               + p["bias"].astype(jnp.float32))
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary
# ---------------------------------------------------------------------------
def rope_freqs(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    dim = x.shape[-1]
    freqs = rope_freqs(dim, theta)                       # (dim/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..s,d/2)
    cos = jnp.cos(angles)[..., :, None, :]               # (.., s, 1, d/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# dense MLP variants
# ---------------------------------------------------------------------------
_ACT = {
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),
}


def init_mlp(b: ParamBuilder, cfg: ModelConfig, d_ff: int | None = None,
             mlp_type: str | None = None):
    d_ff = d_ff or cfg.d_ff
    kind = mlp_type or cfg.mlp_type
    e = embed_axis(cfg)
    gated = kind in ("silu_gated", "gelu_gated")
    if cfg.ffn_weight_store == "int8":
        # "RRAM-domain" dense storage: FFN weights held int8 with
        # per-output-column scales; dequant fuses into the GEMM, so HBM
        # traffic is the int8 array (half the bf16 bytes — the paper's
        # density/read-energy argument ported to TPU). Inference-only.
        sc_up = cfg.d_model ** -0.5 / 127.0
        sc_dn = d_ff ** -0.5 / 127.0
        _int8_param(b, "w_up_q", (cfg.d_model, d_ff), (e, "mlp"))
        _const_param(b, "w_up_scale", (d_ff,), ("mlp",), sc_up)
        if gated:
            _int8_param(b, "w_gate_q", (cfg.d_model, d_ff), (e, "mlp"))
            _const_param(b, "w_gate_scale", (d_ff,), ("mlp",), sc_up)
        _int8_param(b, "w_down_q", (d_ff, cfg.d_model), ("mlp", e))
        _const_param(b, "w_down_scale", (cfg.d_model,), (None,), sc_dn)
    else:
        b.param("w_up", (cfg.d_model, d_ff), (e, "mlp"))
        if gated:
            b.param("w_gate", (cfg.d_model, d_ff), (e, "mlp"))
        b.param("w_down", (d_ff, cfg.d_model), ("mlp", e))
    if cfg.use_mlp_bias:
        b.param("b_up", (d_ff,), ("mlp",), init="zeros")
        b.param("b_down", (cfg.d_model,), (None,), init="zeros")


def _int8_param(b: ParamBuilder, name: str, shape, logical):
    if b.abstract:
        b.params[name] = jax.ShapeDtypeStruct(shape, jnp.int8)
        b.axes[name] = logical
        return
    arr = jax.random.randint(b._split(), shape, -127, 128, jnp.int32)
    b.params[name] = arr.astype(jnp.int8)
    b.axes[name] = logical


def _const_param(b: ParamBuilder, name: str, shape, logical, value: float):
    if b.abstract:
        b.params[name] = jax.ShapeDtypeStruct(shape, jnp.float32)
    else:
        b.params[name] = jnp.full(shape, value, jnp.float32)
    b.axes[name] = logical


def apply_mlp(p: dict, cfg: ModelConfig, x: jax.Array, rules,
              mlp_type: str | None = None) -> jax.Array:
    """FUSED_FFN_ACT (Table I): GEMM -> (+bias) -> act -> GEMM -> (+bias).
    On TPU the fusion is realized either by XLA (jnp path) or by the Pallas
    ffn_act kernel; the int8 "RRAM" weight store is handled by the fusion
    registry (core/fusion.py) which wraps this. This is the jnp oracle path.
    """
    from repro.sharding import logical_constraint
    kind = mlp_type or cfg.mlp_type
    act = _ACT["silu" if kind == "silu_gated" else
               "gelu" if kind in ("gelu", "gelu_gated") else "relu2"]
    if "w_up_q" in p:
        # int8 "RRAM" store: dequant fused into the GEMM by XLA; the HBM
        # operand is the int8 array
        p = dict(p)
        cd = cfg.compute_dtype
        p["w_up"] = (p["w_up_q"].astype(cd)
                     * p["w_up_scale"].astype(cd))
        if "w_gate_q" in p:
            p["w_gate"] = (p["w_gate_q"].astype(cd)
                           * p["w_gate_scale"].astype(cd))
        p["w_down"] = (p["w_down_q"].astype(cd)
                       * p["w_down_scale"].astype(cd))
    h = jnp.einsum("...d,df->...f", x, p["w_up"].astype(cfg.compute_dtype))
    if "b_up" in p:
        h = h + p["b_up"].astype(h.dtype)
    h = act(h)
    if "w_gate" in p:
        h = h * jnp.einsum("...d,df->...f", x,
                           p["w_gate"].astype(cfg.compute_dtype))
    if rules is not None:
        h = logical_constraint(rules, h, ("batch",) + (None,) * (h.ndim - 2)
                               + ("mlp",))
    out = jnp.einsum("...f,fd->...d", h, p["w_down"].astype(cfg.compute_dtype))
    if "b_down" in p:
        out = out + p["b_down"].astype(out.dtype)
    if rules is not None and cfg.seq_sharding and out.ndim == 3 \
            and out.shape[1] > 1:
        # seq-shard the partial-sum output so XLA emits reduce-scatter
        # instead of all-reduce at the FFNOut cut point (Megatron-SP)
        out = logical_constraint(rules, out, ("batch", "seq_sp", None))
    return out


# ---------------------------------------------------------------------------
# RWKV6 channel-mix (token-shifted MLP)
# ---------------------------------------------------------------------------
def init_rwkv_cm(b: ParamBuilder, cfg: ModelConfig):
    e = embed_axis(cfg)
    b.param("mu_k", (cfg.d_model,), (None,), init="zeros")
    b.param("mu_r", (cfg.d_model,), (None,), init="zeros")
    b.param("w_k", (cfg.d_model, cfg.d_ff), (e, "mlp"))
    b.param("w_v", (cfg.d_ff, cfg.d_model), ("mlp", e))
    b.param("w_r", (cfg.d_model, cfg.d_model), (e, None))


def apply_rwkv_cm(p: dict, cfg: ModelConfig, x: jax.Array, rules,
                  x_prev: jax.Array | None = None
                  ) -> tuple[jax.Array, jax.Array]:
    """RWKV channel mix. x: (B,S,D). x_prev: (B,D) last token of the previous
    step (decode) or None (token shift within the sequence). Returns
    (out, new_x_prev)."""
    from repro.sharding import logical_constraint
    if x_prev is None:
        shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        shifted = jnp.concatenate(
            [x_prev[:, None, :], x[:, :-1]], axis=1) if x.shape[1] > 1 \
            else x_prev[:, None, :]
    xk = x + (shifted - x) * p["mu_k"].astype(x.dtype)
    xr = x + (shifted - x) * p["mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(
        jnp.einsum("bsd,df->bsf", xk, p["w_k"].astype(cfg.compute_dtype))))
    if rules is not None:
        k = logical_constraint(rules, k, ("batch", None, "mlp"))
    kv = jnp.einsum("bsf,fd->bsd", k, p["w_v"].astype(cfg.compute_dtype))
    r = jax.nn.sigmoid(
        jnp.einsum("bsd,de->bse", xr, p["w_r"].astype(cfg.compute_dtype)))
    return r * kv, x[:, -1]


# ---------------------------------------------------------------------------
# Mixture of Experts (sort-based, capacity-dropping — production style)
# ---------------------------------------------------------------------------
def init_moe(b: ParamBuilder, cfg: ModelConfig):
    assert cfg.moe is not None
    m = cfg.moe
    e = embed_axis(cfg)
    if cfg.moe_ff_fsdp:
        # shard the expert d_ff dim over 'data' (weights never gathered;
        # the contraction reduces the small routed activations instead)
        up_ax = ("experts", None, "moe_ff")
        dn_ax = ("experts", "moe_ff", None)
    else:
        up_ax = ("experts", e, None)
        dn_ax = ("experts", None, e)
    b.param("router", (cfg.d_model, m.num_experts), (e, None),
            scale=cfg.d_model ** -0.5)
    b.param("w_up", (m.num_experts, cfg.d_model, m.d_ff_expert), up_ax)
    b.param("w_gate", (m.num_experts, cfg.d_model, m.d_ff_expert), up_ax)
    b.param("w_down", (m.num_experts, m.d_ff_expert, cfg.d_model), dn_ax)
    if m.num_shared_experts > 0:
        sb = b.scope("shared")
        init_mlp(sb, cfg, d_ff=m.d_ff_shared, mlp_type="silu_gated")


def apply_moe(p: dict, cfg: ModelConfig, x: jax.Array, rules,
              dropless: bool = False) -> jax.Array:
    """Top-k routed experts with per-expert capacity, sort-based dispatch.

    Dispatch layout: tokens are sorted by assigned expert and scattered into
    an (E, C, d) buffer sharded expert-wise over the 'model' axis (expert
    parallelism) — XLA materializes the all-to-all at the shard boundary.
    Overflow beyond capacity C is dropped (weights renormalized), matching
    capacity-factor MoE training systems.

    ``dropless`` (the inference/serving path) sets the capacity to the
    worst case instead: capacity competition couples every token in the
    batch, so a dropped token depends on WHICH other tokens share its
    forward — that would make chunked prefill diverge from whole-prompt
    prefill. With no drops, routing is per-token independent and any
    chunking of a prompt is bit-identical. Cost: the (E, T, D) worst-case
    buffer inflates the dispatch einsums ~E/(k*capacity_factor)x over
    the capacity path — acceptable at serving chunk sizes; a sorted
    segment-GEMM over the T*k occupied rows is the known optimization if
    full-size MoE prefill throughput ever matters here.
    """
    from repro.sharding import logical_constraint
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    xf = x.reshape(T, D)

    gate_logits = jnp.einsum(
        "td,de->te", xf, p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(gate_logits, axis=-1)
    gate_w, gate_idx = jax.lax.top_k(probs, m.top_k)        # (T,k)
    gate_w = gate_w / jnp.clip(
        jnp.sum(gate_w, axis=-1, keepdims=True), 1e-9)

    k = m.top_k
    E = m.num_experts
    if dropless:
        # worst case: every token's top-k lands on one expert
        cap = T
    else:
        cap = max(int(T * k / E * m.capacity_factor), 1)
        # round capacity to MXU-aligned multiple where it matters
        if cap >= 128:
            cap = ((cap + 127) // 128) * 128

    flat_expert = gate_idx.reshape(-1)                      # (T*k,)
    flat_token = jnp.repeat(jnp.arange(T), k)               # (T*k,)
    flat_w = gate_w.reshape(-1)

    order = jnp.argsort(flat_expert)                        # stable
    sorted_expert = flat_expert[order]
    sorted_token = flat_token[order]
    sorted_w = flat_w[order]

    # position of each routed token within its expert
    ones = jnp.ones_like(sorted_expert)
    seg_pos = jax.lax.associative_scan(jnp.add, ones) - 1
    offsets = jnp.cumsum(jnp.bincount(sorted_expert, length=E)) \
        - jnp.bincount(sorted_expert, length=E)
    pos_in_expert = seg_pos - offsets[sorted_expert]
    keep = pos_in_expert < cap

    # scatter tokens into (E, C, D)
    slot = jnp.where(keep, sorted_expert * cap + pos_in_expert, E * cap)
    buf = jnp.zeros((E * cap + 1, D), xf.dtype).at[slot].set(
        xf[sorted_token])[:-1]
    buf = buf.reshape(E, cap, D)
    if rules is not None:
        buf = logical_constraint(rules, buf, ("experts", None, None))

    # per-expert fused FFN (the "RRAM-domain" fused kernel for MoE)
    h = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(cfg.compute_dtype))
    h = jax.nn.silu(h) * jnp.einsum(
        "ecd,edf->ecf", buf, p["w_gate"].astype(cfg.compute_dtype))
    out_buf = jnp.einsum(
        "ecf,efd->ecd", h, p["w_down"].astype(cfg.compute_dtype))
    out_buf = out_buf.reshape(E * cap, D)

    # combine back to tokens
    gathered = out_buf[jnp.clip(slot, 0, E * cap - 1)]
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    contrib = gathered * sorted_w[:, None].astype(gathered.dtype)
    out = jnp.zeros((T, D), x.dtype).at[sorted_token].add(contrib)
    out = out.reshape(B, S, D)
    if rules is not None and cfg.seq_sharding and S > 1:
        # the combine scatter-add otherwise materializes replicated and
        # all-reduces (tokens, D) f32 per layer
        out = logical_constraint(rules, out, ("batch", "seq_sp", None))

    if m.num_shared_experts > 0:
        out = out + apply_mlp(p["shared"], cfg, x, rules,
                              mlp_type="silu_gated")
    return out


def moe_aux_loss(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Load-balancing auxiliary loss (Switch-style)."""
    m = cfg.moe
    B, S, D = x.shape
    xf = x.reshape(-1, D)
    logits = jnp.einsum("td,de->te", xf, p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(probs, axis=-1)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top1, m.num_experts, dtype=jnp.float32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    return m.num_experts * jnp.sum(frac_tokens * frac_probs)
