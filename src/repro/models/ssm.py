"""Attention-free mixers: RWKV6 (Finch, data-dependent decay) and Mamba2
(SSD), plus single-step decode recurrences.

Both use the same *chunked hybrid* algorithm: the sequence is split into
chunks; the intra-chunk contribution is an exact scan over chunk positions
(vmapped across chunks — parallel), and the inter-chunk contribution is a
scan over chunks carrying the recurrent state. Every exponential term is of
the form exp(sum of negative log-decays) <= 1, so the algorithm is stable at
any sequence length — this is why these archs run the long_500k shape.
Sequential depth = chunk_size + num_chunks instead of seq_len.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ParamBuilder, embed_axis


# ---------------------------------------------------------------------------
# RWKV6 time-mix
# ---------------------------------------------------------------------------
def init_rwkv6(b: ParamBuilder, cfg: ModelConfig):
    D = cfg.d_model
    H, K = cfg.num_heads, cfg.head_dim
    r = cfg.ssm.rwkv_lora_rank
    rd = cfg.ssm.rwkv_decay_lora
    e = embed_axis(cfg)
    b.param("mu_x", (D,), (None,), init="zeros")
    b.param("mu", (5, D), (None, None), init="zeros")        # w,k,v,r,g bases
    b.param("lora_a", (D, 5 * r), (e, None), scale=0.01)
    b.param("lora_b", (5, r, D), (None, None, None), scale=0.01)
    b.param("decay_base", (D,), (None,), init="zeros")
    b.param("decay_a", (D, rd), (e, None), scale=0.01)
    b.param("decay_b", (rd, D), (None, None), scale=0.01)
    b.param("bonus_u", (H, K), ("heads", None), init="zeros")
    b.param("w_r", (D, H, K), (e, "heads", None))
    b.param("w_k", (D, H, K), (e, "heads", None))
    b.param("w_v", (D, H, K), (e, "heads", None))
    b.param("w_g", (D, D), (e, None))
    b.param("w_o", (H, K, D), ("heads", None, e))
    b.param("ln_x_scale", (H, K), ("heads", None), init="ones")
    b.param("ln_x_bias", (H, K), ("heads", None), init="zeros")


def _rwkv6_inputs(p: dict, cfg: ModelConfig, x: jax.Array,
                  shifted: jax.Array):
    """Token-shift mixing + projections. x, shifted: (B,S,D).
    Returns r,k,v (B,S,H,K), logw (B,S,H,K) negative log-decay, g (B,S,D)."""
    cd = cfg.compute_dtype
    H, K = cfg.num_heads, cfg.head_dim
    sx = shifted - x
    base = x + sx * p["mu_x"].astype(x.dtype)
    r_lora = jax.nn.tanh(jnp.einsum(
        "bsd,dr->bsr", base, p["lora_a"].astype(cd)))
    r_lora = r_lora.reshape(*r_lora.shape[:-1], 5, -1)
    dyn = jnp.einsum("bsir,ird->bsid", r_lora, p["lora_b"].astype(cd))
    mixed = x[:, :, None] + sx[:, :, None] * (
        p["mu"].astype(x.dtype) + dyn)                        # (B,S,5,D)
    xw, xk, xv, xr, xg = [mixed[:, :, i] for i in range(5)]

    decay_raw = (p["decay_base"].astype(jnp.float32)
                 + jnp.einsum("bsd,dr,re->bse", xw.astype(jnp.float32),
                              p["decay_a"].astype(jnp.float32),
                              p["decay_b"].astype(jnp.float32)))
    logw = -jnp.exp(decay_raw)                                # (B,S,D) < 0
    B, S, _ = x.shape
    logw = logw.reshape(B, S, H, K)
    rr = jnp.einsum("bsd,dhk->bshk", xr, p["w_r"].astype(cd))
    kk = jnp.einsum("bsd,dhk->bshk", xk, p["w_k"].astype(cd))
    vv = jnp.einsum("bsd,dhk->bshk", xv, p["w_v"].astype(cd))
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["w_g"].astype(cd)))
    return rr, kk, vv, logw, g


def _rwkv6_finish(p: dict, cfg: ModelConfig, y: jax.Array,
                  g: jax.Array) -> jax.Array:
    """Per-head groupnorm (ln_x), gate, output projection. y: (B,S,H,K)."""
    cd = cfg.compute_dtype
    yf = y.astype(jnp.float32)
    mean = jnp.mean(yf, axis=-1, keepdims=True)
    var = jnp.var(yf, axis=-1, keepdims=True)
    yn = (yf - mean) * jax.lax.rsqrt(var + 1e-5)
    yn = yn * p["ln_x_scale"].astype(jnp.float32) \
        + p["ln_x_bias"].astype(jnp.float32)
    out = jnp.einsum("bshk,hkd->bsd", yn.astype(cd), p["w_o"].astype(cd))
    return out * g


def _pad_to_grid(S: int, chunk: int, *tensors):
    """Zero-pad (B,S,...) tensors along axis 1 to the next multiple of
    ``chunk``. Zero inputs are *identity elements* of both recurrences
    (k=v=0 adds nothing to the state; logw=0 / dt=0 means decay exp(0)=1),
    so a padded tail leaves the carried state bit-identical to processing
    the exact length. This is what makes the chunk grid canonical: a
    sequence processed whole and the same sequence processed as
    chunk-aligned extend() slices run the exact same op sequence, which
    the chunked-prefill parity tests rely on."""
    S_pad = ((S + chunk - 1) // chunk) * chunk
    if S_pad == S:
        return tensors
    pad = S_pad - S
    return tuple(
        jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        for t in tensors)


def wkv6_chunked(r, k, v, logw, u, state0, chunk: int):
    """Chunked WKV6. r,k,v,logw: (B,S,H,K) [f32 math]; u: (H,K);
    state0: (B,H,K,K) [key-dim, value-dim]. Returns y (B,S,H,K), state.
    S need not divide ``chunk``: the tail is identity-padded (see
    `_pad_to_grid`), so the state after S tokens is exact."""
    B, S, H, K = r.shape
    r, k, v, logw = _pad_to_grid(S, chunk, r, k, v, logw)
    N = r.shape[1] // chunk
    f32 = jnp.float32
    rc = r.astype(f32).reshape(B, N, chunk, H, K)
    kc = k.astype(f32).reshape(B, N, chunk, H, K)
    vc = v.astype(f32).reshape(B, N, chunk, H, K)
    wc = logw.astype(f32).reshape(B, N, chunk, H, K)

    # ---- intra-chunk: exact scan over chunk positions, parallel over chunks
    def intra_step(S_loc, inp):
        rt, kt, vt, wt = inp                                  # (B,N,H,K)
        # y_t = r_t . S_loc + (r_t . (u*k_t)) v_t
        y = jnp.einsum("bnhk,bnhkv->bnhv", rt, S_loc)
        y = y + jnp.einsum("bnhk,bnhk->bnh", rt, u * kt)[..., None] * vt
        S_loc = jnp.exp(wt)[..., None] * S_loc \
            + kt[..., None] * vt[..., None, :]
        return S_loc, y

    xs = (jnp.moveaxis(rc, 2, 0), jnp.moveaxis(kc, 2, 0),
          jnp.moveaxis(vc, 2, 0), jnp.moveaxis(wc, 2, 0))
    S_loc0 = jnp.zeros((B, N, H, K, K), f32)
    S_loc_final, y_intra = jax.lax.scan(intra_step, S_loc0, xs)
    y_intra = jnp.moveaxis(y_intra, 0, 2)                     # (B,N,c,H,K)

    # ---- inter-chunk: scan over chunks carrying the state
    cum = jnp.cumsum(wc, axis=2)                              # inclusive
    cum_excl = cum - wc                                       # exclusive
    decay_all = jnp.exp(cum[:, :, -1])                        # (B,N,H,K)
    r_dec = rc * jnp.exp(cum_excl)                            # bounded <=1

    def inter_step(S_carry, inp):
        r_dec_c, S_loc_c, decay_c = inp                       # per chunk
        y_inter = jnp.einsum("bchk,bhkv->bchv", r_dec_c, S_carry)
        S_carry = decay_c[..., None] * S_carry + S_loc_c
        return S_carry, y_inter

    xs2 = (jnp.moveaxis(r_dec, 1, 0), jnp.moveaxis(S_loc_final, 1, 0),
           jnp.moveaxis(decay_all, 1, 0))
    state, y_inter = jax.lax.scan(inter_step, state0.astype(f32), xs2)
    y_inter = jnp.moveaxis(y_inter, 0, 1)                     # (B,N,c,H,K)

    y = (y_intra + y_inter).reshape(B, N * chunk, H, K)[:, :S]
    return y, state


def apply_rwkv6(p: dict, cfg: ModelConfig, x: jax.Array,
                state: dict | None) -> tuple[jax.Array, dict]:
    """Sequence (train/prefill) or single-step (decode) RWKV6 time-mix.
    state = {"s": (B,H,K,K) f32, "x_prev": (B,D)} or None (fresh)."""
    B, S, D = x.shape
    H, K = cfg.num_heads, cfg.head_dim
    if state is None:
        state = init_rwkv6_state(cfg, B)
    if S == 1:
        shifted = state["x_prev"][:, None]
    else:
        shifted = jnp.concatenate(
            [state["x_prev"][:, None], x[:, :-1]], axis=1)
    r, k, v, logw, g = _rwkv6_inputs(p, cfg, x, shifted)
    u = p["bonus_u"].astype(jnp.float32)

    if S == 1:
        # exact single-step recurrence
        rt = r[:, 0].astype(jnp.float32)
        kt = k[:, 0].astype(jnp.float32)
        vt = v[:, 0].astype(jnp.float32)
        wt = logw[:, 0]
        s = state["s"]
        y = jnp.einsum("bhk,bhkv->bhv", rt, s) \
            + jnp.einsum("bhk,bhk->bh", rt, u * kt)[..., None] * vt
        s = jnp.exp(wt)[..., None] * s + kt[..., None] * vt[..., None, :]
        y = y[:, None]                                        # (B,1,H,K)
    else:
        # canonical grid: absolute blocks of chunk_size (identity-padded
        # tail) so chunk-aligned extend() splits are bit-exact vs whole
        chunk = min(cfg.ssm.chunk_size, S)
        y, s = wkv6_chunked(r, k, v, logw, u, state["s"], chunk)
        y = y.reshape(B, S, H, K)

    out = _rwkv6_finish(p, cfg, y.astype(x.dtype), g)
    return out, {"s": s, "x_prev": x[:, -1]}


def init_rwkv6_state(cfg: ModelConfig, batch: int) -> dict:
    H, K = cfg.num_heads, cfg.head_dim
    return {"s": jnp.zeros((batch, H, K, K), jnp.float32),
            "x_prev": jnp.zeros((batch, cfg.d_model),
                                jnp.dtype(cfg.compute_dtype))}


def rwkv6_state_logical() -> dict:
    return {"s": ("batch", "heads", None, None), "x_prev": ("batch", None)}


# ---------------------------------------------------------------------------
# Mamba2 (SSD)
# ---------------------------------------------------------------------------
def _m2_dims(cfg: ModelConfig):
    d_inner = cfg.ssm.expand * cfg.d_model
    heads = d_inner // cfg.ssm.head_dim
    return d_inner, heads, cfg.ssm.state_dim


def init_mamba2(b: ParamBuilder, cfg: ModelConfig):
    D = cfg.d_model
    d_inner, H, n = _m2_dims(cfg)
    conv_dim = d_inner + 2 * n
    e = embed_axis(cfg)
    b.param("w_in", (D, d_inner + conv_dim + H), (e, "mlp"))
    b.param("conv_w", (cfg.ssm.conv_width, conv_dim), (None, None),
            scale=0.5)
    b.param("conv_b", (conv_dim,), (None,), init="zeros")
    b.param("a_log", (H,), (None,), init="zeros")
    b.param("dt_bias", (H,), (None,), init="zeros")
    b.param("d_skip", (H,), (None,), init="ones")
    b.param("norm_scale", (d_inner,), (None,), init="ones")
    b.param("w_out", (d_inner, D), ("mlp", e))


def ssd_chunked(xh, Bm, Cm, dt, a_log, state0, chunk: int):
    """Chunked SSD. xh: (B,S,H,P) head inputs; Bm,Cm: (B,S,n); dt: (B,S,H);
    state0: (B,H,P,n). Returns y (B,S,H,P), state. S need not divide
    ``chunk``: the tail is identity-padded (dt=0 -> decay 1, xh*dt=0), so
    the state after S tokens is exact (see `_pad_to_grid`)."""
    B, S, H, P = xh.shape
    n = Bm.shape[-1]
    xh, Bm, Cm, dt = _pad_to_grid(S, chunk, xh, Bm, Cm, dt)
    N = xh.shape[1] // chunk
    f32 = jnp.float32
    loga = (-jnp.exp(a_log.astype(f32)) * dt.astype(f32))     # (B,S,H) < 0
    xc = (xh.astype(f32) * dt.astype(f32)[..., None]) \
        .reshape(B, N, chunk, H, P)
    bc = Bm.astype(f32).reshape(B, N, chunk, n)
    cc = Cm.astype(f32).reshape(B, N, chunk, n)
    lc = loga.reshape(B, N, chunk, H)

    def intra_step(S_loc, inp):
        xt, bt, ct, lt = inp                                  # (B,N,...)
        S_loc = jnp.exp(lt)[..., None, None] * S_loc \
            + xt[..., None] * bt[:, :, None, None, :]
        y = jnp.einsum("bnhps,bns->bnhp", S_loc, ct)
        return S_loc, y

    xs = (jnp.moveaxis(xc, 2, 0), jnp.moveaxis(bc, 2, 0),
          jnp.moveaxis(cc, 2, 0), jnp.moveaxis(lc, 2, 0))
    S_loc0 = jnp.zeros((B, N, H, P, n), f32)
    S_loc_final, y_intra = jax.lax.scan(intra_step, S_loc0, xs)
    y_intra = jnp.moveaxis(y_intra, 0, 2)                     # (B,N,c,H,P)

    cum = jnp.cumsum(lc, axis=2)                              # inclusive
    decay_all = jnp.exp(cum[:, :, -1])                        # (B,N,H)

    def inter_step(S_carry, inp):
        cum_c, c_c, S_loc_c, decay_c = inp
        # y_inter_t = exp(cum_t) * C_t . S_carry   (state used inclusively)
        y = jnp.einsum("bchs,bhps->bchp",
                       jnp.exp(cum_c)[..., None] * c_c[:, :, None, :],
                       S_carry)
        S_carry = decay_c[..., None, None] * S_carry + S_loc_c
        return S_carry, y

    xs2 = (jnp.moveaxis(cum, 1, 0), jnp.moveaxis(cc, 1, 0),
           jnp.moveaxis(S_loc_final, 1, 0), jnp.moveaxis(decay_all, 1, 0))
    state, y_inter = jax.lax.scan(inter_step, state0.astype(f32), xs2)
    y_inter = jnp.moveaxis(y_inter, 0, 1)

    y = (y_intra + y_inter).reshape(B, N * chunk, H, P)[:, :S]
    return y, state


def apply_mamba2(p: dict, cfg: ModelConfig, x: jax.Array,
                 state: dict | None) -> tuple[jax.Array, dict]:
    """Mamba2 block. state = {"conv": (B,W-1,conv_dim), "ssm": (B,H,P,n)}."""
    B, S, D = x.shape
    d_inner, H, n = _m2_dims(cfg)
    P = cfg.ssm.head_dim
    W = cfg.ssm.conv_width
    conv_dim = d_inner + 2 * n
    cd = cfg.compute_dtype
    if state is None:
        state = init_mamba2_state(cfg, B)

    proj = jnp.einsum("bsd,de->bse", x, p["w_in"].astype(cd))
    z, xBC, dt_raw = jnp.split(proj, [d_inner, d_inner + conv_dim], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (B,S,H)

    # causal depthwise conv with carried state
    xBC_hist = jnp.concatenate([state["conv"].astype(xBC.dtype), xBC], axis=1)
    new_conv = xBC_hist[:, -(W - 1):]
    # windowed conv: out[t] = sum_s w[s] * hist[t + s]  (hist len = S + W - 1)
    conv_out = jnp.zeros_like(xBC)
    for s in range(W):
        conv_out = conv_out + xBC_hist[:, s:s + S] \
            * p["conv_w"][s].astype(xBC.dtype)
    conv_out = jax.nn.silu(conv_out + p["conv_b"].astype(xBC.dtype))

    xh, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + n], axis=-1)
    xh = xh.reshape(B, S, H, P)

    if S == 1:
        lt = (-jnp.exp(p["a_log"].astype(jnp.float32)) * dt[:, 0])  # (B,H)
        s_new = jnp.exp(lt)[..., None, None] * state["ssm"] \
            + (xh[:, 0].astype(jnp.float32) * dt[:, 0][..., None])[..., None] \
            * Bm[:, 0].astype(jnp.float32)[:, None, None, :]
        y = jnp.einsum("bhps,bs->bhp", s_new,
                       Cm[:, 0].astype(jnp.float32))[:, None]
        ssm_state = s_new
    else:
        # canonical grid (see apply_rwkv6): chunk-aligned splits bit-exact
        chunk = min(cfg.ssm.chunk_size, S)
        y, ssm_state = ssd_chunked(xh, Bm, Cm, dt, p["a_log"],
                                   state["ssm"], chunk)

    y = y + p["d_skip"].astype(jnp.float32)[:, None] \
        * xh.astype(jnp.float32) * 1.0
    y = y.reshape(B, S, d_inner).astype(cd)

    # gated RMSNorm (mamba2 style)
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(jnp.square(yf), -1, keepdims=True)
                            + 1e-6)
         * p["norm_scale"].astype(jnp.float32)).astype(cd)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(cd))
    return out, {"conv": new_conv.astype(state["conv"].dtype),
                 "ssm": ssm_state}


def init_mamba2_state(cfg: ModelConfig, batch: int) -> dict:
    d_inner, H, n = _m2_dims(cfg)
    conv_dim = d_inner + 2 * n
    return {
        "conv": jnp.zeros((batch, cfg.ssm.conv_width - 1, conv_dim),
                          jnp.dtype(cfg.compute_dtype)),
        "ssm": jnp.zeros((batch, H, cfg.ssm.head_dim, n), jnp.float32),
    }


def mamba2_state_logical() -> dict:
    return {"conv": ("batch", None, "mlp"),
            "ssm": ("batch", "mlp", None, None)}
