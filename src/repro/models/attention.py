"""Attention mixers: GQA/MQA/MHA and MLA (DeepSeek), with flat and CHIME
tiered KV caches.

The jnp implementations here are the oracles; `FUSED_QKV_PROJ` and
`FUSED_ATTN_STREAM` (paper Table I) have Pallas TPU twins in repro/kernels
selected via ``cfg.use_pallas_kernels`` through core/fusion.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ParamBuilder, apply_rope, embed_axis
from repro.sharding import logical_constraint

NEG_INF = -2.0 ** 20


def _constrain(rules, x, logical):
    return x if rules is None else logical_constraint(rules, x, logical)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------
def init_attn(b: ParamBuilder, cfg: ModelConfig):
    e = embed_axis(cfg)
    b.param("wq", (cfg.d_model, cfg.num_heads, cfg.head_dim),
            (e, "heads", None))
    b.param("wk", (cfg.d_model, cfg.num_kv_heads, cfg.head_dim),
            (e, "kv_heads", None))
    b.param("wv", (cfg.d_model, cfg.num_kv_heads, cfg.head_dim),
            (e, "kv_heads", None))
    b.param("wo", (cfg.num_heads, cfg.head_dim, cfg.d_model),
            ("heads", None, e))
    if cfg.use_attn_bias:
        b.param("bq", (cfg.num_heads, cfg.head_dim), ("heads", None),
                init="zeros")
        b.param("bk", (cfg.num_kv_heads, cfg.head_dim), ("kv_heads", None),
                init="zeros")
        b.param("bv", (cfg.num_kv_heads, cfg.head_dim), ("kv_heads", None),
                init="zeros")
        b.param("bo", (cfg.d_model,), (None,), init="zeros")


def qkv_proj(p: dict, cfg: ModelConfig, x: jax.Array, positions: jax.Array,
             rules) -> tuple[jax.Array, jax.Array, jax.Array]:
    """FUSED_QKV_PROJ: GEMM(X·Wq)+bq ; GEMM(X·Wk)+bk ; GEMM(X·Wv)+bv.
    One pass over X; RoPE applied before caching (keys cached post-RoPE)."""
    cd = cfg.compute_dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(cd))
    if "bq" in p:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    if cfg.pos_emb == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = _constrain(rules, q, ("batch", None, "heads", None))
    k = _constrain(rules, k, ("batch", None, "kv_heads", None))
    v = _constrain(rules, v, ("batch", None, "kv_heads", None))
    return q, k, v


def gqa_scores_softmax_pv(q: jax.Array, k: jax.Array, v: jax.Array,
                          mask: jax.Array | None,
                          scale: float | None = None,
                          rules=None,
                          scores_dtype=jnp.float32,
                          kv_logical=("batch", None, "heads", None)
                          ) -> jax.Array:
    """Grouped attention. q: (B,S,H,D); k,v: (B,L,Hkv,D); mask broadcastable
    to (B,1,S,L) / (1,1,1,L) or None. Returns (B,S,H,D). This is the jnp
    oracle for FUSED_ATTN_STREAM (the Pallas kernel streams K/V tiles with
    online softmax instead of materializing the (S,L) score matrix).

    K/V are broadcast to the full head count before the score einsum so the
    (B,H,S,L) scores shard cleanly over 'model' on the H dim — the grouped
    (Hkv, G) reshape formulation makes SPMD fall into involuntary full
    rematerialization when Hkv < model-axis size (observed on
    nemotron-340b: replicated 6.4 GB score buffers)."""
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else D ** -0.5
    sdt = jnp.dtype(scores_dtype)
    kf = k.astype(sdt)
    vf = v.astype(sdt)
    if G > 1:
        kf = jnp.broadcast_to(kf[:, :, :, None],
                              (B, kf.shape[1], Hkv, G, D)) \
            .reshape(B, kf.shape[1], H, D)
        vf = jnp.broadcast_to(vf[:, :, :, None],
                              (B, vf.shape[1], Hkv, G, D)) \
            .reshape(B, vf.shape[1], H, D)
    if rules is not None:
        from repro.sharding import logical_constraint
        kf = logical_constraint(rules, kf, kv_logical)
        vf = logical_constraint(rules, vf, kv_logical)
    scores = jnp.einsum("bshd,blhd->bhsl", q.astype(sdt), kf) * scale
    if mask is not None:
        scores = jnp.where(mask, scores,
                           jnp.asarray(NEG_INF, scores.dtype))
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhsl,blhd->bshd", probs, vf)
    return out.astype(q.dtype)


def attn_out(p: dict, cfg: ModelConfig, o: jax.Array, rules) -> jax.Array:
    out = jnp.einsum("bshk,hkd->bsd", o,
                     p["wo"].astype(cfg.compute_dtype))
    if "bo" in p:
        out = out + p["bo"].astype(out.dtype)
    return out


def causal_mask(S: int, L: int, offset: int = 0) -> jax.Array:
    """(1,1,S,L) causal mask; offset = number of cached tokens before the
    current block (query i attends key j iff j <= i + offset)."""
    qi = jnp.arange(S)[:, None]
    kj = jnp.arange(L)[None, :]
    return (kj <= qi + offset)[None, None]


# ---- flat KV cache --------------------------------------------------------
def init_flat_cache(cfg: ModelConfig, batch: int, max_len: int,
                    kv_heads: int | None = None,
                    head_dim: int | None = None) -> dict:
    kvh = kv_heads or cfg.num_kv_heads
    hd = head_dim or cfg.head_dim
    dt = jnp.dtype(cfg.compute_dtype)
    return {
        "k": jnp.zeros((batch, max_len, kvh, hd), dt),
        "v": jnp.zeros((batch, max_len, kvh, hd), dt),
    }


def flat_cache_logical() -> dict:
    ax = ("batch", "kv_seq_shard", "kv_heads", None)
    return {"k": ax, "v": ax}


def flat_cache_update(cache: dict, k_new: jax.Array, v_new: jax.Array,
                      pos: jax.Array) -> dict:
    """Insert (B,1,Hkv,D) at position pos (scalar int32)."""
    k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, pos, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, pos, 0, 0))
    return {"k": k, "v": v}


def attend_flat(cfg: ModelConfig, q: jax.Array, cache: dict,
                pos: jax.Array) -> jax.Array:
    """Decode attention over a flat cache: q (B,1,H,D), keys valid < pos+1."""
    L = cache["k"].shape[1]
    valid = (jnp.arange(L) <= pos)[None, None, None, :]
    return gqa_scores_softmax_pv(q, cache["k"], cache["v"], valid)


# ---------------------------------------------------------------------------
# two-part (tiered) attention: flash-style partial softmax merge
# ---------------------------------------------------------------------------
def _bcast_kv_heads(t: jax.Array, H: int) -> jax.Array:
    """(B,L,Hkv,D) -> (B,L,H,D) by group broadcast (free under fusion)."""
    B, L, Hkv, D = t.shape
    G = H // Hkv
    if G == 1:
        return t
    return jnp.broadcast_to(t[:, :, :, None], (B, L, Hkv, G, D)) \
        .reshape(B, L, H, D)


def partial_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      valid: jax.Array, scale: float,
                      k_scale: jax.Array | None = None,
                      v_scale: jax.Array | None = None,
                      sdt=jnp.float32):
    """One attendable segment -> flash partials (m, denom, acc), f32.

    q: (B,S,H,D); k,v: (B,L,Hkv,D) — may be int8 with per-(token,head)
    scales k_scale/v_scale (B,L,Hkv,1): the scales factor OUT of the dots
    (scores = (q·k_q) * k_scale; pv = (p*v_scale)·v_q), so the int8 arrays
    are the HBM operands and no dequantized copy is materialized — this is
    what makes the cold tier's bandwidth saving real in the HLO.
    """
    B, S, H, D = q.shape
    kf = _bcast_kv_heads(k.astype(sdt), H)
    scores = jnp.einsum("bshd,blhd->bhsl", q.astype(sdt), kf) * scale
    if k_scale is not None:
        ks = _bcast_kv_heads(k_scale, H)[..., 0]          # (B,L,H)
        ks = jnp.swapaxes(ks, 1, 2)[:, :, None, :]        # (B,H,1,L)
        scores = scores * ks.astype(scores.dtype)
    scores = jnp.where(valid[None, None, None, :], scores.astype(
        jnp.float32), NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)           # (B,H,S,1)
    p = jnp.exp(scores - m)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    pv = p.astype(sdt)
    if v_scale is not None:
        vs = _bcast_kv_heads(v_scale, H)[..., 0]          # (B,L,H)
        vs = jnp.swapaxes(vs, 1, 2)[:, :, None, :]        # (B,H,1,L)
        pv = pv * vs.astype(pv.dtype)
    vf = _bcast_kv_heads(v.astype(sdt), H)
    acc = jnp.einsum("bhsl,blhd->bhsd", pv, vf).astype(jnp.float32)
    return m, denom, acc


def merge_partials(parts: list[tuple[jax.Array, jax.Array, jax.Array]],
                   out_dtype) -> jax.Array:
    """Merge flash partials across segments -> (B,S,H,D)."""
    m_star = parts[0][0]
    for m, _, _ in parts[1:]:
        m_star = jnp.maximum(m_star, m)
    denom = 0.0
    acc = 0.0
    for m, d, a in parts:
        w = jnp.exp(m - m_star)                            # (B,H,S,1)
        denom = denom + d * w
        acc = acc + a * w
    out = acc / jnp.maximum(denom, 1e-30)
    return jnp.swapaxes(out, 1, 2).astype(out_dtype)      # (B,S,H,D)


def attend_tiered(cfg, q: jax.Array, k_store: dict, v_store: dict,
                  pos) -> jax.Array:
    """Decode attention over a CHIME-tiered KV store without concat or
    dequant materialization: cold (int8, seq-sharded) and hot (bf16,
    replicated ring) segments each produce flash partials, merged by
    softmax stitching — no resharding collective between tiers."""
    from repro.core import kv_tiers as KT
    scale = q.shape[-1] ** -0.5
    sdt = jnp.dtype(cfg.attn_scores_dtype)
    W = KT.hot_window_of(k_store)
    max_len = k_store["cold_q"].shape[1]
    cold_valid = jnp.arange(max_len) <= (pos - W)
    hot_pos = KT.hot_ring_positions(pos, W)
    hot_valid = (hot_pos >= 0) & (hot_pos <= pos)
    p_cold = partial_attention(
        q, k_store["cold_q"], v_store["cold_q"], cold_valid, scale,
        k_scale=k_store["cold_scale"], v_scale=v_store["cold_scale"],
        sdt=sdt)
    p_hot = partial_attention(
        q, k_store["hot"], v_store["hot"], hot_valid, scale, sdt=sdt)
    return merge_partials([p_cold, p_hot], q.dtype)


def mla_attend_tiered(p: dict, cfg, q_nope: jax.Array, q_rope: jax.Array,
                      c_store: dict, r_store: dict, pos) -> jax.Array:
    """Tiered MLA decode in absorbed (latent-space) form: the cold latent
    tier stays int8 (scales factor out of both score dots and the PV dot);
    cold/hot segments merge by softmax stitching."""
    from repro.core import kv_tiers as KT
    m = cfg.mla
    cd = jnp.dtype(cfg.compute_dtype)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope,
                       p["wk_b"].astype(cd))               # (B,S,H,R)
    W = KT.hot_window_of(c_store)
    L = c_store["cold_q"].shape[1]
    cold_valid = jnp.arange(L) <= (pos - W)
    hot_pos = KT.hot_ring_positions(pos, W)
    hot_valid = (hot_pos >= 0) & (hot_pos <= pos)

    def seg(c, c_scale, r, r_scale, valid):
        nope = jnp.einsum("bshr,blr->bhsl", q_lat.astype(jnp.float32),
                          c.astype(jnp.float32))
        rope = jnp.einsum("bshr,blr->bhsl", q_rope.astype(jnp.float32),
                          r.astype(jnp.float32))
        if c_scale is not None:
            nope = nope * c_scale[..., 0][:, None, None, :]
            rope = rope * r_scale[..., 0][:, None, None, :]
        scores = (nope + rope) * scale
        scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
        mx = jnp.max(scores, -1, keepdims=True)
        pr = jnp.exp(scores - mx)
        den = jnp.sum(pr, -1, keepdims=True)
        if c_scale is not None:
            pr = pr * c_scale[..., 0][:, None, None, :]
        acc = jnp.einsum("bhsl,blr->bhsr", pr,
                         c.astype(jnp.float32))
        return mx, den, acc

    parts = [
        seg(c_store["cold_q"], c_store["cold_scale"],
            r_store["cold_q"], r_store["cold_scale"], cold_valid),
        seg(c_store["hot"], None, r_store["hot"], None, hot_valid),
    ]
    o_lat = merge_partials(parts, cd)                      # (B,S,H,R)
    o = jnp.einsum("bshr,rhv->bshv", o_lat, p["wv_b"].astype(cd))
    return jnp.einsum("bshv,hvd->bsd", o, p["wo"].astype(cd))


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------
def init_mla(b: ParamBuilder, cfg: ModelConfig):
    m = cfg.mla
    e = embed_axis(cfg)
    H = cfg.num_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    if m.q_lora_rank:
        b.param("wq_a", (cfg.d_model, m.q_lora_rank), (e, None))
        b.param("q_norm_scale", (m.q_lora_rank,), (None,), init="ones")
        b.param("wq_b", (m.q_lora_rank, H, qk_dim), (None, "heads", None))
    else:
        b.param("wq", (cfg.d_model, H, qk_dim), (e, "heads", None))
    b.param("wkv_a", (cfg.d_model, m.kv_lora_rank), (e, None))
    b.param("kv_norm_scale", (m.kv_lora_rank,), (None,), init="ones")
    b.param("wk_rope", (cfg.d_model, m.qk_rope_head_dim), (e, None))
    b.param("wk_b", (m.kv_lora_rank, H, m.qk_nope_head_dim),
            (None, "heads", None))
    b.param("wv_b", (m.kv_lora_rank, H, m.v_head_dim),
            (None, "heads", None))
    b.param("wo", (H, m.v_head_dim, cfg.d_model), ("heads", None, e))


def _rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    return (xf * jax.lax.rsqrt(
        jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
        * scale.astype(jnp.float32)).astype(x.dtype)


def mla_latents(p: dict, cfg: ModelConfig, x: jax.Array,
                positions: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Compute the compressed KV latent and the shared RoPE key — these are
    what the (tierable) MLA cache stores."""
    m = cfg.mla
    cd = cfg.compute_dtype
    c_kv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"].astype(cd))
    c_kv = _rms(c_kv, p["kv_norm_scale"])
    k_rope = jnp.einsum("bsd,dr->bsr", x, p["wk_rope"].astype(cd))
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def mla_queries(p: dict, cfg: ModelConfig, x: jax.Array,
                positions: jax.Array) -> tuple[jax.Array, jax.Array]:
    m = cfg.mla
    cd = cfg.compute_dtype
    if m.q_lora_rank:
        cq = _rms(jnp.einsum("bsd,dr->bsr", x, p["wq_a"].astype(cd)),
                  p["q_norm_scale"])
        q = jnp.einsum("bsr,rhk->bshk", cq, p["wq_b"].astype(cd))
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
    q_nope = q[..., :m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], positions,
                        cfg.rope_theta)
    return q_nope, q_rope


def mla_attention(p: dict, cfg: ModelConfig, q_nope: jax.Array,
                  q_rope: jax.Array, c_kv: jax.Array, k_rope: jax.Array,
                  mask: jax.Array | None, absorbed: bool) -> jax.Array:
    """MLA attention from latents. Two execution strategies:

    * expanded (paper-faithful baseline): materialize per-head K_nope and V
      from the latent, run standard MHA;
    * absorbed (beyond-paper optimization, §Perf): fold W_uk into the query
      and W_uv into the output so scores/PV run directly in the
      kv_lora_rank latent space — never materializes (B,L,H,128) keys.
    """
    m = cfg.mla
    cd = cfg.compute_dtype
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    rope_scores = jnp.einsum("bshr,blr->bhsl",
                             q_rope.astype(jnp.float32),
                             k_rope.astype(jnp.float32))
    if absorbed:
        # q_latent = q_nope @ W_uk  -> (B,S,H,R)
        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["wk_b"].astype(cd))
        nope_scores = jnp.einsum("bshr,blr->bhsl",
                                 q_lat.astype(jnp.float32),
                                 c_kv.astype(jnp.float32))
        scores = (nope_scores + rope_scores) * scale
        if mask is not None:
            scores = jnp.where(mask[:, :, 0] if mask.ndim == 5 else mask,
                               scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        o_lat = jnp.einsum("bhsl,blr->bshr", probs,
                           c_kv.astype(jnp.float32)).astype(cd)
        o = jnp.einsum("bshr,rhv->bshv", o_lat, p["wv_b"].astype(cd))
    else:
        k_nope = jnp.einsum("blr,rhk->blhk", c_kv, p["wk_b"].astype(cd))
        v = jnp.einsum("blr,rhv->blhv", c_kv, p["wv_b"].astype(cd))
        nope_scores = jnp.einsum("bshk,blhk->bhsl",
                                 q_nope.astype(jnp.float32),
                                 k_nope.astype(jnp.float32))
        scores = (nope_scores + rope_scores) * scale
        if mask is not None:
            scores = jnp.where(mask[:, :, 0] if mask.ndim == 5 else mask,
                               scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("bhsl,blhv->bshv", probs,
                       v.astype(jnp.float32)).astype(cd)
    return jnp.einsum("bshv,hvd->bsd", o, p["wo"].astype(cd))


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    m = cfg.mla
    dt = jnp.dtype(cfg.compute_dtype)
    return {
        "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dt),
        "k_rope": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dt),
    }


def mla_cache_logical() -> dict:
    return {"c_kv": ("batch", "kv_seq_shard", None),
            "k_rope": ("batch", "kv_seq_shard", None)}


def mla_cache_update(cache: dict, c_kv_new: jax.Array, k_rope_new: jax.Array,
                     pos: jax.Array) -> dict:
    return {
        "c_kv": jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv_new, (0, pos, 0)),
        "k_rope": jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope_new, (0, pos, 0)),
    }
