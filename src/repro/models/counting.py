"""Analytic parameter counts per config — feeds MODEL_FLOPS = 6·N·D in the
roofline (§Roofline) and the CHIME simulator's per-kernel byte counts."""

from __future__ import annotations

from repro.configs.base import ModelConfig


def _block_specs(cfg: ModelConfig):
    """Yield (mixer, mlp_kind, d_ff) per layer, resolving MoE first-dense."""
    idx = 0
    for seg in cfg.segments:
        for _ in range(seg.repeats):
            for mixer in seg.pattern:
                if mixer in ("mamba2",) and cfg.family == "hybrid":
                    mlp = None
                elif mixer == "rwkv6":
                    mlp = "rwkv_cm"
                elif cfg.mlp_type == "moe":
                    if cfg.moe and idx < cfg.moe.first_dense_layers:
                        mlp = "dense_first"
                    else:
                        mlp = "moe"
                else:
                    mlp = cfg.mlp_type
                yield mixer, mlp, cfg.d_ff
                idx += 1


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    D = cfg.d_model
    n = 0
    n += cfg.vocab_size * D                      # embed
    if not cfg.tie_embeddings and not cfg.is_encoder:
        n += cfg.vocab_size * D                  # lm_head
    if cfg.is_encoder:
        n += cfg.vocab_size * D                  # classifier head
    if cfg.frontend is not None:
        f = cfg.frontend
        n += f.frontend_dim * D + (D * D if f.connector == "mlp" else 0)

    seen_shared_attn = False
    for mixer, mlp, d_ff in _block_specs(cfg):
        # mixer
        if mixer in ("attn", "attn_shared"):
            a = D * (cfg.num_heads + 2 * cfg.num_kv_heads) * cfg.head_dim \
                + cfg.num_heads * cfg.head_dim * D
            if mixer == "attn_shared":
                if not seen_shared_attn:
                    n += a
                    seen_shared_attn = True
            else:
                n += a
        elif mixer == "mla":
            m = cfg.mla
            qk = m.qk_nope_head_dim + m.qk_rope_head_dim
            n += D * cfg.num_heads * qk          # wq (full rank)
            n += D * m.kv_lora_rank + D * m.qk_rope_head_dim
            n += m.kv_lora_rank * cfg.num_heads * (
                m.qk_nope_head_dim + m.v_head_dim)
            n += cfg.num_heads * m.v_head_dim * D
        elif mixer == "rwkv6":
            H, K = cfg.num_heads, cfg.head_dim
            r, rd = cfg.ssm.rwkv_lora_rank, cfg.ssm.rwkv_decay_lora
            n += 3 * D * H * K + D * D + H * K * D
            n += D * 5 * r + 5 * r * D + D * rd + rd * D
        elif mixer == "mamba2":
            d_inner = cfg.ssm.expand * D
            conv_dim = d_inner + 2 * cfg.ssm.state_dim
            H = d_inner // cfg.ssm.head_dim
            n += D * (d_inner + conv_dim + H) + d_inner * D

        # mlp
        if mlp is None or mlp == "rwkv_cm":
            if mlp == "rwkv_cm":
                n += D * d_ff + d_ff * D + D * D
        elif mlp == "moe":
            m = cfg.moe
            e_count = (m.top_k if active_only else m.num_experts)
            n += D * m.num_experts               # router
            n += e_count * 3 * D * m.d_ff_expert
            if m.num_shared_experts:
                n += 3 * D * m.d_ff_shared
        elif mlp == "dense_first":
            n += 3 * D * cfg.moe.d_ff_dense
        else:
            mats = 3 if mlp in ("silu_gated", "gelu_gated") else 2
            n += mats * D * d_ff
    return n


def kv_elems_per_token(cfg: ModelConfig) -> int:
    """Cache elements appended per generated token (all layers): GQA K+V
    rows and MLA latents. This is the single source of truth for KV byte
    math — the simulator's `kv_bytes_per_token` cost terms and the serving
    pool's `slot_kv_bytes` capacity admission both derive from it, so the
    two can never drift."""
    total = 0
    for mixer, _, _ in _block_specs(cfg):
        if mixer in ("attn", "attn_shared"):
            total += 2 * cfg.num_kv_heads * cfg.head_dim
        elif mixer == "mla":
            total += cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
    return total


def kv_scale_elems_per_token(cfg: ModelConfig) -> int:
    """float32 quant-scale elements per token in the tiered cold store:
    one per (token, kv-head) for each of K and V, one per MLA latent
    store (scales are per-token over the trailing feature dim)."""
    total = 0
    for mixer, _, _ in _block_specs(cfg):
        if mixer in ("attn", "attn_shared"):
            total += 2 * cfg.num_kv_heads
        elif mixer == "mla":
            total += 2
    return total


def kv_bytes_per_token(cfg: ModelConfig, dtype_bytes: int = 2) -> int:
    """KV-cache bytes appended per generated token (all layers)."""
    return kv_elems_per_token(cfg) * dtype_bytes
