"""Analytic parameter counts per config — feeds MODEL_FLOPS = 6·N·D in the
roofline (§Roofline) and the CHIME simulator's per-kernel byte counts."""

from __future__ import annotations

import numpy as np

from repro.configs.base import ModelConfig


def _block_specs(cfg: ModelConfig):
    """Yield (mixer, mlp_kind, d_ff) per layer, resolving MoE first-dense."""
    idx = 0
    for seg in cfg.segments:
        for _ in range(seg.repeats):
            for mixer in seg.pattern:
                if mixer in ("mamba2",) and cfg.family == "hybrid":
                    mlp = None
                elif mixer == "rwkv6":
                    mlp = "rwkv_cm"
                elif cfg.mlp_type == "moe":
                    if cfg.moe and idx < cfg.moe.first_dense_layers:
                        mlp = "dense_first"
                    else:
                        mlp = "moe"
                else:
                    mlp = cfg.mlp_type
                yield mixer, mlp, cfg.d_ff
                idx += 1


def mixer_weight_elems(cfg: ModelConfig, mixer: str) -> int:
    """Weight elements of ONE layer's mixer half-block."""
    D = cfg.d_model
    if mixer in ("attn", "attn_shared"):
        return D * (cfg.num_heads + 2 * cfg.num_kv_heads) * cfg.head_dim \
            + cfg.num_heads * cfg.head_dim * D
    if mixer == "mla":
        m = cfg.mla
        qk = m.qk_nope_head_dim + m.qk_rope_head_dim
        return (D * cfg.num_heads * qk            # wq (full rank)
                + D * m.kv_lora_rank + D * m.qk_rope_head_dim
                + m.kv_lora_rank * cfg.num_heads * (
                    m.qk_nope_head_dim + m.v_head_dim)
                + cfg.num_heads * m.v_head_dim * D)
    if mixer == "rwkv6":
        H, K = cfg.num_heads, cfg.head_dim
        r, rd = cfg.ssm.rwkv_lora_rank, cfg.ssm.rwkv_decay_lora
        return (3 * D * H * K + D * D + H * K * D
                + D * 5 * r + 5 * r * D + D * rd + rd * D)
    if mixer == "mamba2":
        d_inner = cfg.ssm.expand * D
        conv_dim = d_inner + 2 * cfg.ssm.state_dim
        H = d_inner // cfg.ssm.head_dim
        return D * (d_inner + conv_dim + H) + d_inner * D
    raise ValueError(mixer)


def mlp_weight_elems(cfg: ModelConfig, mlp: str | None, d_ff: int,
                     active_only: bool = False) -> int:
    """Weight elements of ONE layer's mlp half-block (0 for mixer-only)."""
    D = cfg.d_model
    if mlp is None:
        return 0
    if mlp == "rwkv_cm":
        return D * d_ff + d_ff * D + D * D
    if mlp == "moe":
        m = cfg.moe
        e_count = (m.top_k if active_only else m.num_experts)
        n = D * m.num_experts                    # router
        n += e_count * 3 * D * m.d_ff_expert
        if m.num_shared_experts:
            n += 3 * D * m.d_ff_shared
        return n
    if mlp == "dense_first":
        return 3 * D * cfg.moe.d_ff_dense
    mats = 3 if mlp in ("silu_gated", "gelu_gated") else 2
    return mats * D * d_ff


def layer_weight_elems(cfg: ModelConfig, mixer: str, mlp: str | None,
                       d_ff: int, active_only: bool = False) -> int:
    """Weight elements of ONE full layer block (mixer + mlp)."""
    return mixer_weight_elems(cfg, mixer) \
        + mlp_weight_elems(cfg, mlp, d_ff, active_only)


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    D = cfg.d_model
    n = 0
    n += cfg.vocab_size * D                      # embed
    if not cfg.tie_embeddings and not cfg.is_encoder:
        n += cfg.vocab_size * D                  # lm_head
    if cfg.is_encoder:
        n += cfg.vocab_size * D                  # classifier head
    if cfg.frontend is not None:
        f = cfg.frontend
        n += f.frontend_dim * D + (D * D if f.connector == "mlp" else 0)

    seen_shared_attn = False
    for mixer, mlp, d_ff in _block_specs(cfg):
        a = mixer_weight_elems(cfg, mixer)
        if mixer == "attn_shared":
            # one weight set reused by every application (Zamba2 shape)
            if not seen_shared_attn:
                n += a
                seen_shared_attn = True
        else:
            n += a
        n += mlp_weight_elems(cfg, mlp, d_ff, active_only)
    return n


# ---------------------------------------------------------------------------
# RRAM weight streaming: the param-set split between tiers
# ---------------------------------------------------------------------------
def param_dtype_bytes(cfg: ModelConfig) -> int:
    """Bytes per weight element in the stored param dtype (2 for the
    bfloat16 default, which bare numpy does not know)."""
    try:
        return np.dtype(cfg.param_dtype).itemsize
    except TypeError:
        return 2


def weight_units(cfg: ModelConfig) -> list[tuple[str, str | None, int, int]]:
    """Scan units as (mixer, mlp, d_ff, repeats): consecutive identical
    layers compressed exactly as `models.model.build_plan` compresses
    BlockSpecs, so unit indices here and in `Model.plan` agree."""
    units: list[list] = []
    for spec in _block_specs(cfg):
        if units and units[-1][0] == spec:
            units[-1][1] += 1
        else:
            units.append([spec, 1])
    return [(m, mlp, dff, r) for (m, mlp, dff), r in units]


def streamed_unit_indices(cfg: ModelConfig) -> tuple[int, ...]:
    """Unit indices whose per-layer weight slices live in the simulated
    RRAM tier under ``cfg.weight_stream_layers`` (W): scanned units with
    their own per-layer params (shared attention excluded) and more
    repeats than the W-repeat DRAM sliding window. Mirrors
    `Model.streamed_units` — the single plan-free source the scheduler
    and simulator price from."""
    W = int(getattr(cfg, "weight_stream_layers", 0) or 0)
    if W < 1 or not cfg.scan_layers:
        return ()
    return tuple(i for i, (m, _, _, r) in enumerate(weight_units(cfg))
                 if r > W and m != "attn_shared")


def stream_window_repeats(cfg: ModelConfig, repeats: int) -> int:
    """DRAM sliding-window depth (in repeats) a streamed unit keeps
    resident: at least 2 (the double-buffer floor — the current slice in
    the scan carry plus the prefetched next one), at most the unit's own
    repeat count."""
    W = int(getattr(cfg, "weight_stream_layers", 0) or 0)
    return min(max(W, 2), repeats)


def weight_stream_split(cfg: ModelConfig) -> tuple[int, int]:
    """(dram_resident_bytes, rram_streamed_bytes) of the full param set
    under ``cfg.weight_stream_layers``.

    Streamed units keep `stream_window_repeats` layer slices in DRAM
    (transit storage for the layer-ahead prefetch) while their FULL
    per-layer weight slices are RRAM-resident (the tier is the home of
    the data; the window only stages it). Everything else — embeddings,
    head, frontend, shared attention, units at or under the window — is
    DRAM-resident. W = 0 puts every param byte in DRAM and zero in RRAM.
    """
    ib = param_dtype_bytes(cfg)
    dram = count_params(cfg) * ib
    rram = 0
    streamed = set(streamed_unit_indices(cfg))
    for i, (mixer, mlp, d_ff, r) in enumerate(weight_units(cfg)):
        if i not in streamed:
            continue
        lb = layer_weight_elems(cfg, mixer, mlp, d_ff) * ib
        win = stream_window_repeats(cfg, r)
        dram -= (r - win) * lb
        rram += r * lb
    return dram, rram


def kv_elems_per_token(cfg: ModelConfig) -> int:
    """Cache elements appended per generated token (all layers): GQA K+V
    rows and MLA latents. This is the single source of truth for KV byte
    math — the simulator's `kv_bytes_per_token` cost terms and the serving
    pool's `slot_kv_bytes` capacity admission both derive from it, so the
    two can never drift."""
    total = 0
    for mixer, _, _ in _block_specs(cfg):
        if mixer in ("attn", "attn_shared"):
            total += 2 * cfg.num_kv_heads * cfg.head_dim
        elif mixer == "mla":
            total += cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
    return total


def kv_scale_elems_per_token(cfg: ModelConfig) -> int:
    """float32 quant-scale elements per token in the tiered cold store:
    one per (token, kv-head) for each of K and V, one per MLA latent
    store (scales are per-token over the trailing feature dim)."""
    total = 0
    for mixer, _, _ in _block_specs(cfg):
        if mixer in ("attn", "attn_shared"):
            total += 2 * cfg.num_kv_heads
        elif mixer == "mla":
            total += 2
    return total


def kv_bytes_per_token(cfg: ModelConfig, dtype_bytes: int = 2) -> int:
    """KV-cache bytes appended per generated token (all layers)."""
    return kv_elems_per_token(cfg) * dtype_bytes
