"""FUSED_NORM (paper Table I): Reduce -> Normalize -> Scale -> Shift on the
SFPE, i.e. the VPU on TPU. Row-block tiling; full feature dim per tile so
the reduction is kernel-local."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _norm_kernel(x_ref, s_ref, b_ref, o_ref, *, kind: str, eps: float,
                 use_bias: bool):
    x = x_ref[...].astype(jnp.float32)                    # (bm, D)
    s = s_ref[...].astype(jnp.float32)                    # (1, D)
    if kind == "rms":
        out = x * jax.lax.rsqrt(
            jnp.mean(jnp.square(x), axis=1, keepdims=True) + eps) * s
    else:
        mean = jnp.mean(x, axis=1, keepdims=True)
        var = jnp.mean(jnp.square(x - mean), axis=1, keepdims=True)
        out = (x - mean) * jax.lax.rsqrt(var + eps) * s
    if use_bias:
        out = out + b_ref[...].astype(jnp.float32)
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("kind", "eps", "block_m", "interpret"))
def fused_norm(x: jax.Array, scale: jax.Array, bias: jax.Array | None,
               kind: str = "rms", eps: float = 1e-6, *,
               block_m: int = 256, interpret: bool | None = None
               ) -> jax.Array:
    """x: (M, D) -> (M, D)."""
    M, D = x.shape
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    block_m = min(block_m, M)
    assert M % block_m == 0, (M, block_m)
    use_bias = bias is not None
    bb = (bias if use_bias else jnp.zeros((D,), x.dtype)).reshape(1, D)

    kernel = functools.partial(_norm_kernel, kind=kind, eps=eps,
                               use_bias=use_bias)
    return pl.pallas_call(
        kernel,
        grid=(M // block_m,),
        in_specs=[
            pl.BlockSpec((block_m, D), lambda mi: (mi, 0)),
            pl.BlockSpec((1, D), lambda mi: (0, 0)),
            pl.BlockSpec((1, D), lambda mi: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, D), lambda mi: (mi, 0)),
        out_shape=jax.ShapeDtypeStruct((M, D), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, scale.reshape(1, D), bb)
