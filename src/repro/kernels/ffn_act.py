"""FUSED_FFN_ACT (paper Table I): GEMM(X·W1) -> act -> GEMM(·W2) chained in
one kernel — the RRAM-NMP's fused FFN, retargeted to MXU.

CHIME's RRAM chiplet keeps FFN weights resident and chains the two GEMMs so
the (tokens, d_ff) intermediate never leaves the logic die. TPU port: the X
row-block and the output accumulator are VMEM-resident; W1/W2 column/row
tiles stream HBM->VMEM; the hidden activation exists only as a
(block_m, block_f) VMEM tile. Supports gated variants (W_gate streamed
alongside W1) and squared-ReLU (nemotron).

Int8 "RRAM-stored" weights are dequantized in VMEM before the MXU dot — the
HBM traffic is the int8 bytes (see core/quant.py for the domain argument).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _act(h, kind: str):
    if kind == "silu_gated":
        return jax.nn.silu(h)
    if kind in ("gelu", "gelu_gated"):
        return jax.nn.gelu(h)
    if kind == "relu2":
        return jnp.square(jax.nn.relu(h))
    raise ValueError(kind)


def _ffn_kernel(x_ref, w1_ref, wg_ref, w2_ref, o_ref, acc_ref, *,
                kind: str, num_f: int, gated: bool):
    """Grid: (num_m, num_f). f is the streaming axis: each step computes a
    (block_m, block_f) hidden tile and accumulates its contribution to the
    (block_m, D) output in VMEM scratch."""
    fi = pl.program_id(1)

    @pl.when(fi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)                  # (bm, D)
    w1 = w1_ref[...].astype(jnp.float32)                # (D, bf)
    h = _act(jax.lax.dot_general(
        x, w1, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32), kind)
    if gated:
        wg = wg_ref[...].astype(jnp.float32)
        h = h * jax.lax.dot_general(
            x, wg, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    w2 = w2_ref[...].astype(jnp.float32)                # (bf, D)
    acc_ref[...] += jax.lax.dot_general(
        h, w2, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(fi == num_f - 1)
    def _finalize():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("kind", "block_m", "block_f", "interpret"))
def ffn_act(x: jax.Array, w_up: jax.Array, w_gate: jax.Array | None,
            w_down: jax.Array, kind: str = "silu_gated", *,
            block_m: int = 128, block_f: int = 512,
            interpret: bool | None = None) -> jax.Array:
    """x: (M, D); w_up/w_gate: (D, F); w_down: (F, D) -> (M, D)."""
    M, D = x.shape
    F = w_up.shape[1]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    block_m = min(block_m, M)
    block_f = min(block_f, F)
    assert M % block_m == 0 and F % block_f == 0, (M, F, block_m, block_f)
    num_m, num_f = M // block_m, F // block_f
    gated = w_gate is not None
    wg = w_gate if gated else w_up  # dummy ref when ungated (never read)

    kernel = functools.partial(_ffn_kernel, kind=kind, num_f=num_f,
                               gated=gated)
    return pl.pallas_call(
        kernel,
        grid=(num_m, num_f),
        in_specs=[
            pl.BlockSpec((block_m, D), lambda mi, fi: (mi, 0)),
            pl.BlockSpec((D, block_f), lambda mi, fi: (0, fi)),
            pl.BlockSpec((D, block_f), lambda mi, fi: (0, fi)),
            pl.BlockSpec((block_f, D), lambda mi, fi: (fi, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, D), lambda mi, fi: (mi, 0)),
        out_shape=jax.ShapeDtypeStruct((M, D), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, D), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, w_up, wg, w_down)


def ffn_vmem_bytes(block_m: int, block_f: int, D: int,
                   dtype_bytes: int = 2, gated: bool = True) -> int:
    tiles = (block_m * D + (2 if gated else 1) * D * block_f
             + block_f * D) * dtype_bytes
    scratch = block_m * D * 4
    out = block_m * D * dtype_bytes
    return tiles + scratch + out


# ---------------------------------------------------------------------------
# int8 "RRAM-stored" weights: dequant in VMEM before the MXU dot — the
# HBM->VMEM stream is the int8 array (half the bf16 bytes), which is the
# paper's RRAM density/read-energy argument made concrete.
# ---------------------------------------------------------------------------
def _ffn_q_kernel(x_ref, w1q_ref, w1s_ref, w2q_ref, w2s_ref, o_ref,
                  acc_ref, *, kind: str, num_f: int):
    fi = pl.program_id(1)

    @pl.when(fi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    w1 = w1q_ref[...].astype(jnp.float32) \
        * w1s_ref[...].astype(jnp.float32)          # (D,bf) x (1,bf)
    h = _act(jax.lax.dot_general(
        x, w1, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32), kind)
    w2 = w2q_ref[...].astype(jnp.float32) \
        * w2s_ref[...].astype(jnp.float32)          # (bf,D) x (1,D)
    acc_ref[...] += jax.lax.dot_general(
        h, w2, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(fi == num_f - 1)
    def _finalize():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("kind", "block_m", "block_f", "interpret"))
def ffn_act_int8(x: jax.Array, w_up_q: jax.Array, w_up_scale: jax.Array,
                 w_down_q: jax.Array, w_down_scale: jax.Array,
                 kind: str = "gelu", *, block_m: int = 128,
                 block_f: int = 512, interpret: bool | None = None
                 ) -> jax.Array:
    """x: (M,D); w_up_q int8 (D,F), w_up_scale (F,); w_down_q int8 (F,D),
    w_down_scale (D,). Ungated kinds (gelu/relu2)."""
    M, D = x.shape
    F = w_up_q.shape[1]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    block_m = min(block_m, M)
    block_f = min(block_f, F)
    assert M % block_m == 0 and F % block_f == 0
    num_m, num_f = M // block_m, F // block_f
    kernel = functools.partial(_ffn_q_kernel, kind=kind, num_f=num_f)
    return pl.pallas_call(
        kernel,
        grid=(num_m, num_f),
        in_specs=[
            pl.BlockSpec((block_m, D), lambda mi, fi: (mi, 0)),
            pl.BlockSpec((D, block_f), lambda mi, fi: (0, fi)),
            pl.BlockSpec((1, block_f), lambda mi, fi: (0, fi)),
            pl.BlockSpec((block_f, D), lambda mi, fi: (fi, 0)),
            pl.BlockSpec((1, D), lambda mi, fi: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, D), lambda mi, fi: (mi, 0)),
        out_shape=jax.ShapeDtypeStruct((M, D), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, D), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, w_up_q, w_up_scale.reshape(1, F), w_down_q,
      w_down_scale.reshape(1, D))
