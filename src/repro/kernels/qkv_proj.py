"""FUSED_QKV_PROJ (paper Table I): GEMM(X·Wq)+bq; GEMM(X·Wk)+bk;
GEMM(X·Wv)+bv in one pass over X.

The fusion's point in CHIME is that X is read from DRAM once and reused by
all three projections in the PU. TPU port: Wq|Wk|Wv are concatenated along
the output dim; the X row-block stays VMEM-resident while weight column
tiles stream; bias add fused (the SFPE step). The wrapper in ops.py splits
the concatenated output back into Q/K/V.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _qkv_kernel(x_ref, w_ref, b_ref, o_ref, *, use_bias: bool):
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    out = jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    if use_bias:
        out = out + b_ref[...].astype(jnp.float32)
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def qkv_proj(x: jax.Array, w: jax.Array, b: jax.Array | None = None, *,
             block_m: int = 128, block_n: int = 256,
             interpret: bool | None = None) -> jax.Array:
    """x: (M, D); w: (D, N) = concat(Wq|Wk|Wv); b: (N,) or None."""
    M, D = x.shape
    N = w.shape[1]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    block_m = min(block_m, M)
    block_n = min(block_n, N)
    assert M % block_m == 0 and N % block_n == 0, (M, N, block_m, block_n)
    use_bias = b is not None
    bb = (b if use_bias else jnp.zeros((N,), x.dtype)).reshape(1, N)

    kernel = functools.partial(_qkv_kernel, use_bias=use_bias)
    return pl.pallas_call(
        kernel,
        grid=(M // block_m, N // block_n),
        in_specs=[
            pl.BlockSpec((block_m, D), lambda mi, ni: (mi, 0)),
            pl.BlockSpec((D, block_n), lambda mi, ni: (0, ni)),
            pl.BlockSpec((1, block_n), lambda mi, ni: (0, ni)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n),
                               lambda mi, ni: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(x, w, bb)
