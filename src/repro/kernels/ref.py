"""Pure-jnp oracles for the Pallas kernels (paper Table I fused kernels).
These are the ground truth the kernel tests assert against, and the
execution path the dry-run lowers (so cost_analysis reflects shipped HLO).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 20


def attn_stream_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True,
                    scale: float | None = None) -> jax.Array:
    """q: (B,H,S,D); k,v: (B,Hkv,L,D); GQA by head grouping."""
    B, H, S, D = q.shape
    Hkv, L = k.shape[1], k.shape[2]
    G = H // Hkv
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, S, D)
    scale = scale if scale is not None else D ** -0.5
    scores = jnp.einsum("bkgsd,bkld->bkgsl", qf,
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.arange(L)[None, :] <= jnp.arange(S)[:, None] + (L - S)
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bkgsl,bkld->bkgsd", p, v.astype(jnp.float32))
    return o.reshape(B, H, S, D).astype(q.dtype)


def ffn_act_ref(x: jax.Array, w_up: jax.Array, w_gate: jax.Array | None,
                w_down: jax.Array, act: str = "silu_gated") -> jax.Array:
    """x: (M, D); w_up: (D, F); w_down: (F, D)."""
    h = x.astype(jnp.float32) @ w_up.astype(jnp.float32)
    if act in ("silu_gated",):
        h = jax.nn.silu(h)
    elif act in ("gelu", "gelu_gated"):
        h = jax.nn.gelu(h)
    elif act == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        raise ValueError(act)
    if w_gate is not None:
        h = h * (x.astype(jnp.float32) @ w_gate.astype(jnp.float32))
    return (h @ w_down.astype(jnp.float32)).astype(x.dtype)


def qkv_proj_ref(x: jax.Array, w: jax.Array,
                 b: jax.Array | None) -> jax.Array:
    """x: (M, D); w: (D, N) = concat(Wq|Wk|Wv); one pass over x."""
    out = x.astype(jnp.float32) @ w.astype(jnp.float32)
    if b is not None:
        out = out + b.astype(jnp.float32)
    return out.astype(x.dtype)


def fused_norm_ref(x: jax.Array, scale: jax.Array,
                   bias: jax.Array | None, kind: str = "rms",
                   eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rms":
        out = xf * jax.lax.rsqrt(
            jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
        out = out * scale.astype(jnp.float32)
    else:
        mean = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        out = (xf - mean) * jax.lax.rsqrt(var + eps) \
            * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)
