"""Version compatibility for Pallas TPU lowering parameters.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``; the
kernels use whichever this jax exposes so the same BlockSpecs lower on
both old and new toolchains.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams
