"""Fused paged-decode attention over the tiered/paged KV layout.

`attn_stream` fuses prefill; this is its decode-side sibling. The serving
decode step previously materialized the whole attendable store via
`store_read` (a dequantized f32/bf16 copy of the int8 cold tier) before
running unfused XLA attention. Here the online softmax streams K/V pages
straight out of the store-native layouts instead:

  * grid (slot, kv-head, 1 + block-table entry): step 0 consumes the hot
    ring (full precision, the just-appended token anchors the running
    max); steps 1..num_pages each consume one cold page through
    block-table indirection — a scalar-prefetch table maps logical page j
    to its physical page (-1 = dead page, skipped via `pl.when`);
  * per-slot lengths ride in scalar prefetch, so ragged contexts share
    one compiled kernel and the batched serving `decode_step` vmaps it;
  * **in-kernel int8 dequant**: cold pages stay in the per-(token, head)
    symmetric codec of `core.quant` (the PR 5 `hot_q`/`hot_scale` spill
    codec, `spill_codec_bound` contract) — the scales factor OUT of the
    dots exactly like the unfused `partial_attention` oracle
    (scores = (q·k_q)·k_scale; pv = (p·v_scale)·v_q), so no f32 restore
    of the cold tier ever exists, in HBM or VMEM.

SLIM-style adaptive-threshold sparse read (opt-in, ``tau`` > 0): with the
hot segment processed first, the running max m_g is anchored and the
denominator is >= 1, so a cold page whose score upper bound

    ub_g = scale * 127 * max(page k-scales) * ||q_g||_1   (>= any score,
                                  since |q . k_q| <= ||q||_1 * 127)

satisfies ub_g < m_g + log(tau) for EVERY group g contributes less than
block_k * tau probability mass per head and is skipped whole. The
documented drift contract (tests/test_paged_decode.py holds it
empirically, like the spill_compress logit-drift gate): total skipped
softmax mass per head < n_cold_tokens * tau. tau = 0 disables the check
and the kernel is an exact (modulo f32 associativity) twin of the
two-segment merge oracle.

Layouts (store-native, no transposed copies): q (B, Hkv, G, D) — head
h = hkv * G + g, matching the GQA group broadcast; hot k/v
(B, W, Hkv, D); cold q/v int8 (B, max_len, Hkv, D) with f32 scales
(B, max_len, Hkv, 1); lengths (B,) int32; table (B, num_pages) int32.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

NEG_INF = -2.0 ** 20
INT8_QMAX = 127.0  # symmetric int8 codec levels (core.quant)


# ---------------------------------------------------------------------------
# tiered stores: hot ring (full precision) + int8 cold pages
# ---------------------------------------------------------------------------
def _paged_tiered_kernel(len_ref, tab_ref, q_ref, hk_ref, hv_ref,
                         ckq_ref, cks_ref, cvq_ref, cvs_ref, o_ref,
                         acc_ref, m_ref, d_ref, *, scale: float,
                         block_k: int, num_pages: int, hot_w: int,
                         tau: float):
    b = pl.program_id(0)
    ki = pl.program_id(2)
    pos = len_ref[b]
    G = q_ref.shape[2]

    @pl.when(ki == 0)
    def _hot():
        # hot ring: slot i holds absolute position pos - ((pos - i) % W);
        # slot pos % W holds the just-appended token, so the running max
        # is anchored here — no reliance on exp underflow downstream.
        q = q_ref[0, 0].astype(jnp.float32)                # (G, D)
        k = hk_ref[0, :, 0, :].astype(jnp.float32)         # (W, D)
        v = hv_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale    # (G, W)
        slot = jax.lax.broadcasted_iota(jnp.int32, (G, hot_w), 1)
        hot_pos = pos - ((pos - slot) % hot_w)
        s = jnp.where(hot_pos >= 0, s, NEG_INF)
        m = jnp.max(s, axis=1, keepdims=True)              # (G, 1)
        p = jnp.exp(s - m)
        d_ref[...] = jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m

    # cold page j = ki - 1 covers tokens [j*block_k, (j+1)*block_k);
    # attendable cold positions are <= pos - W. The table entry is the
    # PHYSICAL page (used by the BlockSpecs); masking runs on logical j.
    j = jnp.maximum(ki - 1, 0)
    page_live = (ki > 0) & (tab_ref[b, j] >= 0) \
        & (j * block_k <= pos - hot_w)
    if tau > 0.0:
        # SLIM sparse read: skip the page when no group's score upper
        # bound can reach within log(tau) of the running max.
        qf = q_ref[0, 0].astype(jnp.float32)
        tok = j * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1)
        ks = cks_ref[0, :, 0, 0].reshape(1, block_k)
        max_ks = jnp.max(jnp.where(tok <= pos - hot_w, ks, 0.0))
        q_l1 = jnp.sum(jnp.abs(qf), axis=1, keepdims=True)  # (G, 1)
        ub = scale * INT8_QMAX * max_ks * q_l1
        page_live &= jnp.any(ub >= m_ref[...] + math.log(tau))

    @pl.when(page_live)
    def _cold():
        q = q_ref[0, 0].astype(jnp.float32)
        kq = ckq_ref[0, :, 0, :].astype(jnp.float32)       # (bk, D)
        ks = cks_ref[0, :, 0, 0]                           # (bk,)
        # scales factor out of the dots (the partial_attention math):
        # the int8 arrays are the only K/V bytes this step touches
        s = jax.lax.dot_general(
            q, kq, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        s = s * ks[None, :]
        tok = j * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (G, block_k), 1)
        s = jnp.where(tok <= pos - hot_w, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        d_ref[...] = d_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        vs = cvs_ref[0, :, 0, 0]
        vq = cvq_ref[0, :, 0, :].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p * vs[None, :], vq, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == num_pages)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(d_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "block_k", "tau", "interpret"))
def paged_decode_tiered(q: jax.Array, hot_k: jax.Array, hot_v: jax.Array,
                        cold_kq: jax.Array, cold_ks: jax.Array,
                        cold_vq: jax.Array, cold_vs: jax.Array,
                        lengths: jax.Array, table: jax.Array, *,
                        scale: float | None = None, block_k: int = 128,
                        tau: float = 0.0,
                        interpret: bool | None = None) -> jax.Array:
    """q (B,Hkv,G,D); hot (B,W,Hkv,D); cold int8 (B,max_len,Hkv,D) +
    f32 scales (B,max_len,Hkv,1); lengths (B,) int32 current positions;
    table (B,num_pages) int32 logical->physical page map (-1 = dead).
    Returns (B,Hkv,G,D) in q.dtype."""
    B, Hkv, G, D = q.shape
    W = hot_k.shape[1]
    max_len = cold_kq.shape[1]
    scale = scale if scale is not None else D ** -0.5
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    block_k = min(block_k, max_len)
    pad = (-max_len) % block_k
    if pad:  # ragged tail page: padded tokens sit past pos and stay masked
        cold_kq, cold_vq = (
            jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
            for t in (cold_kq, cold_vq))
        cold_ks, cold_vs = (
            jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
            for t in (cold_ks, cold_vs))
    num_pages = (max_len + pad) // block_k
    assert table.shape == (B, num_pages), (table.shape, B, num_pages)

    def _bcast_idx(b, h, ki, lens, tab):
        return (b, h, 0, 0)

    def _hot_idx(b, h, ki, lens, tab):
        return (b, 0, h, 0)

    def _cold_idx(b, h, ki, lens, tab):
        return (b, jnp.maximum(tab[b, jnp.maximum(ki - 1, 0)], 0), h, 0)

    kernel = functools.partial(
        _paged_tiered_kernel, scale=scale, block_k=block_k,
        num_pages=num_pages, hot_w=W, tau=tau)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, num_pages + 1),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), _bcast_idx),       # q (VMEM-resident)
            pl.BlockSpec((1, W, 1, D), _hot_idx),         # hot k
            pl.BlockSpec((1, W, 1, D), _hot_idx),         # hot v
            pl.BlockSpec((1, block_k, 1, D), _cold_idx),  # cold k int8
            pl.BlockSpec((1, block_k, 1, 1), _cold_idx),  # cold k scale
            pl.BlockSpec((1, block_k, 1, D), _cold_idx),  # cold v int8
            pl.BlockSpec((1, block_k, 1, 1), _cold_idx),  # cold v scale
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), _bcast_idx),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths, table, q, hot_k, hot_v, cold_kq, cold_ks, cold_vq, cold_vs)


# ---------------------------------------------------------------------------
# flat stores: full-precision pages, same block-table indirection
# ---------------------------------------------------------------------------
def _paged_flat_kernel(len_ref, tab_ref, q_ref, k_ref, v_ref, o_ref,
                       acc_ref, m_ref, d_ref, *, scale: float,
                       block_k: int, num_pages: int):
    b = pl.program_id(0)
    ki = pl.program_id(2)
    pos = len_ref[b]
    G = q_ref.shape[2]

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        d_ref[...] = jnp.zeros_like(d_ref)

    # page ki covers tokens [ki*block_k, (ki+1)*block_k); page 0 always
    # holds token 0 <= pos, so the running max is anchored on step 0
    page_live = (tab_ref[b, ki] >= 0) & (ki * block_k <= pos)

    @pl.when(page_live)
    def _update():
        q = q_ref[0, 0].astype(jnp.float32)                # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # (bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        tok = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (G, block_k), 1)
        s = jnp.where(tok <= pos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        d_ref[...] = d_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == num_pages - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(d_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "block_k", "interpret"))
def paged_decode_flat(q: jax.Array, k: jax.Array, v: jax.Array,
                      lengths: jax.Array, table: jax.Array, *,
                      scale: float | None = None, block_k: int = 128,
                      interpret: bool | None = None) -> jax.Array:
    """q (B,Hkv,G,D); k,v (B,max_len,Hkv,D); lengths (B,) int32; table
    (B,num_pages) int32 (-1 = dead). Returns (B,Hkv,G,D). The sparse read
    is tiered-only: the flat store carries no per-page scales to bound
    scores with."""
    B, Hkv, G, D = q.shape
    max_len = k.shape[1]
    scale = scale if scale is not None else D ** -0.5
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    block_k = min(block_k, max_len)
    pad = (-max_len) % block_k
    if pad:
        k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
                for t in (k, v))
    num_pages = (max_len + pad) // block_k
    assert table.shape == (B, num_pages), (table.shape, B, num_pages)

    def _bcast_idx(b, h, ki, lens, tab):
        return (b, h, 0, 0)

    def _page_idx(b, h, ki, lens, tab):
        return (b, jnp.maximum(tab[b, ki], 0), h, 0)

    kernel = functools.partial(
        _paged_flat_kernel, scale=scale, block_k=block_k,
        num_pages=num_pages)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, num_pages),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), _bcast_idx),
            pl.BlockSpec((1, block_k, 1, D), _page_idx),
            pl.BlockSpec((1, block_k, 1, D), _page_idx),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), _bcast_idx),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths, table, q, k, v)


def paged_decode_vmem_bytes(block_k: int, G: int, D: int, hot_w: int,
                            dtype_bytes: int = 2) -> int:
    """Static VMEM working set of the tiered kernel: store-dtype tiles
    plus their in-kernel f32 casts, int8 cold tiles plus casts, scales,
    scratch and the output block."""
    q_tile = G * D * (dtype_bytes + 4)
    hot_tiles = 2 * hot_w * D * (dtype_bytes + 4)
    cold_tiles = 2 * block_k * D * (1 + 4)      # int8 + f32 cast
    scales = 2 * block_k * (4 + 4)
    scratch = (G * D + 2 * G) * 4               # acc + m + d
    out = G * D * dtype_bytes
    return q_tile + hot_tiles + cold_tiles + scales + scratch + out
