"""FUSED_ATTN_STREAM (paper Table I): flash-style streaming attention.

CHIME's DRAM-NMP streams K/V tiles from DRAM row buffers through the
SFPE-PE pipeline, updating an online softmax so the (S, L) score matrix is
never materialized. The TPU port: the Q block is VMEM-resident, K/V tiles
stream HBM->VMEM via BlockSpecs, scores/probabilities live only in
VMEM/VREGs, the running (max, denominator, accumulator) state sits in VMEM
scratch. MXU-aligned tiles (multiples of 128 on the matmul dims).

Layout: q (B, H, S, D); k, v (B, Hkv, L, D); GQA mapped by pointing each Q
head's K/V BlockSpec at head h // (H // Hkv).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

NEG_INF = -2.0 ** 20


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, d_ref, *,
                 scale: float, causal: bool, block_q: int, block_k: int,
                 num_k: int, q_offset: int, kv_len: int):
    """Grid: (BH, num_q, num_k); the k axis is the streaming ('arbitrary')
    dimension carrying the online-softmax state in scratch. `kv_len` is the
    unpadded key count: blocks at or past it hold grid padding only."""
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        d_ref[...] = jnp.zeros_like(d_ref)

    # A k-block contributes nothing when it is pure grid padding, or —
    # under the causal mask — when it sits entirely above the diagonal
    # (min k_pos > max q_pos for this q block). Skipping keeps the
    # accumulator exact instead of leaning on f32 exp underflow, which is
    # what broke once every score in a block was NEG_INF.
    dead = ki * block_k >= kv_len
    if causal:
        dead |= ki * block_k > qi * block_q + block_q - 1 + q_offset

    @pl.when(jnp.logical_not(dead))
    def _update():
        q = q_ref[0].astype(jnp.float32)                   # (bq, D)
        k = k_ref[0].astype(jnp.float32)                   # (bk, D)
        v = v_ref[0].astype(jnp.float32)                   # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale    # (bq, bk)

        k_pos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        if causal:
            q_pos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0) + q_offset
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        if kv_len % block_k:  # padded tail block: mask phantom keys
            s = jnp.where(k_pos < kv_len, s, NEG_INF)

        m_prev = m_ref[...]                                # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        d_ref[...] = d_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == num_k - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(d_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "interpret"))
def attn_stream(q: jax.Array, k: jax.Array, v: jax.Array, *,
                causal: bool = True, scale: float | None = None,
                block_q: int = 128, block_k: int = 128,
                interpret: bool | None = None) -> jax.Array:
    """q: (B,H,S,D); k,v: (B,Hkv,L,D) -> (B,H,S,D)."""
    B, H, S, D = q.shape
    Hkv, L = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else D ** -0.5
    if causal and S > L:
        raise ValueError(
            f"causal attn_stream requires S <= L (got S={S}, L={L}): with "
            f"q_offset = L - S negative the first S - L queries precede "
            f"every key and their attention is undefined")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    block_q = min(block_q, S)
    block_k = min(block_k, L)
    # Ragged shapes: pad q/k/v up to the block grid. Phantom keys are
    # masked to NEG_INF in-kernel (kv_len) and phantom query rows are
    # sliced off the output below.
    Sp = -(-S // block_q) * block_q
    Lp = -(-L // block_k) * block_k
    num_q, num_k = Sp // block_q, Lp // block_k
    q_offset = L - S  # causal alignment when L != S (cached prefix)

    qf = q.reshape(B * H, S, D)
    kf = k.reshape(B * Hkv, L, D)
    vf = v.reshape(B * Hkv, L, D)
    if Sp != S:
        qf = jnp.pad(qf, ((0, 0), (0, Sp - S), (0, 0)))
    if Lp != L:
        kf = jnp.pad(kf, ((0, 0), (0, Lp - L), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, Lp - L), (0, 0)))

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, num_k=num_k, q_offset=q_offset, kv_len=L)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, num_q, num_k),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda bh, qi, ki, G=G: (bh // G, ki, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda bh, qi, ki, G=G: (bh // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    out = out.reshape(B, H, Sp, D)
    return out[:, :, :S] if Sp != S else out


def attn_stream_vmem_bytes(block_q: int, block_k: int, D: int,
                           dtype_bytes: int = 2) -> int:
    """Static VMEM working set claimed by the BlockSpecs + scratch —
    used by tests to assert the tiles fit v5e VMEM (~128 MB)."""
    tiles = (block_q * D + 2 * block_k * D) * dtype_bytes   # q + k + v
    casts = (block_q * D + 2 * block_k * D) * 4             # f32 copies
    scratch = (block_q * D + 2 * block_q) * 4               # acc + m + d
    out = block_q * D * dtype_bytes
    return tiles + casts + scratch + out
