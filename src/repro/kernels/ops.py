"""Public jit'd wrappers for the Pallas kernels with model-layout adapters
and jnp fallback (interpret on CPU, compiled on TPU)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import attn_stream as _attn
from repro.kernels import ffn_act as _ffn
from repro.kernels import fused_norm as _norm
from repro.kernels import qkv_proj as _qkv
from repro.kernels import ref

attn_stream_kernel = _attn.attn_stream
ffn_act_kernel = _ffn.ffn_act
qkv_proj_kernel = _qkv.qkv_proj
fused_norm_kernel = _norm.fused_norm


def attn_stream(q: jax.Array, k: jax.Array, v: jax.Array,
                causal: bool = True) -> jax.Array:
    """Model layout (B,S,H,D)/(B,L,Hkv,D) -> kernel layout and back."""
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    o = _attn.attn_stream(qt, kt, vt, causal=causal)
    return jnp.swapaxes(o, 1, 2)


def ffn_act(x: jax.Array, w_up: jax.Array, w_gate: jax.Array | None,
            w_down: jax.Array, kind: str) -> jax.Array:
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])
    out = _ffn.ffn_act(xf, w_up, w_gate, w_down, kind)
    return out.reshape(*lead, -1)


def qkv_proj(x: jax.Array, wq: jax.Array, wk: jax.Array, wv: jax.Array,
             bq=None, bk=None, bv=None):
    """Weights (D, Hx, Dh) per projection; returns q,k,v in model layout."""
    D = x.shape[-1]
    lead = x.shape[:-1]
    shapes = [w.shape[1:] for w in (wq, wk, wv)]
    w = jnp.concatenate([w.reshape(D, -1) for w in (wq, wk, wv)], axis=1)
    b = None
    if bq is not None:
        b = jnp.concatenate([t.reshape(-1) for t in (bq, bk, bv)])
    out = _qkv.qkv_proj(x.reshape(-1, D), w, b)
    sizes = [h * d for h, d in shapes]
    qf, kf, vf = jnp.split(out, [sizes[0], sizes[0] + sizes[1]], axis=-1)
    return (qf.reshape(*lead, *shapes[0]),
            kf.reshape(*lead, *shapes[1]),
            vf.reshape(*lead, *shapes[2]))


def fused_norm(x: jax.Array, scale: jax.Array, bias: jax.Array | None,
               kind: str = "rms") -> jax.Array:
    lead = x.shape[:-1]
    out = _norm.fused_norm(x.reshape(-1, x.shape[-1]), scale, bias, kind)
    return out.reshape(*lead, -1)


__all__ = ["attn_stream", "ffn_act", "qkv_proj", "fused_norm", "ref"]
