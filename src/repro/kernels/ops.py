"""Public jit'd wrappers for the Pallas kernels with model-layout adapters
and jnp fallback (interpret on CPU, compiled on TPU)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import attn_stream as _attn
from repro.kernels import ffn_act as _ffn
from repro.kernels import fused_norm as _norm
from repro.kernels import paged_decode as _paged
from repro.kernels import qkv_proj as _qkv
from repro.kernels import ref

attn_stream_kernel = _attn.attn_stream
ffn_act_kernel = _ffn.ffn_act
qkv_proj_kernel = _qkv.qkv_proj
fused_norm_kernel = _norm.fused_norm
paged_decode_tiered_kernel = _paged.paged_decode_tiered
paged_decode_flat_kernel = _paged.paged_decode_flat


def attn_stream(q: jax.Array, k: jax.Array, v: jax.Array,
                causal: bool = True) -> jax.Array:
    """Model layout (B,S,H,D)/(B,L,Hkv,D) -> kernel layout and back."""
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    o = _attn.attn_stream(qt, kt, vt, causal=causal)
    return jnp.swapaxes(o, 1, 2)


PAGED_DECODE_BLOCK = 128  # cold-page tokens per grid step (= endurance blk)


def paged_decode_tiered(cfg, q: jax.Array, k_store: dict, v_store: dict,
                        pos, *, tau: float = 0.0,
                        block_k: int = PAGED_DECODE_BLOCK) -> jax.Array:
    """Fused decode attention over a tiered store. q (B,1,H,D) model
    layout; the identity block table is derived from pos (dead pages are
    -1 so the kernel skips them). Returns (B,1,H,D)."""
    from repro.core import kv_tiers as KT
    B, S, H, D = q.shape
    Hkv = k_store["hot"].shape[2]
    G = H // Hkv
    W = KT.hot_window_of(k_store)
    max_len = k_store["cold_q"].shape[1]
    bk = min(block_k, max_len)
    tab = jnp.broadcast_to(
        KT.cold_page_table(pos, W, max_len, bk)[None],
        (B, KT.n_cold_pages(max_len, bk)))
    lengths = jnp.full((B,), pos, jnp.int32)
    qr = q[:, 0].reshape(B, Hkv, G, D)
    o = _paged.paged_decode_tiered(
        qr, k_store["hot"], v_store["hot"],
        k_store["cold_q"], k_store["cold_scale"],
        v_store["cold_q"], v_store["cold_scale"],
        lengths, tab, scale=D ** -0.5, block_k=bk, tau=tau)
    return o.reshape(B, H, D)[:, None]


def paged_decode_flat(cfg, q: jax.Array, k_store: dict, v_store: dict,
                      pos, *, block_k: int = PAGED_DECODE_BLOCK
                      ) -> jax.Array:
    """Fused decode attention over a flat store; same table plumbing with
    hot_window=0 (valid = position <= pos)."""
    from repro.core import kv_tiers as KT
    B, S, H, D = q.shape
    Hkv = k_store["flat"].shape[2]
    G = H // Hkv
    max_len = k_store["flat"].shape[1]
    bk = min(block_k, max_len)
    tab = jnp.broadcast_to(
        KT.cold_page_table(pos, 0, max_len, bk)[None],
        (B, KT.n_cold_pages(max_len, bk)))
    lengths = jnp.full((B,), pos, jnp.int32)
    qr = q[:, 0].reshape(B, Hkv, G, D)
    o = _paged.paged_decode_flat(
        qr, k_store["flat"], v_store["flat"], lengths, tab,
        scale=D ** -0.5, block_k=bk)
    return o.reshape(B, H, D)[:, None]


def ffn_act(x: jax.Array, w_up: jax.Array, w_gate: jax.Array | None,
            w_down: jax.Array, kind: str) -> jax.Array:
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])
    out = _ffn.ffn_act(xf, w_up, w_gate, w_down, kind)
    return out.reshape(*lead, -1)


def qkv_proj(x: jax.Array, wq: jax.Array, wk: jax.Array, wv: jax.Array,
             bq=None, bk=None, bv=None):
    """Weights (D, Hx, Dh) per projection; returns q,k,v in model layout."""
    D = x.shape[-1]
    lead = x.shape[:-1]
    shapes = [w.shape[1:] for w in (wq, wk, wv)]
    w = jnp.concatenate([w.reshape(D, -1) for w in (wq, wk, wv)], axis=1)
    b = None
    if bq is not None:
        b = jnp.concatenate([t.reshape(-1) for t in (bq, bk, bv)])
    out = _qkv.qkv_proj(x.reshape(-1, D), w, b)
    sizes = [h * d for h, d in shapes]
    qf, kf, vf = jnp.split(out, [sizes[0], sizes[0] + sizes[1]], axis=-1)
    return (qf.reshape(*lead, *shapes[0]),
            kf.reshape(*lead, *shapes[1]),
            vf.reshape(*lead, *shapes[2]))


def fused_norm(x: jax.Array, scale: jax.Array, bias: jax.Array | None,
               kind: str = "rms") -> jax.Array:
    lead = x.shape[:-1]
    out = _norm.fused_norm(x.reshape(-1, x.shape[-1]), scale, bias, kind)
    return out.reshape(*lead, -1)


__all__ = ["attn_stream", "ffn_act", "qkv_proj", "fused_norm",
           "paged_decode_tiered", "paged_decode_flat", "ref"]
