"""FCFS + capacity-aware admission control.

CHIME's two memory domains cap concurrency independently: every admitted
request pins a bf16 hot ring (+ recurrent states) in the M3D DRAM stack
and an int8 cold prefix (+ scales) in the write-once RRAM tier. The
scheduler derives byte budgets from the `simulator/hardware.py` domain
capacities and admits the queue head only while BOTH domains have room —
so a bigger hot window or longer max_len genuinely buys fewer concurrent
requests, the same trade the paper's Table III/IV capacities impose.
"""

from __future__ import annotations

import collections
import dataclasses

from repro.serving.request import Request
from repro.simulator.hardware import CHIME, Platform


@dataclasses.dataclass(frozen=True)
class CapacityBudget:
    """KV byte budgets per memory domain."""
    dram_bytes: float
    rram_bytes: float

    @classmethod
    def from_platform(cls, platform: Platform = CHIME,
                      kv_fraction: float = 0.5) -> "CapacityBudget":
        """Reserve ``kv_fraction`` of each domain for KV state (the rest
        holds weights and activations; the paper keeps FFN weights
        resident in RRAM and attention weights in DRAM)."""
        dram = platform.domains["dram"].capacity_bytes * kv_fraction
        rram_dom = platform.domains.get("rram", platform.domains["dram"])
        rram = rram_dom.capacity_bytes * kv_fraction
        return cls(dram, rram)

    def max_concurrent(self, hot_bytes_per_slot: int,
                       cold_bytes_per_slot: int) -> int:
        """Largest slot count both domains can hold simultaneously."""
        lim = float("inf")
        if hot_bytes_per_slot > 0:
            lim = min(lim, self.dram_bytes // hot_bytes_per_slot)
        if cold_bytes_per_slot > 0:
            lim = min(lim, self.rram_bytes // cold_bytes_per_slot)
        return int(lim) if lim != float("inf") else 2 ** 30

    def admits(self, n_resident: int, hot_bytes_per_slot: int,
               cold_bytes_per_slot: int) -> bool:
        """Can an (n_resident+1)-th request's KV state fit?"""
        return ((n_resident + 1) * hot_bytes_per_slot <= self.dram_bytes
                and (n_resident + 1) * cold_bytes_per_slot
                <= self.rram_bytes)


class FCFSScheduler:
    """First-come-first-served queue gated by the capacity budget.

    Strictly FCFS: if the head of the queue does not fit, nothing is
    admitted (no starvation of large requests by small ones).
    """

    def __init__(self, budget: CapacityBudget, hot_bytes_per_slot: int,
                 cold_bytes_per_slot: int):
        self.budget = budget
        self.hot_bytes_per_slot = hot_bytes_per_slot
        self.cold_bytes_per_slot = cold_bytes_per_slot
        self._queue: collections.deque[Request] = collections.deque()
        self.admitted = 0

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self._queue.append(req)

    @property
    def pending(self) -> int:
        return len(self._queue)

    @property
    def max_concurrent(self) -> int:
        return self.budget.max_concurrent(self.hot_bytes_per_slot,
                                          self.cold_bytes_per_slot)

    def can_admit(self, n_active: int) -> bool:
        return bool(self._queue) and self.budget.admits(
            n_active, self.hot_bytes_per_slot, self.cold_bytes_per_slot)

    def next_request(self, n_active: int) -> Request | None:
        """Pop the queue head iff both domain budgets admit one more
        resident request."""
        if not self.can_admit(n_active):
            return None
        self.admitted += 1
        return self._queue.popleft()
