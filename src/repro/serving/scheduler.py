"""Step-plan scheduling: FCFS + capacity-gated admission + Sarathi-style
chunked prefill under a per-step token budget.

CHIME's two memory domains cap concurrency independently: every admitted
request pins a bf16 hot ring (+ recurrent states) in the M3D DRAM stack
and an int8 cold prefix (+ scales) in the write-once RRAM tier. The
scheduler derives byte budgets from the `simulator/hardware.py` domain
capacities and admits the queue head only while BOTH domains have room —
so a bigger hot window or longer max_len genuinely buys fewer concurrent
requests, the same trade the paper's Table III/IV capacities impose.

Since PR 3 the scheduler emits a `StepPlan` per engine step instead of
popping whole requests: each step gets ``token_budget`` tokens, decode
slots take one each, and the remainder goes to in-flight prefill chunks
of at most ``chunk_tokens`` positions (the paper's long-vision-prompt
workloads no longer stall every decode slot for a whole prompt). Ordering
stays strictly FCFS — one prompt prefills at a time, and the queue head
is admitted (slot + byte budgets permitting) only once the previous
prompt committed. Defaults (no budget, no chunk cap) reproduce the PR 1/2
admit-whole-prompt behavior exactly.

Since PR 4 the scheduler is PREEMPTIVE under oversubscription: requests
carry a ``priority`` (higher runs first; FCFS within a class), and when
the queue head outranks a running request while no slot is free, the
plan evicts the lowest-priority, most-recently-admitted victim into an
RRAM spill lane (`StepPlan.evictions`) and later restores it bit-exactly
(`StepPlan.restores`) once capacity frees. ``oversubscribe`` relaxes the
DRAM admission gate by that factor — the marginal resident's bulk KV is
RRAM-resident cold tier, and the overflow must be covered by free spill
lanes so any overflow slot can always be paged out (Cambricon-LLM/SLIM-
style spill-to-dense-tier serving beyond DRAM capacity).

Since PR 5 RRAM is a first-class CAPACITY tier, not just a preemption
parking lot: with ``idle_offload_steps=N`` set, a waiter that cannot get
in (and does not strictly outrank anyone — so PR 4 preemption did not
fire) may still be admitted by OFFLOADING a runner that has been
resident >= N decode steps (`StepPlan.offloads` — the same verbatim,
bit-exact evict/restore machinery; equal-priority rotation is
RRAM-backed time slicing with quantum N). The freed DRAM hot bytes admit
the waiter under the BASE byte gates — no all-or-nothing oversubscribe
factor. ``lane_bytes`` is what one parked image charges against the
RRAM budget: compressed lanes (int8 hot ring, see `core/quant.py`)
shrink it, which is how a fixed RRAM spill budget backs more lanes.
"""

from __future__ import annotations

import collections
import dataclasses

from repro.serving.request import Request
from repro.simulator.hardware import CHIME, Platform


@dataclasses.dataclass(frozen=True)
class CapacityBudget:
    """KV byte budgets per memory domain."""
    dram_bytes: float
    rram_bytes: float

    @classmethod
    def from_platform(cls, platform: Platform = CHIME,
                      kv_fraction: float = 0.5) -> "CapacityBudget":
        """Reserve ``kv_fraction`` of each domain for KV state (the rest
        holds weights and activations; the paper keeps FFN weights
        resident in RRAM and attention weights in DRAM)."""
        dram = platform.domains["dram"].capacity_bytes * kv_fraction
        rram_dom = platform.domains.get("rram", platform.domains["dram"])
        rram = rram_dom.capacity_bytes * kv_fraction
        return cls(dram, rram)

    def max_concurrent(self, hot_bytes_per_slot: int,
                       cold_bytes_per_slot: int, *,
                       weight_bytes: float = 0.0) -> int:
        """Largest slot count both domains can hold simultaneously.
        ``weight_bytes`` (the DRAM-resident weight working set) comes off
        the top of the DRAM budget before any KV slot charges it."""
        dram = self.dram_bytes - weight_bytes
        if dram < 0:
            return 0
        lim = float("inf")
        if hot_bytes_per_slot > 0:
            lim = min(lim, dram // hot_bytes_per_slot)
        if cold_bytes_per_slot > 0:
            lim = min(lim, self.rram_bytes // cold_bytes_per_slot)
        return int(lim) if lim != float("inf") else 2 ** 30

    def admits(self, n_resident: int, hot_bytes_per_slot: int,
               cold_bytes_per_slot: int, *, oversubscribe: float = 1.0,
               spilled: int = 0, spill_lanes: int = 0,
               spilled_bytes: float = 0.0,
               weight_bytes: float = 0.0) -> bool:
        """Can an (n_resident+1)-th request's KV state fit?

        ``oversubscribe`` scales the DRAM gate (>= 1): residents beyond
        the base DRAM capacity are spill-backed, so the overflow plus the
        ``spilled`` requests already parked in RRAM must fit in
        ``spill_lanes`` lanes, and ``spilled_bytes`` (the parked images)
        counts against the RRAM budget alongside the cold tiers.
        ``weight_bytes`` is the DRAM-resident weight working set — it is
        NOT spill-backed, so it shrinks the DRAM budget before the
        oversubscribe factor applies."""
        return self.deny_reason(
            n_resident, hot_bytes_per_slot, cold_bytes_per_slot,
            oversubscribe=oversubscribe, spilled=spilled,
            spill_lanes=spill_lanes, spilled_bytes=spilled_bytes,
            weight_bytes=weight_bytes) is None

    def deny_reason(self, n_resident: int, hot_bytes_per_slot: int,
                    cold_bytes_per_slot: int, *,
                    oversubscribe: float = 1.0, spilled: int = 0,
                    spill_lanes: int = 0,
                    spilled_bytes: float = 0.0,
                    weight_bytes: float = 0.0) -> str | None:
        """`admits`, but naming WHICH gate blocks: ``dram_weights``
        (the weight working set alone overflows DRAM — nothing can ever
        be admitted; stream the weights instead), ``dram_budget``,
        ``spill_lanes`` or ``rram_budget`` (None = admissible) — the
        telemetry decision log's admission-denial reason codes."""
        hot, cold = hot_bytes_per_slot, cold_bytes_per_slot
        dram = self.dram_bytes - weight_bytes
        if dram < 0:
            return "dram_weights"
        n = n_resident + 1
        if n * hot > dram * oversubscribe:
            return "dram_budget"
        if hot > 0 and oversubscribe > 1.0:
            overflow = n - int(dram // hot)
            if overflow > 0 and overflow + spilled > spill_lanes:
                return "spill_lanes"
        if n * cold + spilled_bytes > self.rram_bytes:
            return "rram_budget"
        return None

    def deny_reason_bytes(self, hot_bytes: float, cold_bytes: float, *,
                          hot_unit: int = 0, oversubscribe: float = 1.0,
                          spilled: int = 0, spill_lanes: int = 0,
                          spilled_bytes: float = 0.0,
                          weight_bytes: float = 0.0) -> str | None:
        """`deny_reason` for LIVE byte totals instead of uniform per-slot
        worst cases: the paged pool charges each resident its block-
        rounded prompt+generation footprint, so the gate compares the
        summed hot/cold bytes (candidate included) directly against the
        domain budgets. ``hot_unit`` (one full slot's hot bytes) converts
        DRAM overflow into spill-lane slots for the oversubscribe gate."""
        dram = self.dram_bytes - weight_bytes
        if dram < 0:
            return "dram_weights"
        if hot_bytes > dram * oversubscribe:
            return "dram_budget"
        if hot_unit > 0 and oversubscribe > 1.0:
            over = hot_bytes - dram
            overflow = int(-(-over // hot_unit)) if over > 0 else 0
            if overflow > 0 and overflow + spilled > spill_lanes:
                return "spill_lanes"
        if cold_bytes + spilled_bytes > self.rram_bytes:
            return "rram_budget"
        return None


@dataclasses.dataclass(frozen=True)
class PrefillChunk:
    """One planned extend call: ``length`` prompt positions of ``req``
    starting at absolute position ``start``. ``admit`` means the request
    enters prefill with this chunk (the engine allocates its pool slot
    first); ``commit`` means the chunk completes the prompt (the backend
    folds the workspace into the slot and the first token streams)."""
    req: Request
    admit: bool
    start: int
    length: int
    commit: bool


@dataclasses.dataclass(frozen=True)
class StepPlan:
    """The work one engine step executes, in order: spill ``evictions``
    (victim slots pack into RRAM lanes), ``restores`` (spilled requests
    scatter back into freed slots and rejoin decode this step), prefill
    chunks (in FCFS order, at most one request in flight at a time), then
    one decode token on every active slot. ``decode`` is True when the
    step is expected to decode — slots were already active (surviving the
    evictions), a restore re-activates one, or a committing chunk
    activates one this step."""
    chunks: tuple[PrefillChunk, ...]
    decode: bool
    evictions: tuple = ()         # Requests leaving their slot for a lane
    restores: tuple = ()          # Requests resuming from a lane
    offloads: tuple = ()          # idle residents parking for a waiter
    #   (executed exactly like evictions; split out so the engine's
    #   preemption and capacity-offload stats stay distinguishable —
    #   at most ONE of evictions/offloads is non-empty per plan)

    @property
    def prefill_tokens(self) -> int:
        return sum(c.length for c in self.chunks)


class FCFSScheduler:
    """Priority + first-come-first-served StepPlan producer gated by the
    capacity budget and a per-step token budget.

    The queue orders by (priority desc, arrival) — strictly FCFS within
    a priority class: if the head does not fit, nothing is admitted (no
    starvation of large requests by small ones), and a new prompt starts
    prefilling only after the in-flight one commits.

    ``token_budget`` caps the total tokens one step computes (each active
    decode slot costs 1; the remainder feeds prefill chunks).
    ``chunk_tokens`` caps a single prefill chunk. Both default to None
    (unbounded / whole-prompt chunks — the pre-StepPlan behavior).

    ``oversubscribe`` (>= 1, None = engine-resolved, default off) relaxes
    the DRAM admission gate by that factor, spill-lane-backed (see
    `CapacityBudget.admits`). ``spill_lanes`` (None = engine fills it
    from the backend) bounds simultaneous preemptions; when a waiter
    strictly outranks a running request and no slot is free, `plan`
    evicts the lowest-priority, most-recently-admitted victim.

    ``idle_offload_steps`` (>= 1, None = engine-resolved, default off)
    enables proactive idle cold-KV offload: a blocked waiter of EQUAL or
    higher priority may park a runner resident >= that many decode steps
    (see the module docstring). ``lane_bytes`` (None = engine fills it
    from the backend; falls back to one full slot image) is the RRAM
    bytes one parked spill image charges against the budget.

    ``charge_fn`` (None = per-slot worst case) switches the byte gates
    to PAGED accounting: it maps a request to its (hot, cold) byte
    charge — the engine supplies block-rounded prompt+generation bytes
    net of the request's prefix-cache hit — and the scheduler sums live
    charges across residents (admit adds, park subtracts, restore
    re-adds, `release` retires) instead of multiplying a uniform slot
    worst case. ``prefix_probe`` (None = no prefix cache) is called on
    the queue head right before its admission check and returns the
    cached-prefix hit length; the head's first chunk then STARTS at that
    position, so only the tail charges the step token budget.
    ``shared_bytes_fn`` reports the prefix store's *pinned* bytes
    (blocks referenced by a live admission — unreferenced cached blocks
    are reclaimable and must not gate admission), charged against the
    RRAM budget alongside parked spill images.

    ``weight_bytes`` (None = engine fills it from the backend when
    weight charging is on; None/0 reproduces the legacy KV-only gates)
    is the DRAM-resident weight working set, charged off the top of the
    DRAM budget before any KV byte gate — weight streaming shrinks it to
    embeddings + head + the per-unit sliding windows, which is what lets
    an over-budget model through the gate at all (deny reason
    ``dram_weights`` when the weights alone overflow the domain).
    """

    def __init__(self, budget: CapacityBudget, hot_bytes_per_slot: int,
                 cold_bytes_per_slot: int,
                 token_budget: int | None = None,
                 chunk_tokens: int | None = None,
                 oversubscribe: float | None = None,
                 spill_lanes: int | None = None,
                 idle_offload_steps: int | None = None,
                 lane_bytes: int | None = None,
                 charge_fn=None, prefix_probe=None,
                 shared_bytes_fn=None,
                 weight_bytes: float | None = None):
        if chunk_tokens is not None and chunk_tokens < 1:
            # a cap < 1 would make plan() emit degenerate chunks forever
            raise ValueError(f"chunk_tokens must be >= 1 or None, got "
                             f"{chunk_tokens}")
        if token_budget is not None and token_budget < 1:
            raise ValueError(f"token_budget must be >= 1 or None, got "
                             f"{token_budget}")
        if oversubscribe is not None and oversubscribe < 1:
            raise ValueError(f"oversubscribe must be >= 1 or None, got "
                             f"{oversubscribe}")
        if weight_bytes is not None and weight_bytes < 0:
            raise ValueError(f"weight_bytes must be >= 0 or None, got "
                             f"{weight_bytes}")
        if idle_offload_steps is not None and idle_offload_steps < 1:
            # < 1 would offload a request the same step it got its slot:
            # zero guaranteed progress per rotation = potential livelock
            raise ValueError(f"idle_offload_steps must be >= 1 or None, "
                             f"got {idle_offload_steps}")
        self.budget = budget
        self.hot_bytes_per_slot = hot_bytes_per_slot
        self.cold_bytes_per_slot = cold_bytes_per_slot
        self.token_budget = token_budget
        self.chunk_tokens = chunk_tokens
        self.oversubscribe = oversubscribe
        self.spill_lanes = spill_lanes
        self.idle_offload_steps = idle_offload_steps
        self.lane_bytes = lane_bytes
        self.charge_fn = charge_fn
        self.prefix_probe = prefix_probe
        self.shared_bytes_fn = shared_bytes_fn
        # DRAM-resident weight working set charged off the top of the
        # DRAM budget (None = engine fills it from the backend when
        # weight charging is on; stays None -> charges 0, the legacy
        # KV-only accounting)
        self.weight_bytes = weight_bytes
        # paged accounting: admission-time (hot, cold) charge per resident
        # rid; parked requests keep their entry (sums drop, re-add on
        # restore) so the round trip is charge-neutral
        self._charges: dict[int, tuple[int, int]] = {}
        self._charged_hot = 0
        self._charged_cold = 0
        self._queue: collections.deque[Request] = collections.deque()
        self._spilled: list[Request] = []
        self.admitted = 0
        self._seq = 0                 # admission recency (victim pick)
        # decision-log sink: the engine attaches its Telemetry hub here
        # (None = no logging; `_note` is then a cheap None check)
        self.telemetry = None

    def _note(self, code: str, req: Request | None = None, **args):
        """Log one scheduler decision (reason codes in
        `telemetry.REASON_CODES`) if a telemetry hub is attached."""
        if self.telemetry is not None:
            self.telemetry.decision(
                code, rid=None if req is None else req.rid, **args)

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        """Enqueue by (priority desc, arrival): FCFS within a class."""
        pr = req.priority
        if not self._queue or self._queue[-1].priority >= pr:
            self._queue.append(req)
            return
        for i, q in enumerate(self._queue):
            if q.priority < pr:
                self._queue.insert(i, req)
                return
        self._queue.append(req)

    @property
    def pending(self) -> int:
        return len(self._queue)

    @property
    def spilled(self) -> int:
        return len(self._spilled)

    @property
    def _slot_bytes(self) -> int:
        return self.hot_bytes_per_slot + self.cold_bytes_per_slot

    # ---- paged (live-byte) charge bookkeeping ------------------------
    def _charge_of(self, req: Request | None) -> tuple[int, int]:
        """(hot, cold) bytes ``req`` charges: the stored admission-time
        value, a fresh ``charge_fn`` quote, or the slot worst case when
        no candidate is known."""
        if req is not None and req.rid in self._charges:
            return self._charges[req.rid]
        if req is not None and self.charge_fn is not None:
            return self.charge_fn(req)
        return (self.hot_bytes_per_slot, self.cold_bytes_per_slot)

    def _charge_admit(self, req: Request):
        if self.charge_fn is None:
            return
        h, c = self._charge_of(req)
        self._charges[req.rid] = (h, c)
        self._charged_hot += h
        self._charged_cold += c

    def _charge_drop(self, req: Request):
        """Park: the resident's bytes leave the live sums (entry kept
        for the symmetric re-add on restore)."""
        if self.charge_fn is None or req.rid not in self._charges:
            return
        h, c = self._charges[req.rid]
        self._charged_hot -= h
        self._charged_cold -= c

    def _charge_readd(self, req: Request):
        if self.charge_fn is None or req.rid not in self._charges:
            return
        h, c = self._charges[req.rid]
        self._charged_hot += h
        self._charged_cold += c

    def release(self, req: Request):
        """Retire a finished request's byte charge (engine calls this
        when the request leaves its slot for good). No-op in slot mode
        and for rids never charged."""
        if req.rid in self._charges:
            h, c = self._charges.pop(req.rid)
            self._charged_hot -= h
            self._charged_cold -= c

    def _admits(self, n_active: int, spilled_after: int,
                cand: Request | None = None,
                parked: Request | None = None) -> bool:
        """Byte/lane gate for one more resident, with ``spilled_after``
        requests (still) parked in the spill store."""
        return self._deny_reason(n_active, spilled_after, cand=cand,
                                 parked=parked) is None

    def _deny_reason(self, n_active: int, spilled_after: int,
                     cand: Request | None = None,
                     parked: Request | None = None) -> str | None:
        """`_admits` with the blocking gate named (None = admissible).

        Slot mode charges ``n_active + 1`` uniform worst cases. Charge
        mode (``charge_fn`` set) sums the live per-resident charges,
        minus ``parked`` (the victim this step would spill), plus the
        actual ``cand`` charge (worst case when the candidate is not
        known, e.g. `can_admit` probes)."""
        lane_b = (self._slot_bytes if self.lane_bytes is None
                  else self.lane_bytes)
        shared = (self.shared_bytes_fn() if self.shared_bytes_fn
                  is not None else 0)
        if self.charge_fn is None:
            return self.budget.deny_reason(
                n_active, self.hot_bytes_per_slot,
                self.cold_bytes_per_slot,
                oversubscribe=self.oversubscribe or 1.0,
                spilled=spilled_after,
                spill_lanes=self.spill_lanes or 0,
                spilled_bytes=spilled_after * lane_b + shared,
                weight_bytes=self.weight_bytes or 0.0)
        hot, cold = self._charged_hot, self._charged_cold
        if parked is not None:
            ph, pc = self._charge_of(parked)
            hot, cold = hot - ph, cold - pc
        ch, cc = self._charge_of(cand)
        return self.budget.deny_reason_bytes(
            hot + ch, cold + cc,
            hot_unit=self.hot_bytes_per_slot,
            oversubscribe=self.oversubscribe or 1.0,
            spilled=spilled_after,
            spill_lanes=self.spill_lanes or 0,
            spilled_bytes=spilled_after * lane_b + shared,
            weight_bytes=self.weight_bytes or 0.0)

    @property
    def max_concurrent(self) -> int:
        return self.budget.max_concurrent(
            self.hot_bytes_per_slot, self.cold_bytes_per_slot,
            weight_bytes=self.weight_bytes or 0.0)

    def can_admit(self, n_active: int) -> bool:
        return bool(self._queue) and self._admits(n_active, self.spilled)

    # ------------------------------------------------------------------
    def plan(self, *, active_slots: int, decode_slots: int,
             free_slots: int, inflight: tuple[Request, int] | None,
             chunk_unit: int = 1, running: tuple = (),
             free_lanes: int = 0) -> StepPlan:
        """Produce this step's work plan.

        ``active_slots`` counts resident requests (decoding + the one
        prefilling, which already pins a slot and its byte budgets);
        ``inflight`` is (request, next position) of the prompt currently
        prefilling, or None. ``chunk_unit`` comes from the backend: every
        non-final chunk is rounded to a multiple of it so recurrent
        architectures keep their canonical chunk grid (exact-length
        chunks; a chunk may overshoot the token budget by less than one
        unit rather than stall). ``running`` is the victim-candidate set
        (requests currently decoding; the in-flight prefill is never
        preempted) and ``free_lanes`` the spill lanes available.

        Planning is a COMMITMENT, not a peek: admissions pop the queue,
        evictions/offloads move the victim into the scheduler's spilled
        set, and restores pop it back — the engine executes every entry
        of the returned plan within the same step, in eviction ->
        offload -> restore -> chunk -> decode order."""
        evictions: list[Request] = []
        offloads: list[Request] = []
        restores: list[Request] = []
        victims = list(running)

        def best_waiter():
            """The best waiter that could take a freed slot this step:
            the spilled head, or the queue head when no prompt is in
            flight — whichever has the higher priority (the spilled head
            wins ties: it restores first). None = nobody is waiting."""
            cand = self._spilled[0] if self._spilled else None
            if self._queue and inflight is None:
                head = self._queue[0]
                if cand is None or head.priority > cand.priority:
                    cand = head
            return cand

        def park(victim, into):
            """Commit one victim to a spill lane: shared bookkeeping of
            phases 1/1b (the one-victim-per-step accounting must never
            diverge between preemption and idle offload)."""
            nonlocal free_lanes, free_slots, active_slots, decode_slots
            into.append(victim)
            victims.remove(victim)
            self._spill_insert(victim)
            self._charge_drop(victim)
            free_lanes -= 1
            free_slots += 1
            active_slots -= 1
            decode_slots -= 1

        # ---- phase 1: preemptive eviction --------------------------------
        # one victim per step: when the best waiter (spilled or queue
        # head) strictly outranks the weakest runner and cannot get in
        # as things stand — no free slot, OR the byte budgets block it —
        # spill the lowest-priority, most-recently-admitted runner.
        # Never evict unless the waiter would actually be admissible
        # with the victim parked (one fewer resident, one more spilled
        # image in RRAM): a useless eviction strands the victim and can
        # livelock the step loop.
        waiter_blocked = free_slots == 0 \
            or not self._admits(active_slots, self.spilled)
        if waiter_blocked and free_lanes > 0 and victims:
            waiter = best_waiter()
            if waiter is not None:
                victim = min(victims, key=lambda r: (r.priority,
                                                     -r.admit_seq))
                if victim.priority < waiter.priority \
                        and self._admits(active_slots - 1,
                                         self.spilled + 1,
                                         cand=waiter, parked=victim):
                    park(victim, evictions)
                    self._note("evict_priority", victim,
                               waiter_priority=waiter.priority)

        # ---- phase 1b: proactive idle cold-KV offload --------------------
        # RRAM as a capacity tier: when the waiter STILL cannot get in —
        # nobody strictly outranked anyone, so phase 1 did not fire —
        # any runner that has been resident >= idle_offload_steps decode
        # steps has had its time slice and may be parked for an equal-
        # or higher-priority waiter. Same victim pick, same admissibility
        # guard, same one-victim-per-step discipline as preemption; the
        # parked image restores FCFS once capacity frees, so at equal
        # priority this is RRAM-backed round-robin with quantum N. The
        # freed DRAM hot bytes admit the waiter under the BASE gates —
        # no oversubscribe factor involved.
        if self.idle_offload_steps is not None and not evictions:
            blocked = free_slots == 0 \
                or not self._admits(active_slots, self.spilled)
            if blocked and free_lanes > 0 and victims:
                waiter = best_waiter()
                if waiter is not None:
                    waiter_prio = waiter.priority
                    eligible = [
                        r for r in victims
                        if r.resident_steps >= self.idle_offload_steps
                        and r.priority <= waiter_prio]
                    victim = (min(eligible,
                                  key=lambda r: (r.priority,
                                                 -r.admit_seq))
                              if eligible else None)
                    if victim is not None \
                            and self._admits(active_slots - 1,
                                             self.spilled + 1,
                                             cand=waiter,
                                             parked=victim):
                        # the parking must actually BENEFIT a waiter:
                        # either the queue head takes the freed slot
                        # (phase 3), or the spilled head restores into
                        # it (phase 2) — which it only does if it sorts
                        # before the victim in restore order; otherwise
                        # the victim itself would bounce straight back
                        # next step, a useless RRAM round trip that
                        # starves the real waiter.
                        vkey = (-victim.priority, victim.admit_seq)
                        queue_takes = bool(self._queue) \
                            and inflight is None
                        head = self._spilled[0] if self._spilled else None
                        spill_takes = head is not None and \
                            (-head.priority, head.admit_seq) < vkey
                        if queue_takes or spill_takes:
                            park(victim, offloads)
                            self._note("offload_idle", victim,
                                       waiter_priority=waiter_prio)

        # ---- phase 2: restores ------------------------------------------
        # spilled requests resume in (priority, admission) order, but
        # yield free slots to a strictly higher-priority queue head that
        # can actually take them (it would otherwise evict them right
        # back — thrash). A head that outranks but is byte-blocked does
        # NOT hold the slot hostage: the restore proceeds, or the step
        # loop would never drain.
        while self._spilled and free_slots > 0:
            cand = self._spilled[0]
            if any(cand is e for e in evictions) \
                    or any(cand is o for o in offloads):
                break                     # never round-trip within a step
            if self._queue and inflight is None \
                    and self._queue[0].priority > cand.priority \
                    and self._admits(active_slots, self.spilled,
                                     cand=self._queue[0]):
                self._note("restore_yield", cand,
                           to_rid=self._queue[0].rid)
                break
            reason = self._deny_reason(active_slots, self.spilled - 1,
                                       cand=cand)
            if reason is not None:
                self._note("deny_restore_" + reason, cand)
                break
            restores.append(self._spilled.pop(0))
            self._charge_readd(restores[-1])
            self._note("restore", restores[-1])
            free_slots -= 1
            active_slots += 1
            decode_slots += 1             # a restored slot decodes now

        # ---- phase 3: admission + prefill chunks ------------------------
        chunks: list[PrefillChunk] = []
        budget = (float("inf") if self.token_budget is None
                  else self.token_budget - decode_slots)
        cap = self.chunk_tokens or float("inf")
        cur = inflight
        while budget > 0:
            admit = False
            if cur is None:
                if not self._queue:
                    break
                if free_slots <= 0:
                    self._note("deny_no_free_slot", self._queue[0])
                    break
                head = self._queue[0]
                # probe the prefix cache BEFORE the byte gate: the hit
                # shrinks the head's charge (charge_fn reads the same
                # probe result), and the admitted prefill starts at the
                # hit position — only the tail charges the token budget
                hit = (int(self.prefix_probe(head))
                       if self.prefix_probe is not None else 0)
                reason = self._deny_reason(active_slots, self.spilled,
                                           cand=head)
                if reason is not None:
                    self._note("deny_" + reason, head)
                    break
                req = self._queue.popleft()
                admit = True
                free_slots -= 1
                active_slots += 1
                self.admitted += 1
                req.admit_seq = self._seq
                self._seq += 1
                self._charge_admit(req)
                if hit:
                    self._note("admit", req, prefix_hit=hit)
                else:
                    self._note("admit", req)
                cur = (req, hit)
            req, p = cur
            remaining = req.prompt_len - p
            c = int(min(remaining, budget, cap))
            if c < remaining and chunk_unit > 1:
                c = (c // chunk_unit) * chunk_unit or min(chunk_unit,
                                                          remaining)
            commit = (p + c) == req.prompt_len
            chunks.append(PrefillChunk(req, admit, p, c, commit))
            budget -= c
            cur = None if commit else (req, p + c)
        if self._queue and cur is None and free_slots > 0 \
                and budget <= 0:
            self._note("deny_token_budget", self._queue[0])
        return StepPlan(chunks=tuple(chunks),
                        decode=decode_slots > 0
                        or any(c.commit for c in chunks),
                        evictions=tuple(evictions),
                        restores=tuple(restores),
                        offloads=tuple(offloads))

    def _spill_insert(self, req: Request):
        """Park an evicted request, keeping the spilled set in
        (priority desc, admission asc) restore order."""
        key = (-req.priority, req.admit_seq)
        for i, q in enumerate(self._spilled):
            if (-q.priority, q.admit_seq) > key:
                self._spilled.insert(i, req)
                return
        self._spilled.append(req)
