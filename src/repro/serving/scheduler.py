"""Step-plan scheduling: FCFS + capacity-gated admission + Sarathi-style
chunked prefill under a per-step token budget.

CHIME's two memory domains cap concurrency independently: every admitted
request pins a bf16 hot ring (+ recurrent states) in the M3D DRAM stack
and an int8 cold prefix (+ scales) in the write-once RRAM tier. The
scheduler derives byte budgets from the `simulator/hardware.py` domain
capacities and admits the queue head only while BOTH domains have room —
so a bigger hot window or longer max_len genuinely buys fewer concurrent
requests, the same trade the paper's Table III/IV capacities impose.

Since PR 3 the scheduler emits a `StepPlan` per engine step instead of
popping whole requests: each step gets ``token_budget`` tokens, decode
slots take one each, and the remainder goes to in-flight prefill chunks
of at most ``chunk_tokens`` positions (the paper's long-vision-prompt
workloads no longer stall every decode slot for a whole prompt). Ordering
stays strictly FCFS — one prompt prefills at a time, and the queue head
is admitted (slot + byte budgets permitting) only once the previous
prompt committed. Defaults (no budget, no chunk cap) reproduce the PR 1/2
admit-whole-prompt behavior exactly.
"""

from __future__ import annotations

import collections
import dataclasses
import warnings

from repro.serving.request import Request
from repro.simulator.hardware import CHIME, Platform


@dataclasses.dataclass(frozen=True)
class CapacityBudget:
    """KV byte budgets per memory domain."""
    dram_bytes: float
    rram_bytes: float

    @classmethod
    def from_platform(cls, platform: Platform = CHIME,
                      kv_fraction: float = 0.5) -> "CapacityBudget":
        """Reserve ``kv_fraction`` of each domain for KV state (the rest
        holds weights and activations; the paper keeps FFN weights
        resident in RRAM and attention weights in DRAM)."""
        dram = platform.domains["dram"].capacity_bytes * kv_fraction
        rram_dom = platform.domains.get("rram", platform.domains["dram"])
        rram = rram_dom.capacity_bytes * kv_fraction
        return cls(dram, rram)

    def max_concurrent(self, hot_bytes_per_slot: int,
                       cold_bytes_per_slot: int) -> int:
        """Largest slot count both domains can hold simultaneously."""
        lim = float("inf")
        if hot_bytes_per_slot > 0:
            lim = min(lim, self.dram_bytes // hot_bytes_per_slot)
        if cold_bytes_per_slot > 0:
            lim = min(lim, self.rram_bytes // cold_bytes_per_slot)
        return int(lim) if lim != float("inf") else 2 ** 30

    def admits(self, n_resident: int, hot_bytes_per_slot: int,
               cold_bytes_per_slot: int) -> bool:
        """Can an (n_resident+1)-th request's KV state fit?"""
        return ((n_resident + 1) * hot_bytes_per_slot <= self.dram_bytes
                and (n_resident + 1) * cold_bytes_per_slot
                <= self.rram_bytes)


@dataclasses.dataclass(frozen=True)
class PrefillChunk:
    """One planned extend call: ``length`` prompt positions of ``req``
    starting at absolute position ``start``. ``admit`` means the request
    enters prefill with this chunk (the engine allocates its pool slot
    first); ``commit`` means the chunk completes the prompt (the backend
    folds the workspace into the slot and the first token streams)."""
    req: Request
    admit: bool
    start: int
    length: int
    commit: bool


@dataclasses.dataclass(frozen=True)
class StepPlan:
    """The work one engine step executes: prefill chunks (in FCFS order,
    at most one request in flight at a time) followed by one decode token
    on every active slot. ``decode`` is True when the step is expected to
    decode — slots were already active, or a committing chunk activates
    one this step."""
    chunks: tuple[PrefillChunk, ...]
    decode: bool

    @property
    def prefill_tokens(self) -> int:
        return sum(c.length for c in self.chunks)


class FCFSScheduler:
    """First-come-first-served StepPlan producer gated by the capacity
    budget and a per-step token budget.

    Strictly FCFS: if the head of the queue does not fit, nothing is
    admitted (no starvation of large requests by small ones), and a new
    prompt starts prefilling only after the in-flight one commits.

    ``token_budget`` caps the total tokens one step computes (each active
    decode slot costs 1; the remainder feeds prefill chunks).
    ``chunk_tokens`` caps a single prefill chunk. Both default to None
    (unbounded / whole-prompt chunks — the pre-StepPlan behavior).
    """

    def __init__(self, budget: CapacityBudget, hot_bytes_per_slot: int,
                 cold_bytes_per_slot: int,
                 token_budget: int | None = None,
                 chunk_tokens: int | None = None):
        if chunk_tokens is not None and chunk_tokens < 1:
            # a cap < 1 would make plan() emit degenerate chunks forever
            raise ValueError(f"chunk_tokens must be >= 1 or None, got "
                             f"{chunk_tokens}")
        if token_budget is not None and token_budget < 1:
            raise ValueError(f"token_budget must be >= 1 or None, got "
                             f"{token_budget}")
        self.budget = budget
        self.hot_bytes_per_slot = hot_bytes_per_slot
        self.cold_bytes_per_slot = cold_bytes_per_slot
        self.token_budget = token_budget
        self.chunk_tokens = chunk_tokens
        self._queue: collections.deque[Request] = collections.deque()
        self.admitted = 0

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self._queue.append(req)

    @property
    def pending(self) -> int:
        return len(self._queue)

    @property
    def max_concurrent(self) -> int:
        return self.budget.max_concurrent(self.hot_bytes_per_slot,
                                          self.cold_bytes_per_slot)

    def can_admit(self, n_active: int) -> bool:
        return bool(self._queue) and self.budget.admits(
            n_active, self.hot_bytes_per_slot, self.cold_bytes_per_slot)

    # ------------------------------------------------------------------
    def plan(self, *, active_slots: int, decode_slots: int,
             free_slots: int, inflight: tuple[Request, int] | None,
             chunk_unit: int = 1) -> StepPlan:
        """Produce this step's work plan.

        ``active_slots`` counts resident requests (decoding + the one
        prefilling, which already pins a slot and its byte budgets);
        ``inflight`` is (request, next position) of the prompt currently
        prefilling, or None. ``chunk_unit`` comes from the backend: every
        non-final chunk is rounded to a multiple of it so recurrent
        architectures keep their canonical chunk grid (exact-length
        chunks; a chunk may overshoot the token budget by less than one
        unit rather than stall).

        Planning is a COMMITMENT, not a peek: admissions pop the queue
        and count toward ``admitted``, and the engine executes every
        chunk of the returned plan within the same step."""
        chunks: list[PrefillChunk] = []
        budget = (float("inf") if self.token_budget is None
                  else self.token_budget - decode_slots)
        cap = self.chunk_tokens or float("inf")
        cur = inflight
        while budget > 0:
            admit = False
            if cur is None:
                if not self._queue or free_slots <= 0:
                    break
                if not self.budget.admits(active_slots,
                                          self.hot_bytes_per_slot,
                                          self.cold_bytes_per_slot):
                    break
                req = self._queue.popleft()
                admit = True
                free_slots -= 1
                active_slots += 1
                self.admitted += 1
                cur = (req, 0)
            req, p = cur
            remaining = req.prompt_len - p
            c = int(min(remaining, budget, cap))
            if c < remaining and chunk_unit > 1:
                c = (c // chunk_unit) * chunk_unit or min(chunk_unit,
                                                          remaining)
            commit = (p + c) == req.prompt_len
            chunks.append(PrefillChunk(req, admit, p, c, commit))
            budget -= c
            cur = None if commit else (req, p + c)
        return StepPlan(chunks=tuple(chunks),
                        decode=decode_slots > 0
                        or any(c.commit for c in chunks))

    # ---- one-release deprecation shim (PR 3) -------------------------
    def next_request(self, n_active: int) -> Request | None:
        """DEPRECATED: pop the queue head iff both domain budgets admit
        one more resident request. Superseded by `plan`, which chunks the
        head prompt under the step token budget instead of handing it out
        whole."""
        warnings.warn(
            "FCFSScheduler.next_request is deprecated; the engine now "
            "drives StepPlans from FCFSScheduler.plan()",
            DeprecationWarning, stacklevel=2)
        if not self.can_admit(n_active):
            return None
        self.admitted += 1
        return self._queue.popleft()
