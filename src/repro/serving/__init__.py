"""Continuous-batching serving engine over a multi-request tiered KV pool.

CHIME's decode economics (paper §III-C) come from keeping the memory
hierarchy full: the DRAM chiplet streams a hot bf16 window per sequence
while the write-once RRAM tier holds the int8 cold prefix. One request at a
time leaves both domains idle most of the step. This package turns the
single-request `launch/serve.py` path into a serving engine:

* `request.py`   — request/timing dataclasses and the FCFS stream
* `kv_pool.py`   — slot-indexed multi-request extension of core/kv_tiers
* `scheduler.py` — FCFS + capacity-aware admission against the DRAM/RRAM
                   byte budgets of simulator/hardware.py
* `engine.py`    — interleaved prefill/decode step loop (one jitted decode
                   over all slots; static shapes so jit compiles once)
* `metrics.py`   — per-request latency + aggregate tok/s + simulated
                   tokens/J via simulator/chime_sim.py cost terms
"""

from repro.serving.engine import Engine
from repro.serving.kv_pool import TieredKVPool, slot_kv_bytes
from repro.serving.metrics import aggregate_metrics, simulated_efficiency
from repro.serving.request import Request, make_synthetic_requests
from repro.serving.scheduler import CapacityBudget, FCFSScheduler

__all__ = [
    "Engine", "TieredKVPool", "slot_kv_bytes", "aggregate_metrics",
    "simulated_efficiency", "Request", "make_synthetic_requests",
    "CapacityBudget", "FCFSScheduler",
]
