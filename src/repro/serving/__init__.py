"""Continuous-batching serving engine over a multi-request tiered KV pool.

CHIME's decode economics (paper §III-C) come from keeping the memory
hierarchy full: the DRAM chiplet streams a hot bf16 window per sequence
while the write-once RRAM tier holds the int8 cold prefix. One request at a
time leaves both domains idle most of the step. This package turns the
single-request `launch/serve.py` path into a serving engine:

* `request.py`   — request/timing dataclasses and the FCFS stream
* `block_pool.py`— host-side paged prefix sharing: `ENDURANCE_BLOCK`-
                   granular block chains keyed by content hash (token
                   ids + image patch digests), free-list + per-block
                   refcounts + LRU eviction, copy-on-write on first
                   divergence inside a shared block, and write-once
                   endurance bookkeeping for N-way-shared blocks
* `kv_pool.py`   — model-free slot pool: `KVPoolState` (explicit typed
                   pytree) + host-side slot bookkeeping + endurance audit
* `scheduler.py` — `StepPlan` production: priority classes (FCFS within
                   a class) + capacity-aware admission against the
                   DRAM/RRAM byte budgets of simulator/hardware.py +
                   Sarathi-style chunked prefill under a per-step token
                   budget + preemptive eviction/restore planning under
                   spill-lane-backed oversubscription + proactive idle
                   cold-KV offload (RRAM as a first-class capacity tier;
                   opt-in int8-compressed lanes shrink the per-image
                   RRAM charge)
* `backend.py`   — the `InferenceBackend` executor seam: the unified
                   jitted `extend_step` (chunked prefill directly into a
                   pool slot) + `decode_step`; `LocalBackend`
                   (single-host vmapped decode) and `ShardedBackend`
                   (pjit over a launch/mesh.py mesh; params sharded by
                   the model's rules, KV pool slots over 'data', cold
                   kv_seq/heads over 'model')
* `engine.py`    — StepPlan executor over a backend: spill evictions
                   (a victim slot's KV packs verbatim into an RRAM
                   lane), bit-exact restores, prefill chunks, then one
                   jitted decode over all slots (static shapes so the
                   backend compiles once per chunk shape)
* `metrics.py`   — per-request latency + TTFT/TBT percentiles +
                   aggregate tok/s + simulated tokens/J via
                   simulator/chime_sim.py cost terms
* `telemetry.py` — opt-in observability hub: step-span tracer
                   (Chrome-trace/Perfetto export, one lane per KV
                   slot/RRAM lane/request), simulated tier-traffic
                   ledger that reconciles bit-for-bit with
                   `simulated_efficiency`, scheduler decision log and
                   Prometheus text exposition
"""

from repro.serving.backend import (InferenceBackend, LocalBackend,
                                   ShardedBackend, make_backend)
from repro.serving.block_pool import (BlockPool, PrefixHit,
                                      request_prefix_keys)
from repro.serving.engine import Engine
from repro.serving.kv_pool import (KVPoolState, TieredKVPool,
                                   slot_kv_bytes, spill_lane_bytes)
from repro.serving.metrics import (aggregate_metrics, request_metrics,
                                   simulated_efficiency)
from repro.serving.request import Request, make_synthetic_requests
from repro.serving.scheduler import (CapacityBudget, FCFSScheduler,
                                     PrefillChunk, StepPlan)
from repro.serving.telemetry import (REASON_CODES, NullTelemetry,
                                     Telemetry, TierLedger,
                                     parse_prometheus,
                                     validate_chrome_trace)

__all__ = [
    "BlockPool", "PrefixHit", "request_prefix_keys",
    "Engine", "InferenceBackend", "KVPoolState", "LocalBackend",
    "PrefillChunk", "ShardedBackend", "StepPlan", "TieredKVPool",
    "aggregate_metrics", "make_backend", "make_synthetic_requests",
    "request_metrics", "simulated_efficiency", "slot_kv_bytes",
    "spill_lane_bytes", "Request", "CapacityBudget", "FCFSScheduler",
    "Telemetry", "NullTelemetry", "TierLedger", "REASON_CODES",
    "parse_prometheus", "validate_chrome_trace",
]
