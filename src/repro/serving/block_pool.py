"""Paged prefix-sharing KV block pool: host-side block tables, a
content-hash prefix index, and copy-on-write bookkeeping.

CHIME's motivating workload — many concurrent VQA requests carrying the
same system prompt / few-shot header / image — pays the full prefill
(and full per-request KV bytes) for a prefix that is byte-identical
across requests. This module is the vLLM/SGLang-shaped answer scaled to
the tiered edge pool: the KV *prefix* space is carved into
``block_tokens``-granular pages (default `core.kv_tiers.ENDURANCE_BLOCK`
— the same granularity the RRAM endurance counters already use), and a
host-side `BlockPool` maintains

  * a free list + LRU reclamation over ``num_blocks`` physical block
    ids,
  * a radix-style prefix tree keyed on content (token ids; image
    patches by per-row digest) mapping prefixes -> chains of blocks,
  * per-block reference counts (a block referenced by an in-flight
    admission is never reclaimed) and write counters (a shared block is
    physically written ONCE regardless of how many requests later
    reference it — the write-once/read-many discipline that makes
    shared prefixes the ideal tenants of the dense RRAM tier).

The actual KV payload lives in the backend's prefix block store (see
`serving.backend`): full-precision *workspace-form* K/V rows per block
(exactly what `Model.extend` accumulates during chunked prefill), plus
recurrent-state snapshots for SSM architectures. Storing workspace rows
— not the quantized store form — is what makes a prefix hit *exact*:
admission seeds the hit rows into a fresh extend workspace and prefill
resumes at the hit position, so the committed cache is bit-identical to
a cold prefill by the same split-invariance the chunked-prefill parity
tests already establish.

Copy-on-write: a request whose keys diverge *inside* a shared block
still hits the longest common prefix (the matched rows seed the
workspace; the tail recomputes), and at registration the diverging span
is written to a FRESH block — the shared block is never mutated. The
prefix tree therefore only ever grows by appending children; eviction
removes unreferenced leaves in LRU order.

Everything here is host-side bookkeeping (pure Python + numpy); the
jitted block copies live in `serving.backend`.
"""

from __future__ import annotations

import hashlib
from typing import NamedTuple, Optional

import numpy as np

from repro.core.kv_tiers import ENDURANCE_BLOCK

__all__ = ["ENDURANCE_BLOCK", "BlockNode", "BlockPool", "PrefixHit",
           "request_prefix_keys"]


def request_prefix_keys(req) -> tuple:
    """Content keys of a request's prompt, one per backbone position.

    Text positions key on the token id; visual positions key on a
    per-patch-row sha1 digest of the raw float32 bytes (two requests
    share a visual prefix only if the patch rows are bit-identical —
    the only safe notion of "same image" for exact KV reuse). The tuple
    is cached on the request: admission probes and registration reuse
    it without re-hashing the image."""
    keys = getattr(req, "_prefix_keys", None)
    if keys is not None:
        return keys
    parts: list = []
    if req.patches is not None:
        rows = np.ascontiguousarray(np.asarray(req.patches, np.float32))
        parts.extend(hashlib.sha1(row.tobytes()).digest() for row in rows)
    parts.extend(int(t) for t in np.asarray(req.tokens).reshape(-1))
    keys = tuple(parts)
    try:
        req._prefix_keys = keys
    except AttributeError:
        pass                                  # __slots__ request: no cache
    return keys


def _lcp(a: tuple, b: tuple) -> int:
    n = min(len(a), len(b))
    for i in range(n):
        if a[i] != b[i]:
            return i
    return n


class BlockNode:
    """One block of the prefix tree: physical block ``bid`` holds the KV
    workspace rows for prompt positions [start, start + len(keys)).

    ``full`` nodes cover exactly ``block_tokens`` positions and may have
    children (the chain continues); partial nodes are terminal tails of
    a registered prompt (they enable exact-prompt-repeat hits) and never
    grow children. ``has_state`` marks a recurrent-state snapshot for
    the prefix ending at this node (SSM architectures can only resume
    from such a node). ``refcount`` counts in-flight admissions holding
    this block; ``pin_epoch`` protects nodes a same-step probe returned
    from same-step reclamation."""

    __slots__ = ("bid", "start", "keys", "parent", "full", "refcount",
                 "has_state", "tick", "pin_epoch", "children", "partials")

    def __init__(self, bid: int, start: int, keys: tuple,
                 parent: Optional["BlockNode"], full: bool):
        self.bid = bid
        self.start = start
        self.keys = keys
        self.parent = parent
        self.full = full
        self.refcount = 0
        self.has_state = False
        self.tick = 0
        self.pin_epoch = -1
        self.children: dict[tuple, BlockNode] = {}
        self.partials: list[BlockNode] = []

    @property
    def end(self) -> int:
        return self.start + len(self.keys)

    def __repr__(self):                        # pragma: no cover - debug
        return (f"BlockNode(bid={self.bid}, [{self.start},{self.end}), "
                f"full={self.full}, rc={self.refcount}, "
                f"state={self.has_state})")


class PrefixHit(NamedTuple):
    """A successful prefix probe: ``nodes`` is the root-to-deepest block
    chain whose stored rows seed the admission workspace, ``length`` the
    usable hit positions (prefill resumes there), and ``partial`` True
    when the request diverges strictly INSIDE the deepest block — the
    copy-on-write case (its tail recomputes and registers to a fresh
    block; the shared block is untouched)."""
    nodes: tuple
    length: int
    partial: bool


_EMPTY_HIT = PrefixHit((), 0, False)


class BlockPool:
    """Host-side paged prefix pool: free list, refcounts, prefix index.

    The pool never touches device arrays — `register`/`lookup` return
    block ids and chain nodes; the engine drives the backend's jitted
    block copies against them. Reclamation (`_alloc_block` with an empty
    free list) evicts the least-recently-used *leaf* whose refcount is
    zero and which was not pinned by a probe or registration this epoch
    — so a chain an admission is about to seed from can never be pulled
    out from under it within the step."""

    def __init__(self, num_blocks: int, block_tokens: int):
        if num_blocks < 1:
            raise ValueError(f"BlockPool needs num_blocks >= 1, got "
                             f"{num_blocks}")
        if block_tokens < 1:
            raise ValueError(f"BlockPool needs block_tokens >= 1, got "
                             f"{block_tokens}")
        self.num_blocks = num_blocks
        self.block_tokens = block_tokens
        self._root = BlockNode(-1, 0, (), None, True)
        self._free = list(range(num_blocks))
        self._nodes: dict[int, BlockNode] = {}
        self._tick = 0
        self._epoch = 0
        # physical writes per block id: a shared block is written once at
        # registration no matter how many requests later reference it —
        # the RRAM write-once contract, auditable per block
        self.block_writes = np.zeros(num_blocks, np.int64)
        self.stats = {"lookups": 0, "hits": 0, "hit_tokens": 0,
                      "cow_copies": 0, "blocks_registered": 0,
                      "blocks_evicted": 0, "block_writes": 0}

    # ---- views -------------------------------------------------------
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.num_blocks - len(self._free)

    @property
    def pinned_blocks(self) -> int:
        """Blocks referenced by a live admission (refcount > 0). The
        used/pinned gap is reclaimable cache: evictable on demand, so
        capacity gates must not count it as occupied."""
        return sum(1 for n in self._nodes.values() if n.refcount > 0)

    @property
    def max_refcount(self) -> int:
        return max((n.refcount for n in self._nodes.values()), default=0)

    @property
    def total_refcount(self) -> int:
        return sum(n.refcount for n in self._nodes.values())

    def begin_epoch(self):
        """Start a new engine step: pins from previous steps expire."""
        self._epoch += 1

    def _touch(self, node: BlockNode):
        self._tick += 1
        node.tick = self._tick
        node.pin_epoch = self._epoch

    # ---- probe -------------------------------------------------------
    def lookup(self, keys: tuple, *, max_hit: int,
               require_state: bool = False, grid: int = 1) -> PrefixHit:
        """Longest usable cached prefix of ``keys``.

        ``max_hit`` caps the hit length (the engine passes
        ``prompt_len - 1``: at least one position must run through the
        model to produce the first-token logits). ``require_state``
        (recurrent architectures) restricts hits to nodes carrying a
        state snapshot whose end lands on the canonical ``grid``
        (`backend.chunk_unit`) — the only resume points that keep
        chunked prefill bit-identical to a whole-prompt run. Matched
        nodes are pinned for the current epoch (not refcounted — denied
        admissions must not leak references; `acquire` runs only when
        the admission chunk actually executes)."""
        self.stats["lookups"] += 1
        bt = self.block_tokens
        cur, nodes, pos = self._root, [], 0
        while pos + bt <= max_hit:
            child = cur.children.get(tuple(keys[pos:pos + bt]))
            if child is None:
                break
            nodes.append(child)
            cur = child
            pos += bt
        partial = False
        if require_state:
            # only a node.end with a snapshot ON the chunk grid can
            # resume an SSM prefill; a stored exact-tail partial node
            # (same prompt resubmitted) extends the chain when eligible
            best = None
            for cand in cur.partials:
                e = pos + len(cand.keys)
                if (cand.has_state and e <= max_hit and e % grid == 0
                        and tuple(keys[pos:e]) == cand.keys):
                    if best is None or e > best.end:
                        best = cand
            if best is not None:
                nodes.append(best)
                pos = best.end
            else:
                while nodes and not (nodes[-1].has_state
                                     and nodes[-1].end % grid == 0):
                    pos = nodes[-1].start
                    nodes.pop()
        else:
            # divergence INSIDE the next block still hits the longest
            # common prefix of a stored block (full child or partial
            # tail) — the rows [start, start+j) seed the workspace and
            # the tail recomputes (copy-on-write at registration)
            limit = min(max_hit - pos, bt)
            tail = tuple(keys[pos:pos + bt])
            best, best_j = None, 0
            for cand in list(cur.children.values()) + cur.partials:
                j = min(_lcp(cand.keys, tail), limit)
                if j > best_j:
                    best, best_j = cand, j
            if best is not None and best_j > 0:
                nodes.append(best)
                pos += best_j
                partial = best_j < len(best.keys)
        if pos == 0:
            return _EMPTY_HIT
        for n in nodes:
            self._touch(n)
        self.stats["hits"] += 1
        self.stats["hit_tokens"] += pos
        return PrefixHit(tuple(nodes), pos, partial)

    # ---- reference counting ------------------------------------------
    def acquire(self, hit: PrefixHit):
        """Pin a hit chain for the admit -> commit window: every node a
        seeding admission reads gains a reference, so reclamation can
        never free a block an in-flight prefill depends on."""
        for n in hit.nodes:
            n.refcount += 1

    def release(self, hit: PrefixHit):
        for n in hit.nodes:
            if n.refcount <= 0:
                raise AssertionError(
                    f"double release of block {n.bid} (refcount "
                    f"{n.refcount})")
            n.refcount -= 1

    # ---- registration -------------------------------------------------
    def register(self, keys: tuple, *, max_start: int
                 ) -> tuple[list[BlockNode], Optional[BlockNode]]:
        """Index a freshly prefilled prompt's prefix, deduplicating
        against everything already stored.

        Re-walks the tree from the root: existing full blocks are
        reused untouched (NO new physical write — this is the shared-
        block write-once contract), missing full blocks and the final
        partial tail allocate fresh block ids. Returns
        ``(new_nodes, terminal)``: the caller must physically write each
        new node's workspace rows into its block, and ``terminal`` is
        the node whose ``end`` equals ``len(keys)`` (the state-snapshot
        attach point for recurrent architectures) — None when the pool
        ran out of blocks mid-chain or the tail was not storable.

        ``max_start``: a block's rows are copied with a fixed
        ``block_tokens``-wide slice, so only start positions
        ``<= max_start`` (i.e. ``max_len - block_tokens``) are storable
        without the slice clamping out of the workspace."""
        bt = self.block_tokens
        cur, pos = self._root, 0
        new: list[BlockNode] = []
        while pos + bt <= len(keys):
            seg = tuple(keys[pos:pos + bt])
            child = cur.children.get(seg)
            if child is None:
                if pos > max_start:
                    return new, None
                bid = self._alloc_block()
                if bid is None:
                    return new, None          # pool exhausted: partial index
                child = BlockNode(bid, pos, seg, cur, True)
                cur.children[seg] = child
                self._nodes[bid] = child
                new.append(child)
                self.stats["blocks_registered"] += 1
            self._touch(child)
            cur = child
            pos += bt
        if pos == len(keys):
            return new, (cur if cur is not self._root else None)
        seg = tuple(keys[pos:])
        for cand in cur.partials:
            if cand.keys == seg:              # exact-tail dedup: repeated
                self._touch(cand)             # identical prompts write once
                return new, cand
        if pos > max_start:
            return new, None
        bid = self._alloc_block()
        if bid is None:
            return new, None
        node = BlockNode(bid, pos, seg, cur, False)
        cur.partials.append(node)
        self._nodes[bid] = node
        new.append(node)
        self.stats["blocks_registered"] += 1
        self._touch(node)
        return new, node

    def note_write(self, bid: int):
        """Record one physical write to block ``bid`` (workspace rows or
        a state snapshot) — the endurance ledger shared blocks are
        audited against."""
        self.block_writes[bid] += 1
        self.stats["block_writes"] += 1

    # ---- reclamation --------------------------------------------------
    def _alloc_block(self) -> Optional[int]:
        if self._free:
            return self._free.pop()
        victim = None
        for node in self._nodes.values():
            if (node.refcount == 0 and not node.children
                    and not node.partials
                    and node.pin_epoch != self._epoch):
                if victim is None or node.tick < victim.tick:
                    victim = node
        if victim is None:
            return None
        self._evict_node(victim)
        return self._free.pop()

    def _evict_node(self, node: BlockNode):
        assert node.refcount == 0 and not node.children \
            and not node.partials
        if node.full:
            del node.parent.children[node.keys]
        else:
            node.parent.partials.remove(node)
        del self._nodes[node.bid]
        self._free.append(node.bid)
        self.stats["blocks_evicted"] += 1

    # ---- invariants (hypothesis harness hooks) ------------------------
    def check_invariants(self):
        """Structural audit: block-id conservation, linkage, refcounts.
        Raises AssertionError on violation (the property-test oracle)."""
        live = set(self._nodes)
        free = set(self._free)
        assert len(self._free) == len(free), "duplicate free block ids"
        assert not (live & free), f"block ids both live and free: " \
            f"{sorted(live & free)}"
        assert live | free == set(range(self.num_blocks)), \
            "block ids leaked or invented"
        seen: set[int] = set()
        stack = [self._root]
        while stack:
            node = stack.pop()
            for seg, child in node.children.items():
                assert node.full, "partial node grew children"
                assert child.parent is node and child.keys == seg
                assert child.full and len(child.keys) == self.block_tokens
                assert child.start == node.end
                assert child.refcount >= 0
                assert child.bid in live and child.bid not in seen
                seen.add(child.bid)
                stack.append(child)
            for child in node.partials:
                assert node.full, "partial node grew partials"
                assert child.parent is node and not child.full
                assert 0 < len(child.keys) < self.block_tokens
                assert child.start == node.end
                assert child.refcount >= 0
                assert child.bid in live and child.bid not in seen
                seen.add(child.bid)
        assert seen == live, "unreachable live blocks (tree/table drift)"
