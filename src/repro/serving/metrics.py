"""Serving metrics: measured latency/throughput + simulated efficiency.

Wall-clock numbers (TTFT, per-request latency, aggregate tok/s) come from
the engine's clock. Energy cannot be measured on a host CPU, so
tokens/J is *simulated*: each finished request's (prompt, step-count)
trace is fed through the CHIME analytical simulator's per-kernel cost
terms (`simulator/chime_sim.py`) on the target platform — the same
instrument the paper-claims tests validate.

Partial metrics: a request that never emitted a token has no TTFT and a
request that never finished has no latency — those keys are simply
absent rather than computed from the dataclass' 0.0 defaults (which
yielded negative garbage). Evictions whose restore never happened are
excluded from restore-latency pairing and surfaced as
``unrestored_evictions``.
"""

from __future__ import annotations

import numpy as np

from repro.simulator.chime_sim import (cost_layers, request_terms,
                                       spill_terms, sum_terms)
from repro.simulator.hardware import CHIME, Platform


def _restore_latencies(req) -> np.ndarray:
    """Per-preemption spilled time: paired (restore - evict) gaps. An
    eviction whose restore has not happened yet is excluded."""
    n = min(len(req.evict_times), len(req.restore_times))
    return (np.asarray(req.restore_times[:n])
            - np.asarray(req.evict_times[:n]))


def request_metrics(req) -> dict:
    """Per-request metrics; partial for in-flight/never-run requests.

    ``ttft_s`` only exists once a first token was emitted, ``latency_s``
    only once the request finished — `Request` defaults both stamps to
    0.0, so subtracting a real arrival time from them is meaningless."""
    m = {
        "rid": req.rid,
        "prompt_len": req.prompt_len,
        "n_generated": req.n_generated,
        "finished": req.finish_s > 0.0,
        "priority": req.priority,
        "spills": req.n_evictions,
        "preemptions": req.n_preemptions,
        "idle_offloads": req.n_idle_offloads,
    }
    if req.prefix_hit > 0:
        m["prefix_hit_tokens"] = int(req.prefix_hit)
    if req.admit_s > 0.0:
        m["queue_s"] = req.admit_s - req.arrival_s
    if req.first_token_s > 0.0:
        m["ttft_s"] = req.first_token_s - req.arrival_s
    if req.finish_s > 0.0:
        m["latency_s"] = req.finish_s - req.arrival_s
    spilled = _restore_latencies(req)
    if spilled.size:
        m["spilled_s"] = float(spilled.sum())
    unrestored = len(req.evict_times) - len(req.restore_times)
    if unrestored > 0:
        m["unrestored_evictions"] = unrestored
    tbt = np.diff(req.token_times)
    if tbt.size:
        m["tbt_p50_s"] = float(np.percentile(tbt, 50))
        m["tbt_p95_s"] = float(np.percentile(tbt, 95))
        m["tbt_max_s"] = float(tbt.max())
    return m


def aggregate_metrics(finished, wall_s: float) -> dict:
    """Aggregate over finished requests for a run of ``wall_s`` seconds.

    TTFT percentiles are over requests; time-between-tokens (TBT)
    percentiles pool every request's inter-token gaps — the tail that
    chunked prefill exists to bound (a whole-prompt prefill stalls every
    in-flight request's next token for the full prompt duration).

    Tolerates a mixed population: requests that never emitted a token
    (zero-generation admissions, drained queues) are excluded from the
    TTFT pool, unfinished requests from the latency pool, and the counts
    of both exclusions are reported instead of poisoning the
    percentiles with zero-based garbage."""
    if not finished:
        return {"requests": 0, "total_tokens": 0, "tok_per_s": 0.0}
    total = int(sum(r.n_generated for r in finished))
    m = {
        "requests": len(finished),
        "total_tokens": total,
        "tok_per_s": total / max(wall_s, 1e-9),
    }
    ttft = np.array([r.first_token_s - r.arrival_s for r in finished
                     if r.first_token_s > 0.0])
    if ttft.size:
        m["mean_ttft_s"] = float(ttft.mean())
        m["ttft_p50_s"] = float(np.percentile(ttft, 50))
        m["ttft_p95_s"] = float(np.percentile(ttft, 95))
    lat = np.array([r.finish_s - r.arrival_s for r in finished
                    if r.finish_s > 0.0])
    if lat.size:
        m["mean_latency_s"] = float(lat.mean())
        m["p95_latency_s"] = float(np.percentile(lat, 95))
    m["no_token_requests"] = int(
        sum(1 for r in finished if r.first_token_s <= 0.0))
    m["unfinished_requests"] = int(
        sum(1 for r in finished if r.finish_s <= 0.0))
    tbt = np.concatenate(
        [np.diff(r.token_times) for r in finished] or [np.zeros(0)])
    if tbt.size:
        m["tbt_p50_s"] = float(np.percentile(tbt, 50))
        m["tbt_p95_s"] = float(np.percentile(tbt, 95))
        m["tbt_max_s"] = float(tbt.max())
    # spills: how often requests were parked in RRAM (split into
    # priority preemptions vs capacity-driven idle offloads) and how
    # long they sat there before their restore
    m["spills"] = int(sum(r.n_evictions for r in finished))
    m["preemptions"] = int(sum(r.n_preemptions for r in finished))
    m["idle_offloads"] = int(sum(r.n_idle_offloads for r in finished))
    m["restores"] = int(sum(len(r.restore_times) for r in finished))
    m["unrestored_evictions"] = int(
        sum(max(len(r.evict_times) - len(r.restore_times), 0)
            for r in finished))
    rl = np.concatenate([_restore_latencies(r) for r in finished]
                        or [np.zeros(0)])
    if rl.size:
        m["restore_latency_p50_s"] = float(np.percentile(rl, 50))
        m["restore_latency_p95_s"] = float(np.percentile(rl, 95))
    # prefix cache: how many admissions skipped prefill work, how many
    # prompt positions they adopted, and the hit rate over the stream
    hits = [r for r in finished if r.prefix_hit > 0]
    m["prefix_hits"] = len(hits)
    m["prefix_hit_tokens"] = int(sum(r.prefix_hit for r in hits))
    m["prefix_hit_rate"] = len(hits) / len(finished)
    if hits:
        hit_ttft = np.array([r.first_token_s - r.arrival_s for r in hits
                             if r.first_token_s > 0.0])
        if hit_ttft.size:
            m["prefix_hit_mean_ttft_s"] = float(hit_ttft.mean())
        cold_ttft = np.array(
            [r.first_token_s - r.arrival_s for r in finished
             if r.prefix_hit == 0 and r.first_token_s > 0.0])
        if cold_ttft.size:
            m["cold_mean_ttft_s"] = float(cold_ttft.mean())
    return m


def simulated_efficiency(cfg, finished, platform: Platform = CHIME,
                         spill_compressed: bool = False,
                         fused_decode: bool | None = None,
                         sparse_read_tau: float | None = None,
                         weight_stream: bool | None = None) -> dict:
    """Simulated time/energy for the served trace on ``platform``.

    Each request contributes a VQA workload of its own (prompt length,
    generated step count); the per-token attention cost grows with that
    request's context exactly as the engine's tiered reads did.
    Spilled requests (preemption victims and idle cold-KV offloads
    alike) additionally pay the simulated RRAM spill/restore traffic for
    each recorded eviction context (`spill_terms`); ``spill_compressed``
    prices the int8 compressed-lane representation instead of the
    full-precision image (pass the backend's ``spill_compress``).

    Implemented as a `math.fsum` over the flat `CostTerm` stream of the
    whole trace (`chime_sim.request_terms`), which makes the totals
    order-independent: the telemetry `TierLedger`, which prices the SAME
    events step-by-step as the engine runs, reconciles with this
    function bit-for-bit on a drained run.

    ``fused_decode`` / ``sparse_read_tau`` price the fused paged-decode
    attention path instead of the streamed two-segment merge (pass the
    backend's resolved knobs; None falls back to the cfg fields so the
    defaults match whatever the model actually executed).
    ``weight_stream`` additionally prices the RRAM weight fetches of the
    streamed scan units (same resolution: the backend's resolved knob,
    else truthy ``cfg.weight_stream_layers``).
    """
    fused = bool(getattr(cfg, "fused_decode", False)
                 if fused_decode is None else fused_decode)
    tau = float(getattr(cfg, "sparse_read_tau", 0.0)
                if sparse_read_tau is None else sparse_read_tau)
    wstream = bool(getattr(cfg, "weight_stream_layers", 0)
                   if weight_stream is None else weight_stream)
    layers = cost_layers(cfg)
    terms = []
    n_spills = 0
    tokens = 0
    for req in finished:
        for ctx in req.evict_ctx:
            terms += spill_terms(cfg, platform, int(ctx),
                                 compressed=spill_compressed)
            terms += spill_terms(cfg, platform, int(ctx), restore=True,
                                 compressed=spill_compressed)
            n_spills += 1
        if req.n_generated == 0:
            continue
        image = req.has_image and cfg.frontend is not None
        terms += request_terms(cfg, platform, int(req.tokens.shape[0]),
                               req.n_generated, image, layers,
                               cached_prefix=int(req.prefix_hit),
                               fused=fused, sparse_tau=tau,
                               weight_stream=wstream)
        tokens += req.n_generated
    agg = sum_terms(terms)
    energy, sim_s = agg["sim_energy_j"], agg["sim_total_s"]
    return {
        "platform": platform.name,
        "sim_energy_j": energy,
        "sim_total_s": sim_s,
        "sim_spills": n_spills,
        "sim_spill_compressed": bool(spill_compressed),
        "sim_fused_decode": fused,
        "sim_sparse_read_tau": tau,
        "sim_weight_stream": wstream,
        "sim_spill_energy_j": agg["sim_spill_energy_j"],
        "sim_spill_s": agg["sim_spill_s"],
        "sim_energy_split_j": agg["sim_energy_split_j"],
        "sim_tokens_per_j": tokens / energy if energy else 0.0,
        "sim_tok_per_s_sequential": tokens / sim_s if sim_s else 0.0,
    }
