"""Serving metrics: measured latency/throughput + simulated efficiency.

Wall-clock numbers (TTFT, per-request latency, aggregate tok/s) come from
the engine's clock. Energy cannot be measured on a host CPU, so
tokens/J is *simulated*: each finished request's (prompt, step-count)
trace is fed through the CHIME analytical simulator's per-kernel cost
terms (`simulator/chime_sim.py`) on the target platform — the same
instrument the paper-claims tests validate.
"""

from __future__ import annotations

import numpy as np

from repro.simulator.chime_sim import Workload, simulate
from repro.simulator.hardware import CHIME, Platform


def request_metrics(req) -> dict:
    m = {
        "rid": req.rid,
        "prompt_len": req.prompt_len,
        "n_generated": req.n_generated,
        "ttft_s": req.first_token_s - req.arrival_s,
        "latency_s": req.finish_s - req.arrival_s,
    }
    tbt = np.diff(req.token_times)
    if tbt.size:
        m["tbt_p50_s"] = float(np.percentile(tbt, 50))
        m["tbt_p95_s"] = float(np.percentile(tbt, 95))
        m["tbt_max_s"] = float(tbt.max())
    return m


def aggregate_metrics(finished, wall_s: float) -> dict:
    """Aggregate over finished requests for a run of ``wall_s`` seconds.

    TTFT percentiles are over requests; time-between-tokens (TBT)
    percentiles pool every request's inter-token gaps — the tail that
    chunked prefill exists to bound (a whole-prompt prefill stalls every
    in-flight request's next token for the full prompt duration)."""
    if not finished:
        return {"requests": 0, "total_tokens": 0, "tok_per_s": 0.0}
    lat = np.array([r.finish_s - r.arrival_s for r in finished])
    ttft = np.array([r.first_token_s - r.arrival_s for r in finished])
    total = int(sum(r.n_generated for r in finished))
    m = {
        "requests": len(finished),
        "total_tokens": total,
        "tok_per_s": total / max(wall_s, 1e-9),
        "mean_ttft_s": float(ttft.mean()),
        "ttft_p50_s": float(np.percentile(ttft, 50)),
        "ttft_p95_s": float(np.percentile(ttft, 95)),
        "mean_latency_s": float(lat.mean()),
        "p95_latency_s": float(np.percentile(lat, 95)),
    }
    tbt = np.concatenate(
        [np.diff(r.token_times) for r in finished] or [np.zeros(0)])
    if tbt.size:
        m["tbt_p50_s"] = float(np.percentile(tbt, 50))
        m["tbt_p95_s"] = float(np.percentile(tbt, 95))
        m["tbt_max_s"] = float(tbt.max())
    return m


def simulated_efficiency(cfg, finished, platform: Platform = CHIME) -> dict:
    """Simulated time/energy for the served trace on ``platform``.

    Each request contributes a VQA workload of its own (prompt length,
    generated step count); the per-token attention cost grows with that
    request's context exactly as the engine's tiered reads did.
    """
    energy = sim_s = 0.0
    tokens = 0
    for req in finished:
        if req.n_generated == 0:
            continue
        image = req.has_image and cfg.frontend is not None
        wl = Workload(text_tokens=int(req.tokens.shape[0]),
                      output_tokens=req.n_generated, image=image)
        res = simulate(cfg, platform, wl)
        energy += res.energy_j
        sim_s += res.total_s
        tokens += req.n_generated
    return {
        "platform": platform.name,
        "sim_energy_j": energy,
        "sim_total_s": sim_s,
        "sim_tokens_per_j": tokens / energy if energy else 0.0,
        "sim_tok_per_s_sequential": tokens / sim_s if sim_s else 0.0,
    }
