"""Continuous-batching engine driven by per-step work plans: chunked
prefill through the unified `extend_step` + one backend decode step.

Step anatomy (one `Engine.step()` call):

  1. plan — the scheduler emits a `StepPlan`: under the step's token
     budget (decode slots take one token each), the in-flight prompt
     advances by prefill chunks of at most ``chunk_tokens`` positions,
     and the FCFS queue head is admitted (slot + DRAM/RRAM byte budgets
     permitting) once the previous prompt committed. Under pressure the
     plan may first PREEMPT: a strictly higher-priority waiter evicts
     the lowest-priority running victim's KV state into an RRAM spill
     lane (`backend.evict_slot`, verbatim image + endurance-counter
     bump), and spilled requests restore bit-exactly into freed slots
     (`backend.restore_slot`) so resumed decode is token-for-token
     identical to a never-evicted run;
  2. prefill chunks — each chunk is ONE `backend.extend_step` call that
     extends the in-flight request's chunk-resumable state; the final
     (``commit``) chunk folds it into the already-allocated pool slot and
     yields the request's first greedy token. A VQA prompt's visual span
     is chunked in patch space and its text tail in token space, split at
     the modality boundary;
  3. decode — ONE backend call advances every active slot:
     `backend.decode_step` runs the jitted per-slot decode (vmapped
     locally, pjit-sharded on a mesh). Slot shapes are static; the
     backend compiles once per chunk shape;
  4. retire — slots whose request hit EOS or max_new_tokens are freed
     for recycling; inactive slots' cache writes are masked out, so
     endurance counters only ever reflect real occupancies.

With the default knobs (no token budget, no chunk cap) a prompt prefills
in one chunk and the engine reproduces the PR 1/2 admit-whole-prompt
loop token-for-token. With a budget, long vision prompts no longer stall
every decode slot for the full prompt duration — decode slots keep
emitting between chunks (Sarathi-style chunked prefill), which is what
bounds TBT on the paper's multimodal workloads.

The engine is execution-agnostic: it talks to an
`serving.backend.InferenceBackend` and a model-free `TieredKVPool`, so
scheduling, metrics and the endurance audit run unmodified on the local
vmapped backend and the pjit-sharded one. Greedy decoding (matches
`launch.serve.generate`); tokens stream to each request's ``on_token``
callback as they are produced.
"""

from __future__ import annotations

import dataclasses
import inspect
import os
import time
import warnings

import numpy as np

from repro.serving.backend import InferenceBackend
from repro.serving.block_pool import (BlockPool, PrefixHit,
                                      request_prefix_keys)
from repro.serving.request import FINISHED, PREEMPTED, RUNNING, Request
from repro.serving.scheduler import (CapacityBudget, FCFSScheduler,
                                     PrefillChunk)
from repro.serving.telemetry import NullTelemetry
from repro.simulator.hardware import CHIME


def bucket_len(n: int, minimum: int = 8) -> int:
    """Next power of two >= n (>= minimum): bounds jit retraces to
    O(log max_prompt) prefill shapes."""
    b = minimum
    while b < n:
        b *= 2
    return b


def _env_int(name: str) -> int | None:
    """Env knob: a positive int enables, an explicit 0 disables (returned
    as 0 so it is distinguishable from unset), empty/absent returns None;
    anything else is ignored with a warning (an env var should never
    wedge startup)."""
    raw = os.environ.get(name, "").strip()
    if not raw:
        return None
    try:
        v = int(raw)
    except ValueError:
        warnings.warn(f"ignoring non-integer {name}={raw!r}")
        return None
    if v < 0:
        warnings.warn(f"ignoring negative {name}={v}")
        return None
    return v


def _env_float(name: str) -> float | None:
    """Float env knob with the same sanitation contract as `_env_int`."""
    raw = os.environ.get(name, "").strip()
    if not raw:
        return None
    try:
        v = float(raw)
    except ValueError:
        warnings.warn(f"ignoring non-numeric {name}={raw!r}")
        return None
    if v < 0:
        warnings.warn(f"ignoring negative {name}={v}")
        return None
    return v


@dataclasses.dataclass
class _Inflight:
    """The one prompt currently prefilling: its pool slot is already
    allocated (it pins the byte budgets) and ``ext`` carries the
    chunk-resumable state between extend calls. ``prefix`` holds the
    acquired prefix-cache hit (refcounts released once the prompt
    commits and re-registers its chain)."""
    req: Request
    slot: int
    pos: int
    ext: dict
    prefix: PrefixHit | None = None


@dataclasses.dataclass
class _SpillRec:
    """Host-side resume state of one preempted request: which RRAM lane
    holds its packed cache image, and the decode-loop scalars
    (position, last emitted token, occupancy lengths for the endurance
    audit) that restore re-pins to a slot."""
    lane: int
    pos: int
    tok: int
    prefill_len: int
    total_len: int


class Engine:
    """Continuous-batching serving engine over an InferenceBackend.

    ``chunk_tokens`` caps a single prefill chunk and ``token_budget``
    caps the total tokens per step (decode slots included); both default
    to the ``REPRO_SERVE_CHUNK_TOKENS`` / ``REPRO_SERVE_TOKEN_BUDGET``
    env knobs, then to None (whole-prompt chunks — the pre-StepPlan
    behavior). When only ``chunk_tokens`` is set, the budget defaults to
    ``chunk_tokens + num_slots`` (one chunk plus all decode slots per
    step). ``oversubscribe`` (>= 1; env ``REPRO_SERVE_OVERSUBSCRIBE``,
    0/None = off) relaxes the scheduler's DRAM admission gate by that
    factor, spill-lane-backed — the Cambricon-LLM/SLIM-style
    spill-to-dense-tier trade for serving beyond DRAM capacity.
    ``idle_offload_steps`` (>= 1; env ``REPRO_SERVE_IDLE_OFFLOAD_STEPS``,
    0/None = off) enables proactive idle cold-KV offload: a blocked
    equal-or-higher-priority waiter may park a runner resident at least
    that many decode steps into an RRAM lane (bit-exact, same machinery
    as preemption) and take its freed DRAM under the base byte gates.
    ``telemetry`` attaches a `serving.telemetry.Telemetry` hub (span
    tracer + tier-traffic ledger + gauges/decision log); None (default)
    installs the no-op `NullTelemetry`.

    ``paged`` (env ``REPRO_SERVE_PAGED``, default off) switches the
    admission gate from per-slot worst-case ``max_len`` byte charges to
    live block-granular charges (each resident prices its block-rounded
    prompt+generation span). ``prefix_cache`` (default = ``paged``, and
    implying it) additionally shares identical request prefixes through
    the host-side `serving.block_pool.BlockPool`: an admitted request
    whose token/patch prefix hashes to cached chains seeds its prefill
    workspace from the shared blocks and starts prefilling at the hit
    position — only the tail is computed (and charged) — while shared
    blocks take exactly ONE physical write regardless of how many
    requests reference them (the RRAM write-once discipline). The slot
    pool semantics are unchanged either way, so ``Engine(paged=False)``
    stays the exact parity oracle.

    ``charge_weights`` (env ``REPRO_SERVE_CHARGE_WEIGHTS``; default None
    = on iff the backend resolved weight streaming on) charges the
    backend's DRAM-resident weight working set
    (`backend.weight_bytes()[0]`) off the top of the scheduler's DRAM
    budget, so admission sees weights + KV, not KV alone — the gate that
    denies an over-budget resident model (`dram_weights`) and admits its
    weight-streamed twin, whose working set is only embeddings + head +
    the per-unit sliding windows."""

    def __init__(self, backend,
                 scheduler: FCFSScheduler | None = None,
                 platform=CHIME, clock=time.perf_counter,
                 token_budget: int | None = None,
                 chunk_tokens: int | None = None,
                 oversubscribe: float | None = None,
                 idle_offload_steps: int | None = None,
                 paged: bool | None = None,
                 prefix_cache: bool | None = None,
                 charge_weights: bool | None = None,
                 telemetry=None):
        self.backend: InferenceBackend = backend
        self.max_len = backend.max_len
        self.clock = clock
        self.pool = backend.make_pool()
        hot_b, cold_b = backend.slot_kv_bytes()
        if chunk_tokens is None:
            chunk_tokens = _env_int("REPRO_SERVE_CHUNK_TOKENS")
        if token_budget is None:
            token_budget = _env_int("REPRO_SERVE_TOKEN_BUDGET")
        if oversubscribe is None:
            env_v = _env_float("REPRO_SERVE_OVERSUBSCRIBE")
            if env_v is not None and env_v != 0 and env_v < 1:
                # env-knob contract: never wedge startup on a bad value
                warnings.warn(f"ignoring REPRO_SERVE_OVERSUBSCRIBE="
                              f"{env_v} < 1")
                env_v = None
            oversubscribe = env_v
        if idle_offload_steps is None:
            idle_offload_steps = _env_int("REPRO_SERVE_IDLE_OFFLOAD_STEPS")
        # 0 is the explicit "disable" sentinel for both knobs (whole
        # prompts / unbounded budget — even when the env knob is set).
        # An explicitly unbounded budget is NOT rebound to the
        # chunk+slots default.
        for name, v in (("chunk_tokens", chunk_tokens),
                        ("token_budget", token_budget)):
            if v is not None and v < 0:
                raise ValueError(f"{name} must be >= 0 or None, got {v}")
        if oversubscribe is not None and oversubscribe != 0 \
                and oversubscribe < 1:
            raise ValueError(f"oversubscribe must be >= 1 (or 0/None to "
                             f"disable), got {oversubscribe}")
        oversubscribe = oversubscribe or None    # 0 = explicit disable
        if idle_offload_steps is not None and idle_offload_steps < 0:
            raise ValueError(f"idle_offload_steps must be >= 0 or None, "
                             f"got {idle_offload_steps}")
        idle_offload_steps = idle_offload_steps or None  # 0 = disable
        explicit_unbounded = token_budget == 0
        chunk_tokens = chunk_tokens or None
        token_budget = token_budget or None
        if (token_budget is None and not explicit_unbounded
                and chunk_tokens is not None):
            token_budget = chunk_tokens + backend.num_slots
        # ---- paged accounting + prefix cache -------------------------
        if paged is None:
            paged = bool(_env_int("REPRO_SERVE_PAGED"))
        if prefix_cache is None:
            prefix_cache = paged
        self.prefix_cache = bool(prefix_cache)
        self.paged = bool(paged) or self.prefix_cache   # cache implies it
        self.block_pool: BlockPool | None = None
        self._probed: dict[int, PrefixHit] = {}
        self._prefix_block_bytes = 0
        if self.prefix_cache:
            if not (hasattr(backend, "prefix_blocks")
                    and hasattr(backend, "block_tokens")):
                raise ValueError(
                    "prefix_cache/paged needs a backend with the prefix "
                    "block surface (prefix_blocks/block_tokens)")
            self.block_pool = BlockPool(backend.prefix_blocks,
                                        backend.block_tokens)
            self._prefix_block_bytes = backend.prefix_block_bytes()
        # a PR-2/3-era custom backend predates the spill surface: degrade
        # to preemption-disabled instead of crashing on the missing attr
        n_spill = getattr(backend, "n_spill", 0)
        lane_fn = getattr(backend, "spill_lane_bytes", None)
        lane_b = lane_fn() if callable(lane_fn) else hot_b + cold_b
        # ---- DRAM weight working-set charge --------------------------
        # charge_weights: explicit arg > REPRO_SERVE_CHARGE_WEIGHTS env >
        # on-iff-the-backend-streams default. The charge makes the DRAM
        # admission gate see the resident weight working set, not just
        # KV — which is what actually denies an over-budget resident
        # model and admits its streamed twin. Backends without the
        # weight surface (custom PR-era executors) degrade to the legacy
        # KV-only gates.
        if charge_weights is None:
            env_cw = _env_int("REPRO_SERVE_CHARGE_WEIGHTS")
            charge_weights = None if env_cw is None else bool(env_cw)
        wb_fn = getattr(backend, "weight_bytes", None)
        if charge_weights is None:
            charge_weights = bool(getattr(backend, "weight_stream", 0))
        self.charge_weights = bool(charge_weights) and callable(wb_fn)
        weight_b = float(wb_fn()[0]) if self.charge_weights else None
        if scheduler is None:
            scheduler = FCFSScheduler(CapacityBudget.from_platform(platform),
                                      hot_b, cold_b,
                                      token_budget=token_budget,
                                      chunk_tokens=chunk_tokens,
                                      oversubscribe=oversubscribe,
                                      spill_lanes=n_spill,
                                      idle_offload_steps=idle_offload_steps,
                                      lane_bytes=lane_b,
                                      weight_bytes=weight_b)
        elif not isinstance(scheduler, FCFSScheduler) or (
                type(scheduler).plan is not FCFSScheduler.plan):
            pass  # custom planner: it owns its own chunking policy
        else:
            # apply resolved knobs to a provided base-plan scheduler so
            # Engine(..., scheduler=..., chunk_tokens=N) and the
            # REPRO_SERVE_* env forcing are not silently dropped; the
            # scheduler's own explicitly-set knobs win
            if scheduler.chunk_tokens is None and chunk_tokens is not None:
                scheduler.chunk_tokens = chunk_tokens
            if scheduler.token_budget is None and token_budget is not None:
                scheduler.token_budget = token_budget
            if scheduler.oversubscribe is None \
                    and oversubscribe is not None:
                scheduler.oversubscribe = oversubscribe
            if scheduler.spill_lanes is None:
                scheduler.spill_lanes = n_spill
            if scheduler.idle_offload_steps is None \
                    and idle_offload_steps is not None:
                scheduler.idle_offload_steps = idle_offload_steps
            if scheduler.lane_bytes is None:
                scheduler.lane_bytes = lane_b
            if scheduler.weight_bytes is None and weight_b is not None:
                scheduler.weight_bytes = weight_b
        if self.paged:
            # live-block charges + prefix probing: back-fill only unset
            # hooks so a custom scheduler's own policy wins
            if getattr(scheduler, "charge_fn", None) is None:
                try:
                    scheduler.charge_fn = self._charge
                except AttributeError:
                    pass                       # __slots__ scheduler
            if self.prefix_cache \
                    and getattr(scheduler, "prefix_probe", None) is None:
                try:
                    scheduler.prefix_probe = self._probe
                except AttributeError:
                    pass
            if getattr(scheduler, "shared_bytes_fn", None) is None:
                try:
                    scheduler.shared_bytes_fn = self._shared_bytes
                except AttributeError:
                    pass
        self.scheduler = scheduler
        # one-release compat: a PR-3-era custom plan() override that does
        # not accept the preemption kwargs (running/free_lanes) still
        # plans — it just never preempts; warn so it migrates
        try:
            params_ = inspect.signature(type(scheduler).plan).parameters
            self._plan_preemptive = (
                "running" in params_ and "free_lanes" in params_) or any(
                p.kind is p.VAR_KEYWORD for p in params_.values())
        except (TypeError, ValueError):
            self._plan_preemptive = False
        if not self._plan_preemptive:
            warnings.warn(
                "scheduler.plan() does not accept running=/free_lanes=; "
                "the engine will plan without preemption. Accept those "
                "keywords to enable it",
                DeprecationWarning, stacklevel=2)
        if scheduler.max_concurrent < 1:
            wb = getattr(scheduler, "weight_bytes", None) or 0
            wmsg = (f" plus the {wb:.3e}-byte DRAM-resident weight "
                    f"working set" if wb else "")
            raise ValueError(
                f"one slot's KV state ({hot_b} hot + {cold_b} cold bytes)"
                f"{wmsg} exceeds the domain budgets; nothing can be "
                f"admitted")
        # num_slots beyond the byte budgets is allowed but idle: admission
        # is gated per-request by the scheduler, so effective concurrency
        # is min(num_slots, scheduler.max_concurrent)

        # ---- per-slot host state -------------------------------------
        n = backend.num_slots
        self._slot_req: list[Request | None] = [None] * n
        self._tok = np.zeros((n, 1), np.int32)
        self._pos = np.zeros((n,), np.int32)
        self._active = np.zeros((n,), bool)
        # lengths of the CURRENT/LAST occupant (endurance audit input)
        self._slot_prefill_len = [0] * n
        self._slot_total_len = [0] * n
        self._inflight: _Inflight | None = None
        self._spilled: dict[int, _SpillRec] = {}    # rid -> resume state
        self.finished: list[Request] = []
        self._next_rid = 0
        self.stats = {"steps": 0, "prefill_chunks": 0, "extend_calls": 0,
                      "decode_steps": 0, "decode_tokens": 0,
                      "evictions": 0, "restores": 0, "idle_offloads": 0,
                      "prefix_hits": 0, "prefix_hit_tokens": 0}

        # ---- telemetry (opt-in; None = no-op hooks, <2% contract) ----
        self.telemetry = telemetry if telemetry is not None \
            else NullTelemetry()
        if self.telemetry.enabled:
            ctx_fn = getattr(backend, "sim_context", None)
            t_cfg, t_comp = ctx_fn() if callable(ctx_fn) else (None, False)
            self.telemetry.bind(cfg=t_cfg, spill_compressed=t_comp,
                                clock=self.clock, platform=platform,
                                on_snapshot=self.endurance_report,
                                fused_decode=getattr(
                                    backend, "fused_decode", None),
                                sparse_read_tau=getattr(
                                    backend, "sparse_read_tau", None),
                                weight_stream=(
                                    None if getattr(backend,
                                                    "weight_stream", None)
                                    is None
                                    else bool(backend.weight_stream)))
            # the scheduler logs decision codes through the same hub; a
            # user-built scheduler that already carries one keeps it
            if getattr(self.scheduler, "telemetry", None) is None:
                try:
                    self.scheduler.telemetry = self.telemetry
                except AttributeError:
                    pass                       # __slots__ scheduler

    # ------------------------------------------------------------------
    # request intake
    # ------------------------------------------------------------------
    def submit(self, req: Request) -> Request:
        if req.total_len > self.max_len:
            raise ValueError(
                f"request needs {req.total_len} positions > pool max_len "
                f"{self.max_len}")
        if req.rid is None or req.rid < 0:
            req.rid = self._next_rid
        self._next_rid = max(self._next_rid, req.rid + 1)
        req.arrival_s = self.clock()
        self.scheduler.submit(req)
        self.telemetry.request_submitted(req)
        return req

    # ------------------------------------------------------------------
    # paged accounting + prefix cache hooks (scheduler callbacks)
    # ------------------------------------------------------------------
    def _probe(self, req: Request) -> int:
        """Prefix-cache probe for the queue head: longest cached chain
        matching the request's token/patch prefix, usable at the
        backend's prefill grid. Probes are memoized per step (the charge
        and the admission start position must see the SAME hit) and pin
        their blocks against eviction for the step without refcounting —
        a denied admission must not leak references."""
        if self.block_pool is None:
            return 0
        if req.rid in self._probed:
            return self._probed[req.rid].length
        hit = self.block_pool.lookup(
            request_prefix_keys(req), max_hit=req.prompt_len - 1,
            require_state=self.backend.requires_exact_prefill,
            grid=self.backend.chunk_unit)
        self._probed[req.rid] = hit
        return hit.length

    def _charge(self, req: Request) -> tuple[int, int]:
        """(hot, cold) bytes this request charges the byte gates: its
        block-rounded total span, net of the FULL blocks its prefix hit
        covers (those live in the shared store, charged once via
        `_shared_bytes` no matter how many requests reference them)."""
        hit = 0
        if self.block_pool is not None:
            bt = self.backend.block_tokens
            hit = (self._probe(req) // bt) * bt
        return self.backend.slot_kv_bytes(
            length=max(req.total_len - hit, 1))

    def _shared_bytes(self) -> int:
        """Bytes the shared prefix store pins in RRAM: only blocks held
        by a live admission count. Unreferenced cached blocks are
        reclaimable (the pool LRU-evicts them when `register` runs dry),
        so charging them would wedge admission behind a cache that
        nothing ever shrinks."""
        if self.block_pool is None:
            return 0
        return self.block_pool.pinned_blocks * self._prefix_block_bytes

    # ------------------------------------------------------------------
    # prefill chunks
    # ------------------------------------------------------------------
    def _pad_target(self, valid: int, pos: int) -> int:
        """Chunk padding width: exact for recurrent architectures (padded
        rows would corrupt the carried states), the fixed chunk cap when
        chunking (one trace per modality), else the admission bucket (the
        seed's O(log max_prompt) trace bound). Never pads past the slot
        length so the workspace write stays in bounds."""
        if self.backend.requires_exact_prefill:
            return valid
        cap = getattr(self.scheduler, "chunk_tokens", None)
        if cap:
            return max(valid, min(cap, self.max_len - pos))
        return max(min(bucket_len(valid), self.max_len - pos), valid)

    def _chunk_batch(self, req: Request, kind: str, a: int, b: int,
                     pos: int) -> tuple[dict, int]:
        """Batch for the chunk covering absolute positions [a, b) of the
        prompt, single-modality by construction (``kind``). Right-pads to
        `_pad_target`; padded rows' K/V land beyond the chunk's valid
        length where they are never attendable and are overwritten by the
        next chunk."""
        valid = b - a
        target = self._pad_target(valid, pos)
        if kind == "patches":
            part = np.asarray(req.patches[a:b], np.float32)
            if target > valid:
                part = np.concatenate(
                    [part, np.zeros((target - valid,) + part.shape[1:],
                                    np.float32)])
            return {"patches": part[None]}, valid
        vis = 0 if req.patches is None else int(req.patches.shape[0])
        part = np.asarray(req.tokens[a - vis:b - vis], np.int32)
        if target > valid:
            part = np.concatenate(
                [part, np.zeros((target - valid,), np.int32)])
        return {"tokens": part[None]}, valid

    def _run_chunk(self, ch: PrefillChunk) -> list[tuple[int, int, bool]]:
        """Execute one planned chunk: allocate the slot on admission,
        split at the patch/text modality boundary, run the extend calls,
        and stream the first token when the prompt commits."""
        if ch.admit:
            slot = self.pool.alloc()
            self._inflight = _Inflight(req=ch.req, slot=slot, pos=0,
                                       ext=self.backend.fresh_extend())
            ch.req.admit_s = self.clock()
            self.telemetry.request_admitted(ch.req, slot)
            if ch.start > 0:
                self._adopt_prefix(ch)
        fl = self._inflight
        assert fl is not None and fl.req is ch.req and fl.pos == ch.start
        req = ch.req
        vis = 0 if req.patches is None else int(req.patches.shape[0])
        end = ch.start + ch.length
        parts: list[tuple[str, int, int]] = []
        if ch.start < vis:
            parts.append(("patches", ch.start, min(end, vis)))
        if end > vis:
            parts.append(("tokens", max(ch.start, vis), end))
        tok = None
        want_register = self.block_pool is not None and ch.commit
        full_ws = None
        for i, (kind, a, b) in enumerate(parts):
            commit = ch.commit and i == len(parts) - 1
            batch, valid = self._chunk_batch(req, kind, a, b, fl.pos)
            if commit and want_register:
                # the commit call folds the workspace into the slot and
                # returns the committed STORE form; registration needs
                # the complete workspace, so rerun the final chunk
                # uncommitted first (logits are identical either way)
                _, full_ws, _ = self.backend.extend_step(
                    batch, self.pool.state, fl.ext, fl.slot, fl.pos,
                    valid, False)
            tok, ext, state = self.backend.extend_step(
                batch, self.pool.state, fl.ext, fl.slot, fl.pos, valid,
                commit)
            if commit:
                self.pool.state = state
            else:
                fl.ext = ext
            fl.pos += valid
            self.stats["extend_calls"] += 1
        self.stats["prefill_chunks"] += 1
        if not ch.commit:
            return []
        if want_register:
            self._register_prefix(fl, full_ws)
        return self._commit(fl, int(tok))

    def _adopt_prefix(self, ch: PrefillChunk):
        """Seed the freshly-admitted prefill from its probed prefix-cache
        hit: acquire the chain (refcounts drop at registration), gather
        each hit block's workspace rows — and, for exact-prefill
        (recurrent) backends, the chain-terminal state snapshot — into
        the extend workspace, and resume prefill AT the hit position."""
        fl = self._inflight
        req = ch.req
        hit = self._probed.get(req.rid)
        assert hit is not None and hit.length == ch.start, \
            "admission start desynced from the probed prefix hit"
        pool = self.block_pool
        pool.acquire(hit)
        fl.prefix = hit
        self.pool.state = self.backend.ensure_prefix(self.pool.state)
        if self.backend.has_prefix_ws:
            for node in hit.nodes:
                fl.ext = self.backend.prefix_load_ws(
                    self.pool.state, fl.ext, node.bid, node.start)
        if self.backend.requires_exact_prefill:
            fl.ext = self.backend.prefix_load_state(
                self.pool.state, fl.ext, hit.nodes[-1].bid)
        fl.pos = ch.start
        req.prefix_hit = ch.start
        self.stats["prefix_hits"] += 1
        self.stats["prefix_hit_tokens"] += ch.start
        self.telemetry.decision("prefix_adopt", rid=req.rid,
                                hit_tokens=ch.start,
                                blocks=len(hit.nodes))

    def _register_prefix(self, fl: _Inflight, full_ws: dict):
        """Fold the committed prompt's prefix into the shared store:
        dedup against existing chains, write ONLY the new (diverging)
        blocks — each exactly once, the endurance contract — snapshot
        the recurrent state at the chain terminal when the backend needs
        exact resume points, and release the adopted hit's refcounts."""
        req, pool = fl.req, self.block_pool
        bt = self.backend.block_tokens
        self.pool.state = self.backend.ensure_prefix(self.pool.state)
        new, term = pool.register(request_prefix_keys(req),
                                  max_start=self.max_len - bt)
        for node in new:
            if self.backend.has_prefix_ws:
                self.pool.state = self.backend.prefix_save_ws(
                    self.pool.state, full_ws, node.bid, node.start)
            pool.note_write(node.bid)
        if (self.backend.requires_exact_prefill and term is not None
                and not term.has_state and term.end == req.prompt_len
                and term.end % self.backend.chunk_unit == 0):
            self.pool.state = self.backend.prefix_save_state(
                self.pool.state, full_ws, term.bid)
            pool.note_write(term.bid)
            term.has_state = True
        if fl.prefix is not None:
            if fl.prefix.partial and new:
                pool.stats["cow_copies"] += 1
            pool.release(fl.prefix)
            fl.prefix = None

    def _commit(self, fl: _Inflight, tok: int
                ) -> list[tuple[int, int, bool]]:
        req, slot = fl.req, fl.slot
        self._inflight = None
        tel = self.telemetry
        tel.phase_begin("commit")
        req.first_token_s = self.clock()
        req.status = RUNNING
        req.emit(tok)
        req.token_times.append(self.clock())
        tel.request_first_token(req)
        # the slot's cache now holds this request's stores either way;
        # record its occupancy so the endurance audit stays truthful
        self._slot_prefill_len[slot] = req.prompt_len
        self._slot_total_len[slot] = req.prompt_len
        if req.finished_by(tok):
            self._finish(req)            # 1-token request: retires at once
            self.pool.free(slot)
            tel.phase_end(rid=req.rid)
            return [(req.rid, tok, True)]
        req.slot = slot
        req.resident_steps = 0           # fresh residency (offload clock)
        self._slot_req[slot] = req
        self._tok[slot, 0] = tok
        self._pos[slot] = req.prompt_len
        self._active[slot] = True
        tel.phase_end(rid=req.rid)
        return [(req.rid, tok, False)]

    # ------------------------------------------------------------------
    # the step loop
    # ------------------------------------------------------------------
    def _finish(self, req: Request):
        req.status = FINISHED
        req.finish_s = self.clock()
        release = getattr(self.scheduler, "release", None)
        if callable(release):
            release(req)                 # retire its paged byte charge
        self.finished.append(req)
        self.telemetry.request_finished(req)

    def _retire(self, slot: int):
        req = self._slot_req[slot]
        self._finish(req)
        self._slot_req[slot] = None
        self._active[slot] = False
        req.slot = -1
        self.pool.free(slot)

    # ------------------------------------------------------------------
    # preemption: spill to RRAM / bit-exact restore
    # ------------------------------------------------------------------
    def _evict(self, req: Request, offload: bool = False):
        """Pack ``req``'s slot into a free RRAM spill lane and park it.
        The image is the slot's cache verbatim (plus the decode-loop
        scalars recorded host-side), so the later restore resumes decode
        token-for-token identically to a never-evicted run — unless the
        backend compresses spill lanes, in which case the hot ring pays
        the documented codec error. ``offload`` marks a proactive idle
        cold-KV offload (capacity) rather than a preemption (priority);
        the mechanics are identical, only the stats differ."""
        slot = req.slot
        assert slot >= 0 and self._slot_req[slot] is req \
            and self._active[slot]
        lane = self.pool.alloc_lane()
        ctx = int(self._pos[slot])
        self.pool.state = self.backend.evict_slot(self.pool.state, slot,
                                                  lane, ctx)
        self._spilled[req.rid] = _SpillRec(
            lane=lane, pos=ctx, tok=int(self._tok[slot, 0]),
            prefill_len=self._slot_prefill_len[slot],
            total_len=self._slot_total_len[slot])
        req.status = PREEMPTED
        req.slot = -1
        req.evict_times.append(self.clock())
        req.evict_ctx.append(ctx)
        req.n_idle_offloads += 1 if offload else 0
        self._slot_req[slot] = None
        self._active[slot] = False
        self.pool.free(slot)
        self.stats["idle_offloads" if offload else "evictions"] += 1
        self.telemetry.request_evicted(req, slot, lane, ctx, offload)

    def _restore(self, req: Request):
        """Scatter ``req``'s spill lane back into a (possibly different)
        free slot and rejoin decode at the exact position it left."""
        rec = self._spilled.pop(req.rid)
        slot = self.pool.alloc()
        self.pool.state = self.backend.restore_slot(self.pool.state,
                                                    rec.lane, slot)
        self.pool.release_lane(rec.lane)
        req.status = RUNNING
        req.slot = slot
        req.resident_steps = 0           # restored: a fresh time slice
        req.restore_times.append(self.clock())
        self._slot_req[slot] = req
        self._tok[slot, 0] = rec.tok
        self._pos[slot] = rec.pos
        self._active[slot] = True
        self._slot_prefill_len[slot] = rec.prefill_len
        self._slot_total_len[slot] = rec.total_len
        self.stats["restores"] += 1
        self.telemetry.request_restored(req, rec.lane, slot, rec.pos)

    def step(self) -> list[tuple[int, int, bool]]:
        """Execute one StepPlan: spill evictions, restores, prefill
        chunks, then one decode token on every active slot. Returns
        streamed events: (rid, token, done).

        A plan is a commitment, not a peek: producing it pops admitted
        requests off the scheduler queue (and moves evicted/restored
        requests between the running and spilled sets), and this method
        executes every entry in it before decoding."""
        events: list[tuple[int, int, bool]] = []
        fl = self._inflight
        tel = self.telemetry
        tel.step_begin(self.stats["steps"])
        if self.block_pool is not None:
            # fresh pin epoch: this step's probes protect their blocks
            # from LRU eviction without taking refcounts
            self.block_pool.begin_epoch()
            self._probed.clear()
        tel.phase_begin("plan")
        kwargs = {}
        if self._plan_preemptive:
            kwargs = dict(
                running=tuple(r for r in self._slot_req
                              if r is not None),
                free_lanes=self.pool.free_lanes)
        plan = self.scheduler.plan(
            active_slots=self.pool.active_slots,
            decode_slots=int(self._active.sum()),
            free_slots=self.pool.free_slots,
            inflight=None if fl is None else (fl.req, fl.pos),
            chunk_unit=self.backend.chunk_unit, **kwargs)
        evictions = tuple(getattr(plan, "evictions", ()))
        offloads = tuple(getattr(plan, "offloads", ()))
        restores = tuple(getattr(plan, "restores", ()))
        tel.phase_end(chunks=len(plan.chunks))
        tel.phase_begin("evict")
        for req in evictions:
            self._evict(req)
        tel.phase_end(count=len(evictions))
        tel.phase_begin("idle-offload")
        for req in offloads:
            self._evict(req, offload=True)
        tel.phase_end(count=len(offloads))
        tel.phase_begin("restore")
        for req in restores:
            self._restore(req)
        tel.phase_end(count=len(restores))
        tel.phase_begin("chunk-prefill")
        for ch in plan.chunks:
            events.extend(self._run_chunk(ch))
        tel.phase_end(count=len(plan.chunks))
        self.stats["steps"] += 1
        # plan.decode is the planner's say (a custom planner may dedicate
        # a step to prefill); _active is the physical guard
        if not plan.decode or not self._active.any():
            tel.step_end(self._gauges() if tel.enabled else None)
            return events
        tel.phase_begin("decode")
        ntoks, self.pool.state = self.backend.decode_step(
            self._tok, self.pool.state, self._pos, self._active)
        ntoks = np.asarray(ntoks)
        self.stats["decode_steps"] += 1
        n_emitted = 0
        for slot in np.nonzero(self._active)[0]:
            req = self._slot_req[slot]
            tok = int(ntoks[slot])
            req.emit(tok)
            req.token_times.append(self.clock())
            tel.token(req)
            req.resident_steps += 1
            self._pos[slot] += 1
            self._slot_total_len[slot] += 1
            self._tok[slot, 0] = tok
            self.stats["decode_tokens"] += 1
            n_emitted += 1
            done = req.finished_by(tok)
            events.append((req.rid, tok, done))
            if done:
                self._retire(int(slot))
        tel.phase_end(count=n_emitted)
        tel.step_end(self._gauges() if tel.enabled else None)
        return events

    def _gauges(self) -> dict:
        """Occupancy/queue snapshot for the telemetry hub (built only
        when telemetry is enabled)."""
        queue = getattr(self.scheduler, "_queue", ())
        depth: dict[int, int] = {}
        for r in queue:
            depth[r.priority] = depth.get(r.priority, 0) + 1
        g = {
            "slots_total": self.backend.num_slots,
            "slots_active": self.pool.active_slots,
            "slots_free": self.pool.free_slots,
            "slots_decoding": int(self._active.sum()),
            "lanes_free": self.pool.free_lanes,
            "spilled_requests": len(self._spilled),
            "inflight": 0 if self._inflight is None else 1,
            "queue_depth": depth,
        }
        if self.block_pool is not None:
            bp = self.block_pool
            g.update(
                prefix_blocks_used=bp.used_blocks,
                prefix_blocks_free=bp.free_blocks,
                prefix_max_refcount=bp.max_refcount,
                prefix_hits=self.stats["prefix_hits"],
                prefix_hit_tokens=self.stats["prefix_hit_tokens"],
                prefix_cow_copies=bp.stats["cow_copies"],
                prefix_blocks_registered=bp.stats["blocks_registered"],
                prefix_blocks_evicted=bp.stats["blocks_evicted"],
            )
        return g

    @property
    def idle(self) -> bool:
        """True when nothing is queued, prefilling, decoding or parked
        in the spill store."""
        return not (self.scheduler.pending or self.pool.active_slots
                    or self._inflight is not None or self._spilled)

    def run(self, requests=None, max_steps: int | None = None
            ) -> list[Request]:
        """Drain: submit ``requests`` (if given) and step until queue,
        in-flight prefill and slots are empty. Returns the finished
        requests in completion order. Raises once ``max_steps`` steps
        have run without draining."""
        for r in requests or ():
            self.submit(r)
        start = len(self.finished)
        steps = 0
        while not self.idle:
            if max_steps is not None and steps >= max_steps:
                raise RuntimeError(f"engine did not drain in {max_steps} "
                                   f"steps")
            self.step()
            steps += 1
        return self.finished[start:]

    # ------------------------------------------------------------------
    # reports
    # ------------------------------------------------------------------
    def endurance_report(self) -> dict:
        rep = self.pool.endurance_report(
            self._slot_prefill_len, self._slot_total_len,
            self.backend.hot_window)
        rep["spills"] = self.stats["evictions"] \
            + self.stats["idle_offloads"]
        rep["preemptions"] = self.stats["evictions"]
        rep["idle_offloads"] = self.stats["idle_offloads"]
        rep["restores"] = self.stats["restores"]
        return rep
