"""Continuous-batching engine: interleaved prefill admission + one backend
decode step over all slots.

Step anatomy (one `Engine.step()` call):

  1. admission — while a slot is free AND the FCFS scheduler's capacity
     budgets admit another resident request, prefill the queue head
     (right-padded to a shape bucket so jit reuses traces) and overwrite a
     pool slot with its fresh per-request tiered cache;
  2. decode — ONE backend call advances every slot: `backend.decode_step`
     runs the jitted per-slot decode (vmapped locally, pjit-sharded on a
     mesh) so each slot attends its own hot ring + cold tier at its own
     position. Slot shapes are static; the backend compiles once.
  3. retire — slots whose request hit EOS or max_new_tokens are freed for
     recycling; inactive slots' cache writes are masked out, so endurance
     counters only ever reflect real occupancies.

The engine is execution-agnostic: it talks to an
`serving.backend.InferenceBackend` and a model-free `TieredKVPool`, so
scheduling, metrics and the endurance audit run unmodified on the local
vmapped backend and the pjit-sharded one. Greedy decoding (matches
`launch.serve.generate`); tokens stream to each request's ``on_token``
callback as they are produced.
"""

from __future__ import annotations

import time
import warnings

import numpy as np

from repro.serving.backend import InferenceBackend, LocalBackend
from repro.serving.request import FINISHED, RUNNING, Request
from repro.serving.scheduler import CapacityBudget, FCFSScheduler
from repro.simulator.hardware import CHIME


def bucket_len(n: int, minimum: int = 8) -> int:
    """Next power of two >= n (>= minimum): bounds jit retraces to
    O(log max_prompt) prefill shapes."""
    b = minimum
    while b < n:
        b *= 2
    return b


class Engine:
    """Continuous-batching serving engine over an InferenceBackend."""

    def __init__(self, backend, params=None, num_slots: int | None = None,
                 max_len: int | None = None,
                 scheduler: FCFSScheduler | None = None,
                 platform=CHIME, clock=time.perf_counter):
        if params is not None or num_slots is not None or max_len is not None:
            # one-release compat shim: Engine(model, params, num_slots=,
            # max_len=) builds the local backend the seed engine inlined
            warnings.warn(
                "Engine(model, params, num_slots=..., max_len=...) is "
                "deprecated; build a serving.backend (LocalBackend / "
                "ShardedBackend) and pass Engine(backend) instead",
                DeprecationWarning, stacklevel=2)
            backend = LocalBackend(backend, params, num_slots, max_len)
        self.backend: InferenceBackend = backend
        self.max_len = backend.max_len
        self.clock = clock
        self.pool = backend.make_pool()
        hot_b, cold_b = backend.slot_kv_bytes()
        if scheduler is None:
            scheduler = FCFSScheduler(CapacityBudget.from_platform(platform),
                                      hot_b, cold_b)
        self.scheduler = scheduler
        if scheduler.max_concurrent < 1:
            raise ValueError(
                f"one slot's KV state ({hot_b} hot + {cold_b} cold bytes) "
                f"exceeds the domain budgets; nothing can be admitted")
        # num_slots beyond the byte budgets is allowed but idle: admission
        # is gated per-request by the scheduler, so effective concurrency
        # is min(num_slots, scheduler.max_concurrent)

        # ---- per-slot host state -------------------------------------
        n = backend.num_slots
        self._slot_req: list[Request | None] = [None] * n
        self._tok = np.zeros((n, 1), np.int32)
        self._pos = np.zeros((n,), np.int32)
        self._active = np.zeros((n,), bool)
        # lengths of the CURRENT/LAST occupant (endurance audit input)
        self._slot_prefill_len = [0] * n
        self._slot_total_len = [0] * n
        self.finished: list[Request] = []
        self._next_rid = 0

    # ------------------------------------------------------------------
    # request intake
    # ------------------------------------------------------------------
    def submit(self, req: Request) -> Request:
        if req.total_len > self.max_len:
            raise ValueError(
                f"request needs {req.total_len} positions > pool max_len "
                f"{self.max_len}")
        if req.rid is None or req.rid < 0:
            req.rid = self._next_rid
        self._next_rid = max(self._next_rid, req.rid + 1)
        req.arrival_s = self.clock()
        self.scheduler.submit(req)
        return req

    def _make_batch(self, req: Request) -> dict:
        s = int(req.tokens.shape[0])
        vis = 0 if req.patches is None else int(req.patches.shape[0])
        if self.backend.requires_exact_prefill:
            target = s
        else:
            # bucket the text tail, but never pad the prefill sequence
            # (visual tokens + text) past the pool's slot length
            target = max(min(bucket_len(s), self.max_len - vis), s)
        pad = target - s
        toks = np.concatenate(
            [np.asarray(req.tokens, np.int32),
             np.zeros((pad,), np.int32)])[None]
        # plain numpy: the backend's jitted prefill places these however
        # its execution strategy requires
        batch = {"tokens": toks}
        if req.patches is not None:
            batch["patches"] = np.asarray(req.patches, np.float32)[None]
        return batch

    # ------------------------------------------------------------------
    # the step loop
    # ------------------------------------------------------------------
    def _admit(self) -> list[tuple[int, int, bool]]:
        events = []
        while self.pool.free_slots:
            req = self.scheduler.next_request(self.pool.active_slots)
            if req is None:
                break
            batch = self._make_batch(req)
            length = req.prompt_len
            tok, cache = self.backend.prefill(batch, length)
            req.first_token_s = self.clock()
            req.status = RUNNING
            req.emit(int(tok))
            if req.finished_by(int(tok)):
                self._finish(req)        # 1-token request: never lands
                events.append((req.rid, int(tok), True))
                continue
            events.append((req.rid, int(tok), False))
            slot = self.pool.alloc()
            self.pool.insert(cache, slot)
            req.slot = slot
            self._slot_req[slot] = req
            self._slot_prefill_len[slot] = length
            self._slot_total_len[slot] = length
            self._tok[slot, 0] = int(tok)
            self._pos[slot] = length
            self._active[slot] = True
        return events

    def _finish(self, req: Request):
        req.status = FINISHED
        req.finish_s = self.clock()
        self.finished.append(req)

    def _retire(self, slot: int):
        req = self._slot_req[slot]
        self._finish(req)
        self._slot_req[slot] = None
        self._active[slot] = False
        req.slot = -1
        self.pool.free(slot)

    def step(self) -> list[tuple[int, int, bool]]:
        """Admit + decode one token on every active slot. Returns streamed
        events: (rid, token, done)."""
        events = self._admit()
        if not self._active.any():
            return events
        ntoks, self.pool.state = self.backend.decode_step(
            self._tok, self.pool.state, self._pos, self._active)
        ntoks = np.asarray(ntoks)
        for slot in np.nonzero(self._active)[0]:
            req = self._slot_req[slot]
            tok = int(ntoks[slot])
            req.emit(tok)
            self._pos[slot] += 1
            self._slot_total_len[slot] += 1
            self._tok[slot, 0] = tok
            done = req.finished_by(tok)
            events.append((req.rid, tok, done))
            if done:
                self._retire(int(slot))
        return events

    def run(self, requests=None, max_steps: int | None = None
            ) -> list[Request]:
        """Drain: submit ``requests`` (if given) and step until queue and
        slots are empty. Returns the finished requests in completion
        order."""
        for r in requests or ():
            self.submit(r)
        start = len(self.finished)
        steps = 0
        while self.scheduler.pending or self.pool.active_slots:
            self.step()
            steps += 1
            if max_steps is not None and steps > max_steps:
                raise RuntimeError(f"engine did not drain in {max_steps} "
                                   f"steps")
        return self.finished[start:]

    # ------------------------------------------------------------------
    # reports
    # ------------------------------------------------------------------
    def endurance_report(self) -> dict:
        return self.pool.endurance_report(
            self._slot_prefill_len, self._slot_total_len,
            self.backend.hot_window)
