"""Continuous-batching engine driven by per-step work plans: chunked
prefill through the unified `extend_step` + one backend decode step.

Step anatomy (one `Engine.step()` call):

  1. plan — the scheduler emits a `StepPlan`: under the step's token
     budget (decode slots take one token each), the in-flight prompt
     advances by prefill chunks of at most ``chunk_tokens`` positions,
     and the FCFS queue head is admitted (slot + DRAM/RRAM byte budgets
     permitting) once the previous prompt committed. Under pressure the
     plan may first PREEMPT: a strictly higher-priority waiter evicts
     the lowest-priority running victim's KV state into an RRAM spill
     lane (`backend.evict_slot`, verbatim image + endurance-counter
     bump), and spilled requests restore bit-exactly into freed slots
     (`backend.restore_slot`) so resumed decode is token-for-token
     identical to a never-evicted run;
  2. prefill chunks — each chunk is ONE `backend.extend_step` call that
     extends the in-flight request's chunk-resumable state; the final
     (``commit``) chunk folds it into the already-allocated pool slot and
     yields the request's first greedy token. A VQA prompt's visual span
     is chunked in patch space and its text tail in token space, split at
     the modality boundary;
  3. decode — ONE backend call advances every active slot:
     `backend.decode_step` runs the jitted per-slot decode (vmapped
     locally, pjit-sharded on a mesh). Slot shapes are static; the
     backend compiles once per chunk shape;
  4. retire — slots whose request hit EOS or max_new_tokens are freed
     for recycling; inactive slots' cache writes are masked out, so
     endurance counters only ever reflect real occupancies.

With the default knobs (no token budget, no chunk cap) a prompt prefills
in one chunk and the engine reproduces the PR 1/2 admit-whole-prompt
loop token-for-token. With a budget, long vision prompts no longer stall
every decode slot for the full prompt duration — decode slots keep
emitting between chunks (Sarathi-style chunked prefill), which is what
bounds TBT on the paper's multimodal workloads.

The engine is execution-agnostic: it talks to an
`serving.backend.InferenceBackend` and a model-free `TieredKVPool`, so
scheduling, metrics and the endurance audit run unmodified on the local
vmapped backend and the pjit-sharded one. Greedy decoding (matches
`launch.serve.generate`); tokens stream to each request's ``on_token``
callback as they are produced.
"""

from __future__ import annotations

import dataclasses
import inspect
import os
import time
import warnings

import numpy as np

from repro.serving.backend import InferenceBackend, LocalBackend
from repro.serving.request import FINISHED, PREEMPTED, RUNNING, Request
from repro.serving.scheduler import (CapacityBudget, FCFSScheduler,
                                     PrefillChunk, StepPlan)
from repro.serving.telemetry import NullTelemetry
from repro.simulator.hardware import CHIME


def bucket_len(n: int, minimum: int = 8) -> int:
    """Next power of two >= n (>= minimum): bounds jit retraces to
    O(log max_prompt) prefill shapes."""
    b = minimum
    while b < n:
        b *= 2
    return b


def _env_int(name: str) -> int | None:
    """Env knob: a positive int enables, an explicit 0 disables (returned
    as 0 so it is distinguishable from unset), empty/absent returns None;
    anything else is ignored with a warning (an env var should never
    wedge startup)."""
    raw = os.environ.get(name, "").strip()
    if not raw:
        return None
    try:
        v = int(raw)
    except ValueError:
        warnings.warn(f"ignoring non-integer {name}={raw!r}")
        return None
    if v < 0:
        warnings.warn(f"ignoring negative {name}={v}")
        return None
    return v


def _env_float(name: str) -> float | None:
    """Float env knob with the same sanitation contract as `_env_int`."""
    raw = os.environ.get(name, "").strip()
    if not raw:
        return None
    try:
        v = float(raw)
    except ValueError:
        warnings.warn(f"ignoring non-numeric {name}={raw!r}")
        return None
    if v < 0:
        warnings.warn(f"ignoring negative {name}={v}")
        return None
    return v


@dataclasses.dataclass
class _Inflight:
    """The one prompt currently prefilling: its pool slot is already
    allocated (it pins the byte budgets) and ``ext`` carries the
    chunk-resumable state between extend calls."""
    req: Request
    slot: int
    pos: int
    ext: dict


@dataclasses.dataclass
class _SpillRec:
    """Host-side resume state of one preempted request: which RRAM lane
    holds its packed cache image, and the decode-loop scalars
    (position, last emitted token, occupancy lengths for the endurance
    audit) that restore re-pins to a slot."""
    lane: int
    pos: int
    tok: int
    prefill_len: int
    total_len: int


class Engine:
    """Continuous-batching serving engine over an InferenceBackend.

    ``chunk_tokens`` caps a single prefill chunk and ``token_budget``
    caps the total tokens per step (decode slots included); both default
    to the ``REPRO_SERVE_CHUNK_TOKENS`` / ``REPRO_SERVE_TOKEN_BUDGET``
    env knobs, then to None (whole-prompt chunks — the pre-StepPlan
    behavior). When only ``chunk_tokens`` is set, the budget defaults to
    ``chunk_tokens + num_slots`` (one chunk plus all decode slots per
    step). ``oversubscribe`` (>= 1; env ``REPRO_SERVE_OVERSUBSCRIBE``,
    0/None = off) relaxes the scheduler's DRAM admission gate by that
    factor, spill-lane-backed — the Cambricon-LLM/SLIM-style
    spill-to-dense-tier trade for serving beyond DRAM capacity.
    ``idle_offload_steps`` (>= 1; env ``REPRO_SERVE_IDLE_OFFLOAD_STEPS``,
    0/None = off) enables proactive idle cold-KV offload: a blocked
    equal-or-higher-priority waiter may park a runner resident at least
    that many decode steps into an RRAM lane (bit-exact, same machinery
    as preemption) and take its freed DRAM under the base byte gates.
    ``telemetry`` attaches a `serving.telemetry.Telemetry` hub (span
    tracer + tier-traffic ledger + gauges/decision log); None (default)
    installs the no-op `NullTelemetry`."""

    def __init__(self, backend, params=None, num_slots: int | None = None,
                 max_len: int | None = None,
                 scheduler: FCFSScheduler | None = None,
                 platform=CHIME, clock=time.perf_counter,
                 token_budget: int | None = None,
                 chunk_tokens: int | None = None,
                 oversubscribe: float | None = None,
                 idle_offload_steps: int | None = None,
                 telemetry=None):
        if params is not None or num_slots is not None or max_len is not None:
            # one-release compat shim: Engine(model, params, num_slots=,
            # max_len=) builds the local backend the seed engine inlined
            warnings.warn(
                "Engine(model, params, num_slots=..., max_len=...) is "
                "deprecated; build a serving.backend (LocalBackend / "
                "ShardedBackend) and pass Engine(backend) instead",
                DeprecationWarning, stacklevel=2)
            backend = LocalBackend(backend, params, num_slots, max_len)
        self.backend: InferenceBackend = backend
        self.max_len = backend.max_len
        self.clock = clock
        self.pool = backend.make_pool()
        hot_b, cold_b = backend.slot_kv_bytes()
        if chunk_tokens is None:
            chunk_tokens = _env_int("REPRO_SERVE_CHUNK_TOKENS")
        if token_budget is None:
            token_budget = _env_int("REPRO_SERVE_TOKEN_BUDGET")
        if oversubscribe is None:
            env_v = _env_float("REPRO_SERVE_OVERSUBSCRIBE")
            if env_v is not None and env_v != 0 and env_v < 1:
                # env-knob contract: never wedge startup on a bad value
                warnings.warn(f"ignoring REPRO_SERVE_OVERSUBSCRIBE="
                              f"{env_v} < 1")
                env_v = None
            oversubscribe = env_v
        if idle_offload_steps is None:
            idle_offload_steps = _env_int("REPRO_SERVE_IDLE_OFFLOAD_STEPS")
        # 0 is the explicit "disable" sentinel for both knobs (whole
        # prompts / unbounded budget — even when the env knob is set).
        # An explicitly unbounded budget is NOT rebound to the
        # chunk+slots default.
        for name, v in (("chunk_tokens", chunk_tokens),
                        ("token_budget", token_budget)):
            if v is not None and v < 0:
                raise ValueError(f"{name} must be >= 0 or None, got {v}")
        if oversubscribe is not None and oversubscribe != 0 \
                and oversubscribe < 1:
            raise ValueError(f"oversubscribe must be >= 1 (or 0/None to "
                             f"disable), got {oversubscribe}")
        oversubscribe = oversubscribe or None    # 0 = explicit disable
        if idle_offload_steps is not None and idle_offload_steps < 0:
            raise ValueError(f"idle_offload_steps must be >= 0 or None, "
                             f"got {idle_offload_steps}")
        idle_offload_steps = idle_offload_steps or None  # 0 = disable
        explicit_unbounded = token_budget == 0
        chunk_tokens = chunk_tokens or None
        token_budget = token_budget or None
        if (token_budget is None and not explicit_unbounded
                and chunk_tokens is not None):
            token_budget = chunk_tokens + backend.num_slots
        # a PR-2/3-era custom backend predates the spill surface: degrade
        # to preemption-disabled instead of crashing on the missing attr
        n_spill = getattr(backend, "n_spill", 0)
        lane_fn = getattr(backend, "spill_lane_bytes", None)
        lane_b = lane_fn() if callable(lane_fn) else hot_b + cold_b
        if scheduler is None:
            scheduler = FCFSScheduler(CapacityBudget.from_platform(platform),
                                      hot_b, cold_b,
                                      token_budget=token_budget,
                                      chunk_tokens=chunk_tokens,
                                      oversubscribe=oversubscribe,
                                      spill_lanes=n_spill,
                                      idle_offload_steps=idle_offload_steps,
                                      lane_bytes=lane_b)
        elif not isinstance(scheduler, FCFSScheduler) or (
                type(scheduler).plan is not FCFSScheduler.plan):
            pass  # custom planner: it owns its own chunking policy
        else:
            # apply resolved knobs to a provided base-plan scheduler so
            # Engine(..., scheduler=..., chunk_tokens=N) and the
            # REPRO_SERVE_* env forcing are not silently dropped; the
            # scheduler's own explicitly-set knobs win
            if scheduler.chunk_tokens is None and chunk_tokens is not None:
                scheduler.chunk_tokens = chunk_tokens
            if scheduler.token_budget is None and token_budget is not None:
                scheduler.token_budget = token_budget
            if scheduler.oversubscribe is None \
                    and oversubscribe is not None:
                scheduler.oversubscribe = oversubscribe
            if scheduler.spill_lanes is None:
                scheduler.spill_lanes = n_spill
            if scheduler.idle_offload_steps is None \
                    and idle_offload_steps is not None:
                scheduler.idle_offload_steps = idle_offload_steps
            if scheduler.lane_bytes is None:
                scheduler.lane_bytes = lane_b
        self.scheduler = scheduler
        # one-release compat: a PR-3-era custom plan() override that does
        # not accept the preemption kwargs (running/free_lanes) still
        # plans — it just never preempts; warn so it migrates
        try:
            params_ = inspect.signature(type(scheduler).plan).parameters
            self._plan_preemptive = (
                "running" in params_ and "free_lanes" in params_) or any(
                p.kind is p.VAR_KEYWORD for p in params_.values())
        except (TypeError, ValueError):
            self._plan_preemptive = False
        if not self._plan_preemptive:
            warnings.warn(
                "scheduler.plan() does not accept running=/free_lanes=; "
                "the engine will plan without preemption. Accept those "
                "keywords to enable it",
                DeprecationWarning, stacklevel=2)
        # one-release compat: a PR 1/2-era scheduler subclass that
        # overrides next_request (custom admission policy) but not plan()
        # would silently regress to base-class FCFS planning — drive it
        # through a whole-prompt legacy adapter instead (see _plan_legacy)
        self._legacy_sched = (
            type(scheduler).next_request is not FCFSScheduler.next_request
            and type(scheduler).plan is FCFSScheduler.plan)
        if self._legacy_sched:
            warnings.warn(
                "scheduler overrides next_request but not plan(); the "
                "engine will drive it through a whole-prompt admission "
                "adapter (no chunked prefill). Override plan() instead",
                DeprecationWarning, stacklevel=2)
        if scheduler.max_concurrent < 1:
            raise ValueError(
                f"one slot's KV state ({hot_b} hot + {cold_b} cold bytes) "
                f"exceeds the domain budgets; nothing can be admitted")
        # num_slots beyond the byte budgets is allowed but idle: admission
        # is gated per-request by the scheduler, so effective concurrency
        # is min(num_slots, scheduler.max_concurrent)

        # ---- per-slot host state -------------------------------------
        n = backend.num_slots
        self._slot_req: list[Request | None] = [None] * n
        self._tok = np.zeros((n, 1), np.int32)
        self._pos = np.zeros((n,), np.int32)
        self._active = np.zeros((n,), bool)
        # lengths of the CURRENT/LAST occupant (endurance audit input)
        self._slot_prefill_len = [0] * n
        self._slot_total_len = [0] * n
        self._inflight: _Inflight | None = None
        self._spilled: dict[int, _SpillRec] = {}    # rid -> resume state
        self.finished: list[Request] = []
        self._next_rid = 0
        self.stats = {"steps": 0, "prefill_chunks": 0, "extend_calls": 0,
                      "decode_steps": 0, "decode_tokens": 0,
                      "evictions": 0, "restores": 0, "idle_offloads": 0}

        # ---- telemetry (opt-in; None = no-op hooks, <2% contract) ----
        self.telemetry = telemetry if telemetry is not None \
            else NullTelemetry()
        if self.telemetry.enabled:
            ctx_fn = getattr(backend, "sim_context", None)
            t_cfg, t_comp = ctx_fn() if callable(ctx_fn) else (None, False)
            self.telemetry.bind(cfg=t_cfg, spill_compressed=t_comp,
                                clock=self.clock, platform=platform,
                                on_snapshot=self.endurance_report)
            # the scheduler logs decision codes through the same hub; a
            # user-built scheduler that already carries one keeps it
            if getattr(self.scheduler, "telemetry", None) is None:
                try:
                    self.scheduler.telemetry = self.telemetry
                except AttributeError:
                    pass                       # __slots__ scheduler

    # ------------------------------------------------------------------
    # request intake
    # ------------------------------------------------------------------
    def submit(self, req: Request) -> Request:
        if req.total_len > self.max_len:
            raise ValueError(
                f"request needs {req.total_len} positions > pool max_len "
                f"{self.max_len}")
        if req.rid is None or req.rid < 0:
            req.rid = self._next_rid
        self._next_rid = max(self._next_rid, req.rid + 1)
        req.arrival_s = self.clock()
        self.scheduler.submit(req)
        self.telemetry.request_submitted(req)
        return req

    # ------------------------------------------------------------------
    # prefill chunks
    # ------------------------------------------------------------------
    def _pad_target(self, valid: int, pos: int) -> int:
        """Chunk padding width: exact for recurrent architectures (padded
        rows would corrupt the carried states), the fixed chunk cap when
        chunking (one trace per modality), else the admission bucket (the
        seed's O(log max_prompt) trace bound). Never pads past the slot
        length so the workspace write stays in bounds."""
        if self.backend.requires_exact_prefill:
            return valid
        cap = getattr(self.scheduler, "chunk_tokens", None)
        if cap:
            return max(valid, min(cap, self.max_len - pos))
        return max(min(bucket_len(valid), self.max_len - pos), valid)

    def _chunk_batch(self, req: Request, kind: str, a: int, b: int,
                     pos: int) -> tuple[dict, int]:
        """Batch for the chunk covering absolute positions [a, b) of the
        prompt, single-modality by construction (``kind``). Right-pads to
        `_pad_target`; padded rows' K/V land beyond the chunk's valid
        length where they are never attendable and are overwritten by the
        next chunk."""
        valid = b - a
        target = self._pad_target(valid, pos)
        if kind == "patches":
            part = np.asarray(req.patches[a:b], np.float32)
            if target > valid:
                part = np.concatenate(
                    [part, np.zeros((target - valid,) + part.shape[1:],
                                    np.float32)])
            return {"patches": part[None]}, valid
        vis = 0 if req.patches is None else int(req.patches.shape[0])
        part = np.asarray(req.tokens[a - vis:b - vis], np.int32)
        if target > valid:
            part = np.concatenate(
                [part, np.zeros((target - valid,), np.int32)])
        return {"tokens": part[None]}, valid

    def _run_chunk(self, ch: PrefillChunk) -> list[tuple[int, int, bool]]:
        """Execute one planned chunk: allocate the slot on admission,
        split at the patch/text modality boundary, run the extend calls,
        and stream the first token when the prompt commits."""
        if ch.admit:
            slot = self.pool.alloc()
            self._inflight = _Inflight(req=ch.req, slot=slot, pos=0,
                                       ext=self.backend.fresh_extend())
            ch.req.admit_s = self.clock()
            self.telemetry.request_admitted(ch.req, slot)
        fl = self._inflight
        assert fl is not None and fl.req is ch.req and fl.pos == ch.start
        req = ch.req
        vis = 0 if req.patches is None else int(req.patches.shape[0])
        end = ch.start + ch.length
        parts: list[tuple[str, int, int]] = []
        if ch.start < vis:
            parts.append(("patches", ch.start, min(end, vis)))
        if end > vis:
            parts.append(("tokens", max(ch.start, vis), end))
        tok = None
        for i, (kind, a, b) in enumerate(parts):
            commit = ch.commit and i == len(parts) - 1
            batch, valid = self._chunk_batch(req, kind, a, b, fl.pos)
            tok, ext, state = self.backend.extend_step(
                batch, self.pool.state, fl.ext, fl.slot, fl.pos, valid,
                commit)
            if commit:
                self.pool.state = state
            else:
                fl.ext = ext
            fl.pos += valid
            self.stats["extend_calls"] += 1
        self.stats["prefill_chunks"] += 1
        if not ch.commit:
            return []
        return self._commit(fl, int(tok))

    def _commit(self, fl: _Inflight, tok: int
                ) -> list[tuple[int, int, bool]]:
        req, slot = fl.req, fl.slot
        self._inflight = None
        tel = self.telemetry
        tel.phase_begin("commit")
        req.first_token_s = self.clock()
        req.status = RUNNING
        req.emit(tok)
        req.token_times.append(self.clock())
        tel.request_first_token(req)
        # the slot's cache now holds this request's stores either way;
        # record its occupancy so the endurance audit stays truthful
        self._slot_prefill_len[slot] = req.prompt_len
        self._slot_total_len[slot] = req.prompt_len
        if req.finished_by(tok):
            self._finish(req)            # 1-token request: retires at once
            self.pool.free(slot)
            tel.phase_end(rid=req.rid)
            return [(req.rid, tok, True)]
        req.slot = slot
        req.resident_steps = 0           # fresh residency (offload clock)
        self._slot_req[slot] = req
        self._tok[slot, 0] = tok
        self._pos[slot] = req.prompt_len
        self._active[slot] = True
        tel.phase_end(rid=req.rid)
        return [(req.rid, tok, False)]

    # ------------------------------------------------------------------
    # the step loop
    # ------------------------------------------------------------------
    def _finish(self, req: Request):
        req.status = FINISHED
        req.finish_s = self.clock()
        self.finished.append(req)
        self.telemetry.request_finished(req)

    def _retire(self, slot: int):
        req = self._slot_req[slot]
        self._finish(req)
        self._slot_req[slot] = None
        self._active[slot] = False
        req.slot = -1
        self.pool.free(slot)

    # ------------------------------------------------------------------
    # preemption: spill to RRAM / bit-exact restore
    # ------------------------------------------------------------------
    def _evict(self, req: Request, offload: bool = False):
        """Pack ``req``'s slot into a free RRAM spill lane and park it.
        The image is the slot's cache verbatim (plus the decode-loop
        scalars recorded host-side), so the later restore resumes decode
        token-for-token identically to a never-evicted run — unless the
        backend compresses spill lanes, in which case the hot ring pays
        the documented codec error. ``offload`` marks a proactive idle
        cold-KV offload (capacity) rather than a preemption (priority);
        the mechanics are identical, only the stats differ."""
        slot = req.slot
        assert slot >= 0 and self._slot_req[slot] is req \
            and self._active[slot]
        lane = self.pool.alloc_lane()
        ctx = int(self._pos[slot])
        self.pool.state = self.backend.evict_slot(self.pool.state, slot,
                                                  lane, ctx)
        self._spilled[req.rid] = _SpillRec(
            lane=lane, pos=ctx, tok=int(self._tok[slot, 0]),
            prefill_len=self._slot_prefill_len[slot],
            total_len=self._slot_total_len[slot])
        req.status = PREEMPTED
        req.slot = -1
        req.evict_times.append(self.clock())
        req.evict_ctx.append(ctx)
        req.n_idle_offloads += 1 if offload else 0
        self._slot_req[slot] = None
        self._active[slot] = False
        self.pool.free(slot)
        self.stats["idle_offloads" if offload else "evictions"] += 1
        self.telemetry.request_evicted(req, slot, lane, ctx, offload)

    def _restore(self, req: Request):
        """Scatter ``req``'s spill lane back into a (possibly different)
        free slot and rejoin decode at the exact position it left."""
        rec = self._spilled.pop(req.rid)
        slot = self.pool.alloc()
        self.pool.state = self.backend.restore_slot(self.pool.state,
                                                    rec.lane, slot)
        self.pool.release_lane(rec.lane)
        req.status = RUNNING
        req.slot = slot
        req.resident_steps = 0           # restored: a fresh time slice
        req.restore_times.append(self.clock())
        self._slot_req[slot] = req
        self._tok[slot, 0] = rec.tok
        self._pos[slot] = rec.pos
        self._active[slot] = True
        self._slot_prefill_len[slot] = rec.prefill_len
        self._slot_total_len[slot] = rec.total_len
        self.stats["restores"] += 1
        self.telemetry.request_restored(req, rec.lane, slot, rec.pos)

    def _plan_legacy(self):
        """Whole-prompt StepPlan through a subclass's next_request
        (PR 1/2 admission semantics; no chunking)."""
        chunks = []
        free = self.pool.free_slots
        active = self.pool.active_slots
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            while free > 0:
                req = self.scheduler.next_request(active)
                if req is None:
                    break
                chunks.append(PrefillChunk(req, True, 0, req.prompt_len,
                                           True))
                free -= 1
                active += 1
        return StepPlan(chunks=tuple(chunks),
                        decode=bool(self._active.any()) or bool(chunks))

    def step(self) -> list[tuple[int, int, bool]]:
        """Execute one StepPlan: spill evictions, restores, prefill
        chunks, then one decode token on every active slot. Returns
        streamed events: (rid, token, done).

        A plan is a commitment, not a peek: producing it pops admitted
        requests off the scheduler queue (and moves evicted/restored
        requests between the running and spilled sets), and this method
        executes every entry in it before decoding."""
        events: list[tuple[int, int, bool]] = []
        fl = self._inflight
        tel = self.telemetry
        tel.step_begin(self.stats["steps"])
        tel.phase_begin("plan")
        if self._legacy_sched:
            plan = self._plan_legacy()
        else:
            kwargs = {}
            if self._plan_preemptive:
                kwargs = dict(
                    running=tuple(r for r in self._slot_req
                                  if r is not None),
                    free_lanes=self.pool.free_lanes)
            plan = self.scheduler.plan(
                active_slots=self.pool.active_slots,
                decode_slots=int(self._active.sum()),
                free_slots=self.pool.free_slots,
                inflight=None if fl is None else (fl.req, fl.pos),
                chunk_unit=self.backend.chunk_unit, **kwargs)
        evictions = tuple(getattr(plan, "evictions", ()))
        offloads = tuple(getattr(plan, "offloads", ()))
        restores = tuple(getattr(plan, "restores", ()))
        tel.phase_end(chunks=len(plan.chunks))
        tel.phase_begin("evict")
        for req in evictions:
            self._evict(req)
        tel.phase_end(count=len(evictions))
        tel.phase_begin("idle-offload")
        for req in offloads:
            self._evict(req, offload=True)
        tel.phase_end(count=len(offloads))
        tel.phase_begin("restore")
        for req in restores:
            self._restore(req)
        tel.phase_end(count=len(restores))
        tel.phase_begin("chunk-prefill")
        for ch in plan.chunks:
            events.extend(self._run_chunk(ch))
        tel.phase_end(count=len(plan.chunks))
        self.stats["steps"] += 1
        # plan.decode is the planner's say (a custom planner may dedicate
        # a step to prefill); _active is the physical guard
        if not plan.decode or not self._active.any():
            tel.step_end(self._gauges() if tel.enabled else None)
            return events
        tel.phase_begin("decode")
        ntoks, self.pool.state = self.backend.decode_step(
            self._tok, self.pool.state, self._pos, self._active)
        ntoks = np.asarray(ntoks)
        self.stats["decode_steps"] += 1
        n_emitted = 0
        for slot in np.nonzero(self._active)[0]:
            req = self._slot_req[slot]
            tok = int(ntoks[slot])
            req.emit(tok)
            req.token_times.append(self.clock())
            tel.token(req)
            req.resident_steps += 1
            self._pos[slot] += 1
            self._slot_total_len[slot] += 1
            self._tok[slot, 0] = tok
            self.stats["decode_tokens"] += 1
            n_emitted += 1
            done = req.finished_by(tok)
            events.append((req.rid, tok, done))
            if done:
                self._retire(int(slot))
        tel.phase_end(count=n_emitted)
        tel.step_end(self._gauges() if tel.enabled else None)
        return events

    def _gauges(self) -> dict:
        """Occupancy/queue snapshot for the telemetry hub (built only
        when telemetry is enabled)."""
        queue = getattr(self.scheduler, "_queue", ())
        depth: dict[int, int] = {}
        for r in queue:
            depth[r.priority] = depth.get(r.priority, 0) + 1
        return {
            "slots_total": self.backend.num_slots,
            "slots_active": self.pool.active_slots,
            "slots_free": self.pool.free_slots,
            "slots_decoding": int(self._active.sum()),
            "lanes_free": self.pool.free_lanes,
            "spilled_requests": len(self._spilled),
            "inflight": 0 if self._inflight is None else 1,
            "queue_depth": depth,
        }

    @property
    def idle(self) -> bool:
        """True when nothing is queued, prefilling, decoding or parked
        in the spill store."""
        return not (self.scheduler.pending or self.pool.active_slots
                    or self._inflight is not None or self._spilled)

    def run(self, requests=None, max_steps: int | None = None
            ) -> list[Request]:
        """Drain: submit ``requests`` (if given) and step until queue,
        in-flight prefill and slots are empty. Returns the finished
        requests in completion order. Raises once ``max_steps`` steps
        have run without draining."""
        for r in requests or ():
            self.submit(r)
        start = len(self.finished)
        steps = 0
        while not self.idle:
            if max_steps is not None and steps >= max_steps:
                raise RuntimeError(f"engine did not drain in {max_steps} "
                                   f"steps")
            self.step()
            steps += 1
        return self.finished[start:]

    # ------------------------------------------------------------------
    # reports
    # ------------------------------------------------------------------
    def endurance_report(self) -> dict:
        rep = self.pool.endurance_report(
            self._slot_prefill_len, self._slot_total_len,
            self.backend.hot_window)
        rep["spills"] = self.stats["evictions"] \
            + self.stats["idle_offloads"]
        rep["preemptions"] = self.stats["evictions"]
        rep["idle_offloads"] = self.stats["idle_offloads"]
        rep["restores"] = self.stats["restores"]
        return rep
