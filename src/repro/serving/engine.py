"""Continuous-batching engine: interleaved prefill admission + one jitted
decode step over all slots.

Step anatomy (one `Engine.step()` call):

  1. admission — while a slot is free AND the FCFS scheduler's capacity
     budgets admit another resident request, prefill the queue head
     (right-padded to a shape bucket so jit reuses traces) and overwrite a
     pool slot with its fresh per-request tiered cache;
  2. decode — ONE jitted call advances every slot: the per-slot decode is
     the ordinary `Model.decode_step` vmapped over the slot axis, so each
     slot attends its own hot ring + cold tier at its own position. Slot
     shapes are static; jit compiles once per engine.
  3. retire — slots whose request hit EOS or max_new_tokens are freed for
     recycling; inactive slots' cache writes are masked out, so endurance
     counters only ever reflect real occupancies.

Greedy decoding (matches `launch.serve.generate`); tokens stream to each
request's ``on_token`` callback as they are produced.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving import kv_pool as KVP
from repro.serving.kv_pool import TieredKVPool, slot_kv_bytes
from repro.serving.request import FINISHED, RUNNING, Request
from repro.serving.scheduler import CapacityBudget, FCFSScheduler
from repro.simulator.hardware import CHIME


def bucket_len(n: int, minimum: int = 8) -> int:
    """Next power of two >= n (>= minimum): bounds jit retraces to
    O(log max_prompt) prefill shapes."""
    b = minimum
    while b < n:
        b *= 2
    return b


class Engine:
    """Continuous-batching serving engine over a TieredKVPool."""

    def __init__(self, model, params, num_slots: int, max_len: int,
                 scheduler: FCFSScheduler | None = None,
                 platform=CHIME, clock=time.perf_counter):
        cfg = model.cfg
        if cfg.is_encoder:
            raise ValueError("encoder-only model cannot be served")
        if num_slots < 1:
            raise ValueError("engine needs at least one decode slot")
        self.model = model
        self.params = params
        self.max_len = max_len
        self.clock = clock
        self.pool = TieredKVPool(model, num_slots, max_len)
        hot_b, cold_b = slot_kv_bytes(model, max_len)
        if scheduler is None:
            scheduler = FCFSScheduler(CapacityBudget.from_platform(platform),
                                      hot_b, cold_b)
        self.scheduler = scheduler
        if scheduler.max_concurrent < 1:
            raise ValueError(
                f"one slot's KV state ({hot_b} hot + {cold_b} cold bytes) "
                f"exceeds the domain budgets; nothing can be admitted")
        # num_slots beyond the byte budgets is allowed but idle: admission
        # is gated per-request by the scheduler, so effective concurrency
        # is min(num_slots, scheduler.max_concurrent)
        # recurrent (SSM) prefill states are cumulative over the whole
        # padded sequence, so those architectures need exact-length prefill
        self._exact_prefill = any(
            u.block.mixer in ("rwkv6", "mamba2") for u in model.plan)

        # ---- per-slot host state -------------------------------------
        self._slot_req: list[Request | None] = [None] * num_slots
        self._tok = np.zeros((num_slots, 1), np.int32)
        self._pos = np.zeros((num_slots,), np.int32)
        self._active = np.zeros((num_slots,), bool)
        # lengths of the CURRENT/LAST occupant (endurance audit input)
        self._slot_prefill_len = [0] * num_slots
        self._slot_total_len = [0] * num_slots
        self.finished: list[Request] = []
        self._next_rid = 0

        # ---- jitted programs -----------------------------------------
        axes = self.pool.axes

        def slot_step(p, tok, cache, pos):
            c1 = KVP.tree_expand(cache, axes)
            logits, nc = model.decode_step(p, tok[None], c1, pos)
            ntok = jnp.argmax(logits[0, -1], -1).astype(jnp.int32)
            return ntok, KVP.tree_squeeze(nc, axes)

        vm = jax.vmap(slot_step, in_axes=(None, 0, axes, 0),
                      out_axes=(0, axes))

        def step(p, toks, cache, pos, active):
            ntoks, nc = vm(p, toks, cache, pos)

            def sel(n, o, a):
                shp = [1] * n.ndim
                shp[a] = n.shape[a]
                return jnp.where(active.reshape(shp), n, o)

            # inactive slots keep their old cache verbatim: no phantom
            # appends, no endurance-counter drift while a slot is parked
            return ntoks, jax.tree.map(sel, nc, cache, axes)

        self._step = jax.jit(step)

        def prefill(p, batch, length):
            logits, cache = model.prefill(p, batch, max_len, length)
            tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
            return tok[0], cache

        self._prefill = jax.jit(prefill)

    # ------------------------------------------------------------------
    # request intake
    # ------------------------------------------------------------------
    def submit(self, req: Request) -> Request:
        if req.total_len > self.max_len:
            raise ValueError(
                f"request needs {req.total_len} positions > pool max_len "
                f"{self.max_len}")
        if req.rid is None or req.rid < 0:
            req.rid = self._next_rid
        self._next_rid = max(self._next_rid, req.rid + 1)
        req.arrival_s = self.clock()
        self.scheduler.submit(req)
        return req

    def _make_batch(self, req: Request) -> dict:
        s = int(req.tokens.shape[0])
        vis = 0 if req.patches is None else int(req.patches.shape[0])
        if self._exact_prefill:
            target = s
        else:
            # bucket the text tail, but never pad the prefill sequence
            # (visual tokens + text) past the pool's slot length
            target = max(min(bucket_len(s), self.max_len - vis), s)
        pad = target - s
        toks = np.concatenate(
            [np.asarray(req.tokens, np.int32),
             np.zeros((pad,), np.int32)])[None]
        batch = {"tokens": jnp.asarray(toks)}
        if req.patches is not None:
            batch["patches"] = jnp.asarray(
                np.asarray(req.patches,
                           np.float32)[None])
        return batch

    # ------------------------------------------------------------------
    # the step loop
    # ------------------------------------------------------------------
    def _admit(self) -> list[tuple[int, int, bool]]:
        events = []
        while self.pool.free_slots:
            req = self.scheduler.next_request(self.pool.active_slots)
            if req is None:
                break
            batch = self._make_batch(req)
            length = req.prompt_len
            tok, cache = self._prefill(self.params, batch,
                                       jnp.asarray(length, jnp.int32))
            req.first_token_s = self.clock()
            req.status = RUNNING
            req.emit(int(tok))
            if req.finished_by(int(tok)):
                self._finish(req)        # 1-token request: never lands
                events.append((req.rid, int(tok), True))
                continue
            events.append((req.rid, int(tok), False))
            slot = self.pool.alloc()
            self.pool.insert(cache, slot)
            req.slot = slot
            self._slot_req[slot] = req
            self._slot_prefill_len[slot] = length
            self._slot_total_len[slot] = length
            self._tok[slot, 0] = int(tok)
            self._pos[slot] = length
            self._active[slot] = True
        return events

    def _finish(self, req: Request):
        req.status = FINISHED
        req.finish_s = self.clock()
        self.finished.append(req)

    def _retire(self, slot: int):
        req = self._slot_req[slot]
        self._finish(req)
        self._slot_req[slot] = None
        self._active[slot] = False
        req.slot = -1
        self.pool.free(slot)

    def step(self) -> list[tuple[int, int, bool]]:
        """Admit + decode one token on every active slot. Returns streamed
        events: (rid, token, done)."""
        events = self._admit()
        if not self._active.any():
            return events
        ntoks, self.pool.cache = self._step(
            self.params, jnp.asarray(self._tok), self.pool.cache,
            jnp.asarray(self._pos), jnp.asarray(self._active))
        ntoks = np.asarray(ntoks)
        for slot in np.nonzero(self._active)[0]:
            req = self._slot_req[slot]
            tok = int(ntoks[slot])
            req.emit(tok)
            self._pos[slot] += 1
            self._slot_total_len[slot] += 1
            self._tok[slot, 0] = tok
            done = req.finished_by(tok)
            events.append((req.rid, tok, done))
            if done:
                self._retire(int(slot))
        return events

    def run(self, requests=None, max_steps: int | None = None
            ) -> list[Request]:
        """Drain: submit ``requests`` (if given) and step until queue and
        slots are empty. Returns the finished requests in completion
        order."""
        for r in requests or ():
            self.submit(r)
        start = len(self.finished)
        steps = 0
        while self.scheduler.pending or self.pool.active_slots:
            self.step()
            steps += 1
            if max_steps is not None and steps > max_steps:
                raise RuntimeError(f"engine did not drain in {max_steps} "
                                   f"steps")
        return self.finished[start:]

    # ------------------------------------------------------------------
    # reports
    # ------------------------------------------------------------------
    def endurance_report(self) -> dict:
        W = min(self.model.cfg.kv_hot_window, self.max_len)
        return self.pool.endurance_report(
            self._slot_prefill_len, self._slot_total_len, W)
