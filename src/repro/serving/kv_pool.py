"""Slot-indexed multi-request KV pool over the CHIME tiered stores.

The pool is the model's ordinary decode cache (`Model.init_cache`) with the
batch axis reinterpreted as *serving slots*: slot s holds the tiered
DRAM-hot / RRAM-cold KV state of whichever request currently occupies it.
Slot admission overwrites the slot with a freshly prefilled per-request
cache — including its per-slot endurance counters, which is what preserves
the writes<=1-per-cold-slot RRAM discipline across slot recycling.

Cache pytree layout (from Model.init_cache): per scan-unit subtrees whose
leaves carry the slot axis at position 0, or 1 for scanned units (leading
layer-repeat axis). `batch_axes` materializes that axis index per leaf so
insert/reset/vmap all address the slot dimension uniformly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import kv_tiers as KT


def batch_axes(model, cache: dict) -> dict:
    """Tree matching ``cache`` whose leaves give the slot-axis index."""
    axes = {}
    for ui, unit in enumerate(model.plan):
        a = 1 if unit.repeats > 1 else 0
        axes[f"u{ui}"] = jax.tree.map(lambda _: a, cache[f"u{ui}"])
    return axes


def tree_expand(tree: dict, axes: dict) -> dict:
    """Re-insert a size-1 slot axis (inside a vmap body)."""
    return jax.tree.map(lambda l, a: jnp.expand_dims(l, a), tree, axes)


def tree_squeeze(tree: dict, axes: dict) -> dict:
    return jax.tree.map(lambda l, a: jnp.squeeze(l, axis=a), tree, axes)


def slot_kv_bytes(model, max_len: int) -> tuple[int, int]:
    """(dram_hot_bytes, rram_cold_bytes) of ONE slot's cache.

    Hot ring, flat stores and SSM states live in the DRAM domain; the int8
    cold tier (+ its scales) is the RRAM budget. Endurance counters are
    bookkeeping, not capacity.
    """
    shapes, _ = model.cache_spec(1, max_len)
    hot = cold = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        nbytes = 1
        for d in leaf.shape:
            nbytes *= d
        nbytes *= jnp.dtype(leaf.dtype).itemsize
        if key == "writes":
            continue
        if key in ("cold_q", "cold_scale"):
            cold += nbytes
        else:
            hot += nbytes
    return hot, cold


class TieredKVPool:
    """Fixed set of decode slots over a shared tiered cache pytree."""

    def __init__(self, model, num_slots: int, max_len: int):
        self.model = model
        self.num_slots = num_slots
        self.max_len = max_len
        self.cache = model.init_cache(num_slots, max_len)
        self.axes = batch_axes(model, self.cache)
        self._zero_slot = model.init_cache(1, max_len)
        self._free = list(range(num_slots))

        def _insert(pool, req_cache, slot):
            return jax.tree.map(
                lambda p, r, a: jax.lax.dynamic_update_slice_in_dim(
                    p, r.astype(p.dtype), slot, axis=a),
                pool, req_cache, self.axes)

        self._insert = jax.jit(_insert)

    # ---- slot bookkeeping (host side) --------------------------------
    @property
    def free_slots(self) -> int:
        return len(self._free)

    @property
    def active_slots(self) -> int:
        return self.num_slots - len(self._free)

    def alloc(self) -> int:
        return self._free.pop(0)

    def free(self, slot: int):
        assert 0 <= slot < self.num_slots and slot not in self._free
        self._free.append(slot)
        self._free.sort()

    # ---- cache ops ---------------------------------------------------
    def insert(self, req_cache: dict, slot):
        """Overwrite slot ``slot`` with a batch-1 per-request cache (this
        is also the endurance-counter reset on recycling)."""
        self.cache = self._insert(self.cache, req_cache,
                                  jnp.asarray(slot, jnp.int32))

    def reset(self, slot):
        """Zero a slot (explicit scrub; admission overwrites anyway)."""
        self.insert(self._zero_slot, slot)

    # ---- endurance audit ---------------------------------------------
    def worst_case_writes(self) -> jax.Array | None:
        """Elementwise max of every tiered store's per-slot endurance
        counters -> (num_slots, n_blocks), or None if nothing is tiered."""
        worst = None
        for path, leaf in jax.tree_util.tree_flatten_with_path(
                self.cache)[0]:
            key = path[-1].key if hasattr(path[-1], "key") else ""
            if key != "writes":
                continue
            w = leaf
            if w.ndim == 3:              # (repeats, slots, blocks)
                w = jnp.max(w, axis=0)
            worst = w if worst is None else jnp.maximum(worst, w)
        return worst

    def endurance_report(self, prefill_lens, total_lens,
                         hot_window: int) -> dict:
        """Audit writes<=1-per-cold-slot for the CURRENT occupants.

        ``prefill_lens``/``total_lens``: per-slot token counts of the
        request that last occupied each slot (0 for never-used slots). A
        slot whose counters exceed the analytic expectation for its own
        occupancy was recycled without reset — the RRAM endurance
        violation this report exists to catch.
        """
        worst = self.worst_case_writes()
        if worst is None:
            return {"tiered": False, "write_once_ok": True,
                    "max_writes_per_cold_slot": 0.0}
        nb = worst.shape[1]
        expected = jnp.stack([
            KT.expected_block_writes(nb, hot_window, int(p), int(t))
            for p, t in zip(prefill_lens, total_lens)])
        excess = worst - expected
        ratio = worst / jnp.maximum(expected, 1)
        ratio = jnp.where((expected == 0) & (worst > 0), jnp.inf, ratio)
        return {
            "tiered": True,
            "write_once_ok": bool(jnp.all(excess <= 0)),
            "max_writes_per_cold_slot": float(jnp.max(ratio)),
            "total_cold_writes": int(jnp.sum(worst)),
        }
