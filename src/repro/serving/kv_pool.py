"""Slot-indexed multi-request KV pool state over the CHIME tiered stores.

The pool is the model's ordinary decode cache (`Model.init_cache`) with the
batch axis reinterpreted as *serving slots*: slot s holds the tiered
DRAM-hot / RRAM-cold KV state of whichever request currently occupies it.
Slot admission overwrites the slot with a freshly prefilled per-request
cache — including its per-slot endurance counters, which is what preserves
the writes<=1-per-cold-slot RRAM discipline across slot recycling.

This module is deliberately model-free: the cache layout lives in
`KVPoolState`, an explicit typed pytree (cache tree + static slot-axis
index per leaf), and `TieredKVPool` is pure host-side slot bookkeeping
over that state. The jitted cache arithmetic (insert / decode-step) is
owned by the executing `serving.backend.InferenceBackend`, which is what
lets the same pool run on the single-device vmapped path and on a
pjit-sharded mesh unmodified.

Cache pytree layout (from Model.init_cache): per scan-unit subtrees whose
leaves carry the slot axis at position 0, or 1 for scanned units (leading
layer-repeat axis). `batch_axes` materializes that axis index per leaf so
insert/reset/vmap all address the slot dimension uniformly.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import kv_tiers as KT
from repro.models.counting import kv_elems_per_token, kv_scale_elems_per_token


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class KVPoolState:
    """Explicit pytree of a multi-slot KV pool.

    ``cache``: the slot-batched cache tree (arrays, or ShapeDtypeStructs
    for abstract use). ``axes``: a matching tree of ints giving each
    leaf's slot-axis index — static metadata, so a KVPoolState flows
    through jit/pjit with only the cache (and spill buffers) as traced
    children.

    ``spill``: the RRAM-backed spill store (preemption victims + idle
    cold-KV offloads) — a tree mirroring ``cache`` with the slot axis
    reinterpreted as *spill lanes*, or None until the first eviction
    materializes it (lazy: a pool that never spills never pays for the
    extra copy) or when the backend was built without lanes. A
    compressed-lane backend (`spill_compress`) stores the hot ring in
    int8 codec form, so the spill tree's STRUCTURE differs from the
    cache's — ``spill_axes`` then carries its own slot-axis tree (None
    means the lanes mirror ``cache`` and ``axes`` addresses both).
    ``spill_writes``: (n_lanes, n_endurance_blocks) int32
    cumulative RRAM write counters per lane (see
    `core.kv_tiers.bump_spill_writes`) — unlike the per-slot cache
    counters these never reset, because RRAM wear survives lane
    recycling.

    ``prefix``: the paged prefix-sharing block store (PR 7) — a tree
    shaped like the model's extend state with the batch axis
    reinterpreted as *block ids* and the sequence axis shrunk to
    ``block_tokens``: full-precision workspace K/V rows per block, plus
    per-block recurrent-state snapshots for SSM architectures. Lazy like
    ``spill``: None until the first prefix registration materializes it
    (an engine with paging off never pays the copy). ``prefix_axes``
    carries its block-axis index tree (static aux). Which block holds
    what is host-side state in `serving.block_pool.BlockPool`.
    """

    cache: dict
    axes: dict
    spill: dict | None = None
    spill_writes: jax.Array | None = None
    spill_axes: dict | None = None
    prefix: dict | None = None
    prefix_axes: dict | None = None

    @property
    def num_slots(self) -> int:
        leaf = jax.tree.leaves(self.cache)[0]
        return leaf.shape[jax.tree.leaves(self.axes)[0]]

    @property
    def num_spill_lanes(self) -> int:
        if self.spill is None:
            return 0
        axes = self.axes if self.spill_axes is None else self.spill_axes
        leaf = jax.tree.leaves(self.spill)[0]
        return leaf.shape[jax.tree.leaves(axes)[0]]

    def tree_flatten(self):
        axes_leaves, axes_def = jax.tree_util.tree_flatten(self.axes)
        sp_leaves, sp_def = jax.tree_util.tree_flatten(self.spill_axes)
        px_leaves, px_def = jax.tree_util.tree_flatten(self.prefix_axes)
        return ((self.cache, self.spill, self.spill_writes, self.prefix),
                (tuple(axes_leaves), axes_def, tuple(sp_leaves), sp_def,
                 tuple(px_leaves), px_def))

    @classmethod
    def tree_unflatten(cls, aux, children):
        axes = jax.tree_util.tree_unflatten(aux[1], list(aux[0]))
        spill_axes = jax.tree_util.tree_unflatten(aux[3], list(aux[2]))
        prefix_axes = jax.tree_util.tree_unflatten(aux[5], list(aux[4]))
        cache, spill, spill_writes, prefix = children
        return cls(cache=cache, axes=axes, spill=spill,
                   spill_writes=spill_writes, spill_axes=spill_axes,
                   prefix=prefix, prefix_axes=prefix_axes)


def batch_axes(model, cache: dict) -> dict:
    """Tree matching ``cache`` whose leaves give the slot-axis index."""
    axes = {}
    for ui, unit in enumerate(model.plan):
        a = 1 if unit.repeats > 1 else 0
        axes[f"u{ui}"] = jax.tree.map(lambda _: a, cache[f"u{ui}"])
    return axes


def tree_expand(tree: dict, axes: dict) -> dict:
    """Re-insert a size-1 slot axis (inside a vmap body)."""
    return jax.tree.map(lambda l, a: jnp.expand_dims(l, a), tree, axes)


def tree_squeeze(tree: dict, axes: dict) -> dict:
    return jax.tree.map(lambda l, a: jnp.squeeze(l, axis=a), tree, axes)


def map_spill_stores(tree, fn):
    """Rebuild a cache/spill tree with every TIERED store dict (one
    carrying a hot ring — 'hot', or its compressed 'hot_q' form) passed
    through ``fn``; flat stores and recurrent-state subtrees are left in
    place. This is the structural transform between a slot image and its
    compressed spill-lane form (and between their metadata trees — axis
    indices and shardings transform with `kv_tiers.spill_store_meta`)."""
    if isinstance(tree, dict):
        if "hot" in tree or "hot_q" in tree:
            return fn(tree)
        return {k: map_spill_stores(v, fn) for k, v in tree.items()}
    return tree


# keys of the sequence-store leaves inside a block cache; anything else
# (SSM states, rwkv token-mix state, cm_x_prev) is per-slot fixed-size
# DRAM state
_STORE_KEYS = frozenset({"hot", "cold_q", "cold_scale", "writes", "flat"})


def _charged_len(max_len: int, length: int | None,
                 block_tokens: int | None) -> int:
    """Sequence positions one occupant is CHARGED for.

    ``length=None`` keeps the historical worst case — every resident
    pays ``max_len`` regardless of its actual prompt+generation span.
    With a length, the charge is the request's own span rounded up to
    whole ``block_tokens`` pages (a paged allocator hands out whole
    blocks) and clamped to ``max_len``. This is the ACCOUNTING model the
    admission gate and the capacity bench price — the physical XLA slot
    buffers stay statically ``max_len``-shaped (simulated hardware, like
    every energy number in this repo); the paged engine's point is that
    a real block allocator would only materialize these bytes."""
    if length is None:
        return max_len
    bt = block_tokens or KT.ENDURANCE_BLOCK
    length = max(1, min(int(length), max_len))
    return min(-(-length // bt) * bt, max_len)


def slot_kv_bytes(model, max_len: int, *, length: int | None = None,
                  block_tokens: int | None = None) -> tuple[int, int]:
    """(dram_hot_bytes, rram_cold_bytes) of ONE slot's cache.

    Hot ring, flat stores and SSM states live in the DRAM domain; the int8
    cold tier (+ its scales) is the RRAM budget. Endurance counters are
    bookkeeping, not capacity. The sequence-store sizes derive from
    `models/counting.kv_elems_per_token` — the same per-token element
    count behind the simulator's `kv_bytes_per_token` cost terms — so
    capacity admission and simulated efficiency share one KV byte math.

    ``length`` (with ``block_tokens``) switches from the worst-case
    ``max_len`` residency charge to a live block-granular charge for a
    request of that total span (see `_charged_len`) — what the paged
    admission gate and the capacity bench use so their math agrees with
    what paging actually allocates.
    """
    cfg = model.cfg
    cd = jnp.dtype(cfg.compute_dtype).itemsize
    seq_elems = kv_elems_per_token(cfg)
    L = _charged_len(max_len, length, block_tokens)
    shapes, _ = model.cache_spec(1, max_len)
    state_bytes = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if key in _STORE_KEYS:
            continue
        nbytes = jnp.dtype(leaf.dtype).itemsize
        for d in leaf.shape:
            nbytes *= d
        state_bytes += nbytes
    if cfg.kv_policy == "tiered":
        W = min(cfg.kv_hot_window, L)
        hot = seq_elems * W * cd + state_bytes
        cold = (seq_elems * L * jnp.dtype(jnp.int8).itemsize
                + kv_scale_elems_per_token(cfg) * L
                * jnp.dtype(jnp.float32).itemsize)
    else:
        hot = seq_elems * L * cd + state_bytes
        cold = 0
    return int(hot), int(cold)


def spill_lane_bytes(model, max_len: int, compressed: bool = False, *,
                     length: int | None = None,
                     block_tokens: int | None = None) -> int:
    """RRAM bytes ONE occupied spill lane pins while a request is parked.

    A verbatim lane holds the full slot image (hot + cold halves of
    `slot_kv_bytes`). A compressed lane stores the hot ring in the int8
    codec form — int8 payload + per-(token, head) f32 scales — while the
    cold tier, scales and recurrent states ride verbatim; with a flat
    (untiered) cache there is no hot ring and compression changes
    nothing. This is the byte the scheduler charges against the RRAM
    budget per parked request, and what `n_lanes = budget // lane_bytes`
    sizing should use — the capacity lever compressed lanes exist for.
    ``length``/``block_tokens`` apply the same live block-granular
    charge as `slot_kv_bytes` (a parked short request's image only
    covers its own blocks)."""
    hot, cold = slot_kv_bytes(model, max_len, length=length,
                              block_tokens=block_tokens)
    cfg = model.cfg
    if not compressed or cfg.kv_policy != "tiered":
        return hot + cold
    cd = jnp.dtype(cfg.compute_dtype).itemsize
    W = min(cfg.kv_hot_window,
            _charged_len(max_len, length, block_tokens))
    ring = kv_elems_per_token(cfg) * W * cd
    ring_q = kv_elems_per_token(cfg) * W          # int8 payload
    ring_scale = kv_scale_elems_per_token(cfg) * W \
        * jnp.dtype(jnp.float32).itemsize
    return hot - ring + ring_q + ring_scale + cold


class TieredKVPool:
    """Host-side slot bookkeeping over an explicit `KVPoolState`.

    Model-free by construction: the state layout and the jitted insert
    arithmetic come from the backend (`backend.make_pool()` wires them
    up), so the pool neither knows nor cares whether its arrays live on
    one device or a pjit mesh.
    """

    def __init__(self, state: KVPoolState, insert_fn, fresh_slot_fn,
                 num_spill_lanes: int | None = None):
        self.state = state
        self._insert_fn = insert_fn        # (state, req_cache, slot) -> state
        self._fresh_slot = fresh_slot_fn   # () -> batch-1 zero cache
        self.num_slots = state.num_slots
        self._free = list(range(self.num_slots))
        # spill lanes are reserved here but their arrays materialize
        # lazily (backend.evict_slot, on the first preemption)
        if num_spill_lanes is None:
            num_spill_lanes = state.num_spill_lanes
        self.num_spill_lanes = num_spill_lanes
        self._free_lanes = list(range(self.num_spill_lanes))

    # ---- views -------------------------------------------------------
    @property
    def cache(self) -> dict:
        return self.state.cache

    @property
    def axes(self) -> dict:
        return self.state.axes

    # ---- slot bookkeeping (host side) --------------------------------
    @property
    def free_slots(self) -> int:
        return len(self._free)

    @property
    def active_slots(self) -> int:
        return self.num_slots - len(self._free)

    def alloc(self) -> int:
        return self._free.pop(0)

    def free(self, slot: int):
        assert 0 <= slot < self.num_slots and slot not in self._free
        self._free.append(slot)
        self._free.sort()

    # ---- spill-lane bookkeeping (host side) --------------------------
    @property
    def free_lanes(self) -> int:
        return len(self._free_lanes)

    def alloc_lane(self) -> int:
        return self._free_lanes.pop(0)

    def release_lane(self, lane: int):
        assert 0 <= lane < self.num_spill_lanes \
            and lane not in self._free_lanes
        self._free_lanes.append(lane)
        self._free_lanes.sort()

    # ---- cache ops ---------------------------------------------------
    def insert(self, req_cache: dict, slot):
        """Overwrite slot ``slot`` with a batch-1 per-request cache (this
        is also the endurance-counter reset on recycling)."""
        self.state = self._insert_fn(self.state, req_cache, slot)

    def reset(self, slot):
        """Zero a slot (explicit scrub; admission overwrites anyway)."""
        self.insert(self._fresh_slot(), slot)

    # ---- endurance audit ---------------------------------------------
    def worst_case_writes(self) -> jax.Array | None:
        """Elementwise max of every tiered store's per-slot endurance
        counters -> (num_slots, n_blocks), or None if nothing is tiered."""
        worst = None
        for path, leaf in jax.tree_util.tree_flatten_with_path(
                self.state.cache)[0]:
            key = path[-1].key if hasattr(path[-1], "key") else ""
            if key != "writes":
                continue
            w = leaf
            if w.ndim == 3:              # (repeats, slots, blocks)
                w = jnp.max(w, axis=0)
            worst = w if worst is None else jnp.maximum(worst, w)
        return worst

    def endurance_report(self, prefill_lens, total_lens,
                         hot_window: int) -> dict:
        """Audit writes<=1-per-cold-slot for the CURRENT occupants.

        ``prefill_lens``/``total_lens``: per-slot token counts of the
        request that last occupied each slot (0 for never-used slots). A
        slot whose counters exceed the analytic expectation for its own
        occupancy was recycled without reset — the RRAM endurance
        violation this report exists to catch.

        Spill lanes are reported alongside: their counters are cumulative
        RRAM wear (one write per touched block per spill event, never
        reset on lane recycling). The spill keys are ALWAYS present —
        zero before the lazily-materialized lane arrays exist — so a
        report taken early in a run aggregates identically to one taken
        after the first spill, and ``total_rram_writes`` folds the lane
        writes into the cold-tier total unconditionally.
        """
        worst = self.worst_case_writes()
        if worst is None:
            rep = {"tiered": False, "write_once_ok": True,
                   "max_writes_per_cold_slot": 0.0}
        else:
            nb = worst.shape[1]
            expected = jnp.stack([
                KT.expected_block_writes(nb, hot_window, int(p), int(t))
                for p, t in zip(prefill_lens, total_lens)])
            excess = worst - expected
            ratio = worst / jnp.maximum(expected, 1)
            ratio = jnp.where((expected == 0) & (worst > 0), jnp.inf,
                              ratio)
            rep = {
                "tiered": True,
                "write_once_ok": bool(jnp.all(excess <= 0)),
                "max_writes_per_cold_slot": float(jnp.max(ratio)),
                "total_cold_writes": int(jnp.sum(worst)),
            }
        sw = self.state.spill_writes
        rep["spill_lanes"] = self.num_spill_lanes
        rep["total_spill_writes"] = 0 if sw is None else int(jnp.sum(sw))
        rep["max_spill_writes_per_block"] = \
            0 if sw is None else int(jnp.max(sw))
        rep["total_rram_writes"] = rep.get("total_cold_writes", 0) \
            + rep["total_spill_writes"]
        return rep
