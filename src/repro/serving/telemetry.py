"""Serving telemetry: step-span tracing, a simulated tier-traffic
ledger, and Perfetto + Prometheus export.

Three instruments, one object (`Telemetry`), wired through the engine
and scheduler:

1. **Span tracer** — every engine step emits phase spans (plan / evict /
   idle-offload / restore / chunk-prefill / commit / decode) and every
   request a lifecycle track (submit -> admit -> first-token ->
   preempt/park -> restore -> finish), timed via the engine's injectable
   clock. `chrome_trace()` exports Chrome-trace/Perfetto JSON: one
   timeline lane per KV slot (who occupied it, when), one per RRAM spill
   lane (who was parked), one per request.

2. **Tier-traffic ledger** (`TierLedger`) — per-step counters of DRAM
   hot-ring bytes, RRAM cold-tier reads and spill-lane bytes, priced
   through `chime_sim`'s per-kernel `CostTerm` stream into a cumulative
   DRAM/RRAM/compute energy split. Totals are `math.fsum` over the flat
   term multiset, so on a drained run they reconcile **bit-for-bit**
   with `metrics.simulated_efficiency` (which sums the same terms from
   the finished trace) — the live form of the paper's
   cross-chiplet-traffic claim.

3. **Gauges + decision log** — slot/lane occupancy, per-priority queue
   depth, endurance watermarks, and scheduler admission-denial /
   eviction reason codes (`deny_no_free_slot`, `deny_dram_budget`,
   `deny_rram_budget`, `deny_spill_lanes`, `deny_token_budget`,
   `evict_priority`, `offload_idle`, `restore`, `restore_yield`,
   `admit`) — exported as a Prometheus text exposition
   (`prometheus()`) and an optional JSONL snapshot stream.

Telemetry is strictly opt-in: `Engine(telemetry=None)` (the default)
installs `NullTelemetry`, whose hooks are empty methods — the disabled
path costs a handful of no-op calls per multi-millisecond step (<2%
throughput, asserted by the bench and tests). No jax import here; the
module is pure host-side bookkeeping.
"""

from __future__ import annotations

import collections
import json
import math
import re
import time

import numpy as np

from repro.simulator.chime_sim import (cost_layers, decode_token_terms,
                                       prefill_terms, prefix_adopt_terms,
                                       spill_terms, sum_terms,
                                       visual_tokens)
from repro.simulator.chime_sim import closing_terms as _closing_terms
from repro.simulator.hardware import CHIME

# trace process ids: one Perfetto process group per facet
PID_ENGINE = 1      # engine step phases (+ counter tracks)
PID_SLOTS = 2       # KV slots: occupancy segments per slot
PID_LANES = 3       # RRAM spill lanes: parked segments per lane
PID_REQUESTS = 4    # request lifecycle segments per rid

#: every reason code the scheduler/engine can log, with glossary —
#: mirrored in the README reason-code table
REASON_CODES = {
    "admit": "queue head admitted (slot + both byte budgets ok)",
    "deny_no_free_slot": "queue head blocked: no free KV slot",
    "deny_dram_budget": "queue head blocked: DRAM hot-ring byte budget",
    "deny_dram_weights": "queue head blocked: resident weight working "
                         "set leaves no DRAM headroom for its KV",
    "deny_rram_budget": "queue head blocked: RRAM cold-tier byte budget",
    "deny_spill_lanes": "queue head blocked: oversubscribe overflow "
                        "exceeds free spill lanes",
    "deny_token_budget": "queue head blocked: step token budget "
                         "exhausted by decode slots/chunks",
    "deny_restore_dram_budget": "restore deferred: DRAM byte budget",
    "deny_restore_rram_budget": "restore deferred: RRAM byte budget",
    "deny_restore_spill_lanes": "restore deferred: spill-lane gate",
    "evict_priority": "runner preempted by a strictly higher-priority "
                      "waiter (KV spilled to an RRAM lane)",
    "offload_idle": "idle runner parked to RRAM for an equal-or-higher "
                    "priority waiter (capacity offload)",
    "restore": "spilled request restored into a free slot",
    "restore_yield": "restore yielded its slot to a higher-priority "
                     "queue head",
    "prefix_adopt": "admitted request seeded its prefill from cached "
                    "prefix blocks (skipped recompute of the hit span)",
}


# ---------------------------------------------------------------------------
# tier-traffic ledger
# ---------------------------------------------------------------------------
class TierLedger:
    """Per-step simulated traffic/energy accounting of the live engine.

    Every priced engine event (prefill commit, decode token at its
    context, spill, restore, per-request closing static charge) appends
    its `chime_sim.CostTerm` list; `totals()` folds the flat stream with
    `sum_terms` — the same order-independent fsum `simulated_efficiency`
    uses, so a drained run reconciles bitwise.

    On top of the priced terms, each step row splits the attention KV
    read of every decode token into DRAM hot-ring bytes (the bf16 ring,
    last ``kv_hot_window`` tokens) and RRAM cold-tier read bytes (the
    int8 prefix beyond the ring + its f32 scales) — the byte-level view
    of the tiered-attention dataflow."""

    def __init__(self, cfg, platform=None, spill_compressed: bool = False,
                 fused_decode: bool | None = None,
                 sparse_read_tau: float | None = None,
                 weight_stream: bool | None = None):
        from repro.models.counting import (kv_elems_per_token,
                                           kv_scale_elems_per_token)
        self.cfg = cfg
        self.platform = platform or CHIME
        self.spill_compressed = bool(spill_compressed)
        # fused paged-decode pricing: explicit args (the backend's resolved
        # knobs) win; None falls back to the cfg fields so a bare
        # TierLedger(cfg) still prices what the model executes
        self.fused_decode = bool(
            getattr(cfg, "fused_decode", False) if fused_decode is None
            else fused_decode)
        self.sparse_read_tau = float(
            getattr(cfg, "sparse_read_tau", 0.0) if sparse_read_tau is None
            else sparse_read_tau)
        self.weight_stream = bool(
            getattr(cfg, "weight_stream_layers", 0) if weight_stream is None
            else weight_stream)
        self._layers = cost_layers(cfg)
        self._kv_elems = kv_elems_per_token(cfg)
        self._scale_elems = kv_scale_elems_per_token(cfg)
        try:
            self._hot_itemsize = np.dtype(cfg.compute_dtype).itemsize
        except TypeError:       # bfloat16: unknown to bare numpy
            self._hot_itemsize = 2
        self._hot_w = (cfg.kv_hot_window if cfg.kv_policy == "tiered"
                       else None)
        self._terms: list = []
        self._req_terms: dict[int, list] = {}
        self._req_prompt: dict[int, int] = {}
        self.steps: list[dict] = []
        self._row: dict | None = None
        self.requests_closed = 0

    # -- step framing --------------------------------------------------
    def step_begin(self, step: int):
        self._row = {"step": step, "tokens": 0,
                     "dram_hot_ring_bytes": 0.0,
                     "rram_cold_read_bytes": 0.0,
                     "rram_spill_bytes": 0.0,
                     "prefix_adopt_bytes": 0.0,
                     "dram_stream_bytes": 0.0,
                     "rram_stream_bytes": 0.0,
                     "sparse_skipped_bytes": 0.0,
                     "weight_stream_bytes": 0.0,
                     "kv_append_bytes": 0.0,
                     "ucie_bytes": 0.0,
                     "energy_j": 0.0}

    def step_end(self):
        if self._row is not None:
            self.steps.append(self._row)
            self._row = None

    def _record(self, rid: int, terms):
        self._terms.extend(terms)
        self._req_terms.setdefault(rid, []).extend(terms)
        row = self._row
        if row is None:
            return
        for tm in terms:
            row["energy_j"] += tm.energy_j
            if tm.domain == "dram":
                row["dram_stream_bytes"] += tm.bytes_moved
            elif tm.domain == "rram":
                row["rram_stream_bytes"] += tm.bytes_moved
            elif tm.domain == "spill":
                row["rram_spill_bytes"] += tm.bytes_moved
            elif tm.domain == "prefix":
                row["prefix_adopt_bytes"] += tm.bytes_moved
            elif tm.domain == "skipped":
                row["sparse_skipped_bytes"] += tm.bytes_moved
            elif tm.domain == "weight_stream":
                row["weight_stream_bytes"] += tm.bytes_moved
            elif tm.domain == "kv_write":
                row["kv_append_bytes"] += tm.bytes_moved
            elif tm.domain == "ucie":
                row["ucie_bytes"] += tm.bytes_moved

    # -- priced events -------------------------------------------------
    def prefill(self, rid: int, text_tokens: int, image: bool,
                cached: int = 0):
        """Request committed its prompt: price the prefill and remember
        the prompt length that anchors its decode contexts — computed
        with the simulator's own `visual_tokens` formula so the ledger
        and `simulated_efficiency` can never disagree on ctx. ``cached``
        prompt positions came from the shared prefix store: the prefill
        prices only the tail (same `cached_prefix` path as
        `request_terms`) plus the block-adoption transfer."""
        prompt = (visual_tokens(self.cfg) if image else 0) + text_tokens
        self._req_prompt[rid] = prompt
        terms = prefill_terms(self.cfg, self.platform, text_tokens,
                              image, self._layers, cached_prefix=cached,
                              weight_stream=self.weight_stream)
        if cached > 0:
            terms = terms + prefix_adopt_terms(self.cfg, self.platform,
                                               cached)
        self._record(rid, terms)

    def decode(self, rid: int, n_generated: int):
        """One emitted token: n_generated is the post-emit count, so the
        token's context is prompt + (n_generated - 1) — identical for the
        commit-emitted first token and decode-step tokens."""
        ctx = self._req_prompt[rid] + n_generated - 1
        self._record(rid, decode_token_terms(
            self.cfg, self.platform, ctx, self._layers,
            fused=self.fused_decode, sparse_tau=self.sparse_read_tau,
            weight_stream=self.weight_stream))
        row = self._row
        if row is not None:
            row["tokens"] += 1
            if self._hot_w is None:
                row["dram_hot_ring_bytes"] += (self._kv_elems * ctx
                                               * self._hot_itemsize)
            else:
                # the store-level hot/cold view: attendable bytes per
                # tier. Under the sparse read the skipped share of the
                # cold bytes shows up in sparse_skipped_bytes (from the
                # `skipped` CostTerms) while this counter keeps the full
                # attendable figure.
                row["dram_hot_ring_bytes"] += (
                    self._kv_elems * min(ctx, self._hot_w)
                    * self._hot_itemsize)
                cold_toks = max(ctx - self._hot_w, 0)
                row["rram_cold_read_bytes"] += cold_toks * (
                    self._kv_elems + 4 * self._scale_elems)

    def spill(self, rid: int, ctx: int, restore: bool):
        self._record(rid, spill_terms(self.cfg, self.platform, int(ctx),
                                      restore=restore,
                                      compressed=self.spill_compressed))

    def close(self, rid: int):
        """Request finished: charge its closing static-power terms
        (computed over its own non-spill term stream, exactly as
        `request_terms` does)."""
        terms = self._req_terms.get(rid)
        if terms:
            self._record(rid, _closing_terms(self.platform, terms))
            self.requests_closed += 1

    # -- reports -------------------------------------------------------
    def totals(self) -> dict:
        """Cumulative ledger: the reconciling sim_* aggregate plus the
        per-tier byte counters folded (fsum) over the step rows."""
        rows = self.steps + ([self._row] if self._row is not None else [])
        out = sum_terms(self._terms)
        out["tokens"] = int(sum(r["tokens"] for r in rows))
        out["requests_closed"] = self.requests_closed
        for k in ("dram_hot_ring_bytes", "rram_cold_read_bytes",
                  "rram_spill_bytes", "prefix_adopt_bytes",
                  "dram_stream_bytes", "rram_stream_bytes",
                  "sparse_skipped_bytes", "weight_stream_bytes",
                  "kv_append_bytes", "ucie_bytes"):
            out[k] = math.fsum(r[k] for r in rows)
        return out


# ---------------------------------------------------------------------------
# the telemetry hub
# ---------------------------------------------------------------------------
class Telemetry:
    """Live serving observability hub (see module docstring).

    Construct with no arguments and hand to ``Engine(telemetry=...)`` —
    the engine `bind`s its model config, spill codec, injectable clock
    and endurance reporter. ``stats_every`` > 0 emits a snapshot (JSONL
    line via ``snapshot_path``, console line via ``printer``) every N
    steps. ``max_events`` / ``max_decisions`` bound memory; overflow is
    counted, not silently lost."""

    enabled = True

    def __init__(self, cfg=None, platform=None,
                 spill_compressed: bool | None = None, clock=None,
                 stats_every: int = 0, snapshot_path: str | None = None,
                 printer=None, max_events: int = 200_000,
                 max_decisions: int = 10_000,
                 fused_decode: bool | None = None,
                 sparse_read_tau: float | None = None,
                 weight_stream: bool | None = None):
        self.cfg = cfg
        self.platform = platform
        self.spill_compressed = spill_compressed
        self.fused_decode = fused_decode
        self.sparse_read_tau = sparse_read_tau
        self.weight_stream = weight_stream
        self.clock = clock or time.perf_counter
        self.stats_every = int(stats_every or 0)
        self.snapshot_path = snapshot_path
        self.printer = printer
        self.max_events = max_events
        self.max_decisions = max_decisions
        self.ledger: TierLedger | None = None
        self.counters: collections.Counter = collections.Counter()
        self.decision_counts: collections.Counter = collections.Counter()
        self.decisions: list[dict] = []
        self.gauges: dict = {}
        self.phase_s: dict[str, float] = {}
        self.snapshots: list[dict] = []
        self.dropped_events = 0
        self.dropped_decisions = 0
        self._on_snapshot = None
        self._events: list[dict] = []
        self._phase_stack: list[tuple[str, float]] = []
        self._step = -1
        self._t0: float | None = None
        self._t_last = 0.0
        self._slot_open: dict[int, tuple[int, float]] = {}
        self._lane_open: dict[int, tuple[int, float]] = {}
        self._req_open: dict[int, tuple[str, float]] = {}
        self._req_slot: dict[int, int] = {}
        self._slots_seen: set[int] = set()
        self._lanes_seen: set[int] = set()
        self._rids_seen: set[int] = set()
        self._snap_file = None
        self._maybe_ledger()

    def _maybe_ledger(self):
        if self.ledger is None and self.cfg is not None:
            self.ledger = TierLedger(
                self.cfg, self.platform,
                bool(self.spill_compressed),
                fused_decode=self.fused_decode,
                sparse_read_tau=self.sparse_read_tau,
                weight_stream=self.weight_stream)

    def bind(self, *, cfg=None, spill_compressed=None, clock=None,
             platform=None, on_snapshot=None, fused_decode=None,
             sparse_read_tau=None, weight_stream=None):
        """Engine attachment: fill whatever the user left unset. The
        engine's clock always wins — it is the time authority every
        request timestamp already uses."""
        if self.cfg is None:
            self.cfg = cfg
        if self.spill_compressed is None:
            self.spill_compressed = spill_compressed
        if self.fused_decode is None:
            self.fused_decode = fused_decode
        if self.sparse_read_tau is None:
            self.sparse_read_tau = sparse_read_tau
        if self.weight_stream is None:
            self.weight_stream = weight_stream
        if self.platform is None:
            self.platform = platform
        if clock is not None:
            self.clock = clock
        if on_snapshot is not None:
            self._on_snapshot = on_snapshot
        self._maybe_ledger()

    # -- clock ---------------------------------------------------------
    def _now(self) -> float:
        t = self.clock()
        if self._t0 is None:
            self._t0 = t
        self._t_last = t
        return t

    def _us(self, t: float) -> int:
        return int(round((t - (self._t0 or 0.0)) * 1e6))

    def _emit(self, ev: dict):
        if len(self._events) >= self.max_events:
            self.dropped_events += 1
            return
        self._events.append(ev)

    def _span(self, pid: int, tid: int, name: str, t0: float, t1: float,
              args: dict | None = None):
        ev = {"name": name, "ph": "X", "pid": pid, "tid": tid,
              "ts": self._us(t0), "dur": max(self._us(t1) - self._us(t0),
                                             1)}
        if args:
            ev["args"] = args
        self._emit(ev)

    def _instant(self, pid: int, tid: int, name: str, t: float,
                 args: dict | None = None):
        ev = {"name": name, "ph": "i", "s": "t", "pid": pid, "tid": tid,
              "ts": self._us(t)}
        if args:
            ev["args"] = args
        self._emit(ev)

    # -- step framing / phases -----------------------------------------
    def step_begin(self, step: int):
        self._step = step
        self.counters["steps"] += 1
        if self.ledger is not None:
            self.ledger.step_begin(step)

    def step_end(self, gauges: dict | None = None):
        if gauges is not None:
            self.gauges = gauges
            t = self._now()
            self._emit({"name": "slots", "ph": "C", "pid": PID_ENGINE,
                        "tid": 0, "ts": self._us(t),
                        "args": {"active": gauges.get("slots_active", 0),
                                 "free": gauges.get("slots_free", 0)}})
            qd = gauges.get("queue_depth") or {}
            self._emit({"name": "queue_depth", "ph": "C",
                        "pid": PID_ENGINE, "tid": 0, "ts": self._us(t),
                        "args": {str(k): v for k, v in sorted(qd.items())}
                        or {"0": 0}})
        if self.ledger is not None:
            self.ledger.step_end()
        if self.stats_every and (self._step + 1) % self.stats_every == 0:
            self.snapshot()

    def phase_begin(self, name: str):
        self._phase_stack.append((name, self._now()))

    def phase_end(self, count: int | None = None, **args):
        """Close the innermost phase. ``count=0`` elides the span (an
        empty evict/restore phase every step would bury the timeline);
        any other value lands in the span args."""
        name, t0 = self._phase_stack.pop()
        t1 = self._now()
        if count == 0:
            return
        self.phase_s[name] = self.phase_s.get(name, 0.0) + (t1 - t0)
        if count is not None:
            args["count"] = count
        self._span(PID_ENGINE, 0, name, t0, t1, args or None)

    # -- request lifecycle ---------------------------------------------
    def _req_segment(self, rid: int, state: str | None, t: float):
        """Close the request's open lifecycle segment (if any) and open
        ``state`` (None = final close)."""
        open_ = self._req_open.pop(rid, None)
        if open_ is not None:
            self._span(PID_REQUESTS, rid, open_[0], open_[1], t)
        if state is not None:
            self._req_open[rid] = (state, t)
        self._rids_seen.add(rid)

    def request_submitted(self, req):
        t = self._now()
        self.counters["submitted"] += 1
        self._req_segment(req.rid, "queued", t)

    def request_admitted(self, req, slot: int):
        t = self._now()
        self.counters["admitted"] += 1
        self._req_segment(req.rid, "prefill", t)
        self._req_slot[req.rid] = slot
        self._slot_open[slot] = (req.rid, t)
        self._slots_seen.add(slot)

    def request_first_token(self, req):
        t = self._now()
        self.counters["prefill_commits"] += 1
        self._req_segment(req.rid, "decode", t)
        self._instant(PID_REQUESTS, req.rid, "first-token", t)
        if self.ledger is not None:
            image = req.has_image and self.cfg.frontend is not None
            self.ledger.prefill(req.rid, int(req.tokens.shape[0]), image,
                                cached=int(getattr(req, "prefix_hit", 0)))
        self.token(req)

    def token(self, req):
        self.counters["tokens"] += 1
        if self.ledger is not None:
            self.ledger.decode(req.rid, req.n_generated)

    def request_evicted(self, req, slot: int, lane: int, ctx: int,
                        offload: bool):
        t = self._now()
        self.counters["idle_offloads" if offload else "evictions"] += 1
        self._req_segment(req.rid, "parked", t)
        open_ = self._slot_open.pop(slot, None)
        if open_ is not None:
            self._span(PID_SLOTS, slot, f"r{open_[0]}", open_[1], t)
        self._req_slot.pop(req.rid, None)
        self._lane_open[lane] = (req.rid, t)
        self._lanes_seen.add(lane)
        self._instant(PID_REQUESTS, req.rid,
                      "offload" if offload else "preempt", t,
                      {"ctx": int(ctx), "lane": lane})
        if self.ledger is not None:
            self.ledger.spill(req.rid, ctx, restore=False)

    def request_restored(self, req, lane: int, slot: int, ctx: int):
        t = self._now()
        self.counters["restores"] += 1
        self._req_segment(req.rid, "decode", t)
        open_ = self._lane_open.pop(lane, None)
        if open_ is not None:
            self._span(PID_LANES, lane, f"r{open_[0]}", open_[1], t)
        self._req_slot[req.rid] = slot
        self._slot_open[slot] = (req.rid, t)
        self._slots_seen.add(slot)
        if self.ledger is not None:
            self.ledger.spill(req.rid, ctx, restore=True)

    def request_finished(self, req):
        t = self._now()
        self.counters["finished"] += 1
        self._req_segment(req.rid, None, t)
        slot = self._req_slot.pop(req.rid, None)
        if slot is not None:
            open_ = self._slot_open.pop(slot, None)
            if open_ is not None:
                self._span(PID_SLOTS, slot, f"r{open_[0]}", open_[1], t)
        if self.ledger is not None:
            self.ledger.close(req.rid)

    # -- decisions -----------------------------------------------------
    def decision(self, code: str, rid: int | None = None, **args):
        self.decision_counts[code] += 1
        if len(self.decisions) >= self.max_decisions:
            self.dropped_decisions += 1
            return
        d = {"step": self._step, "code": code}
        if rid is not None:
            d["rid"] = rid
        if args:
            d.update(args)
        self.decisions.append(d)

    # -- snapshots -----------------------------------------------------
    def snapshot(self) -> dict:
        snap = {
            "step": self._step,
            "t_s": round(self._t_last - (self._t0 or 0.0), 9),
            "counters": dict(self.counters),
            "gauges": dict(self.gauges),
            "decisions": dict(self.decision_counts),
            "phase_s": dict(sorted(self.phase_s.items())),
        }
        if self.ledger is not None:
            snap["ledger"] = self.ledger.totals()
        if self._on_snapshot is not None:
            snap["endurance"] = self._on_snapshot()
        self.snapshots.append(snap)
        if self.snapshot_path:
            if self._snap_file is None:
                self._snap_file = open(self.snapshot_path, "a")
            self._snap_file.write(json.dumps(snap) + "\n")
            self._snap_file.flush()
        if self.printer is not None:
            g = self.gauges
            self.printer(
                f"[telemetry] step={self._step + 1} "
                f"tok={self.counters['tokens']} "
                f"fin={self.counters['finished']}"
                f"/{self.counters['submitted']} "
                f"slots={g.get('slots_active', '?')}"
                f"/{g.get('slots_total', '?')} "
                f"lanes_free={g.get('lanes_free', '?')} "
                f"spilled={g.get('spilled_requests', '?')}")
        return snap

    def close(self):
        if self._snap_file is not None:
            self._snap_file.close()
            self._snap_file = None

    # -- exports -------------------------------------------------------
    def chrome_trace(self) -> dict:
        """Chrome-trace/Perfetto JSON of everything so far. Open
        segments (still-running requests, occupied slots/lanes) are
        closed at the last observed timestamp; internal state is not
        mutated, so this can be called mid-run."""
        t_end = self._t_last
        events = list(self._events)

        def span(pid, tid, name, t0):
            ev = {"name": name, "ph": "X", "pid": pid, "tid": tid,
                  "ts": self._us(t0),
                  "dur": max(self._us(t_end) - self._us(t0), 1)}
            events.append(ev)

        for slot, (rid, t0) in self._slot_open.items():
            span(PID_SLOTS, slot, f"r{rid}", t0)
        for lane, (rid, t0) in self._lane_open.items():
            span(PID_LANES, lane, f"r{rid}", t0)
        for rid, (state, t0) in self._req_open.items():
            span(PID_REQUESTS, rid, state, t0)
        meta = [
            {"ph": "M", "pid": PID_ENGINE, "tid": 0,
             "name": "process_name",
             "args": {"name": "engine (step phases)"}},
            {"ph": "M", "pid": PID_SLOTS, "tid": 0,
             "name": "process_name",
             "args": {"name": "kv-slots (DRAM hot ring)"}},
            {"ph": "M", "pid": PID_LANES, "tid": 0,
             "name": "process_name",
             "args": {"name": "rram spill lanes"}},
            {"ph": "M", "pid": PID_REQUESTS, "tid": 0,
             "name": "process_name", "args": {"name": "requests"}},
        ]
        for slot in sorted(self._slots_seen):
            meta.append({"ph": "M", "pid": PID_SLOTS, "tid": slot,
                         "name": "thread_name",
                         "args": {"name": f"slot {slot}"}})
        for lane in sorted(self._lanes_seen):
            meta.append({"ph": "M", "pid": PID_LANES, "tid": lane,
                         "name": "thread_name",
                         "args": {"name": f"lane {lane}"}})
        for rid in sorted(self._rids_seen):
            meta.append({"ph": "M", "pid": PID_REQUESTS, "tid": rid,
                         "name": "thread_name",
                         "args": {"name": f"req {rid}"}})
        return {"traceEvents": meta + events, "displayTimeUnit": "ms"}

    def write_chrome_trace(self, path: str):
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)

    def prometheus(self) -> str:
        """Prometheus text exposition of counters, decisions, phase
        times, ledger totals, gauges and endurance watermarks."""
        lines: list[str] = []

        def esc(v) -> str:
            return str(v).replace("\\", r"\\").replace('"', r'\"') \
                .replace("\n", r"\n")

        def fam(name, typ, help_, samples):
            lines.append(f"# HELP {name} {help_}")
            lines.append(f"# TYPE {name} {typ}")
            for labels, value in samples:
                lab = ""
                if labels:
                    lab = "{" + ",".join(
                        f'{k}="{esc(v)}"'
                        for k, v in sorted(labels.items())) + "}"
                lines.append(f"{name}{lab} {value}")

        c = self.counters
        fam("repro_serving_steps_total", "counter",
            "Engine steps executed.", [(None, c["steps"])])
        fam("repro_serving_tokens_total", "counter",
            "Tokens emitted (first tokens included).",
            [(None, c["tokens"])])
        fam("repro_serving_requests_total", "counter",
            "Request lifecycle events.",
            [({"event": e}, c[e])
             for e in ("submitted", "admitted", "finished")])
        fam("repro_serving_spill_events_total", "counter",
            "KV spill-store traffic events.",
            [({"kind": "preempt"}, c["evictions"]),
             ({"kind": "offload"}, c["idle_offloads"]),
             ({"kind": "restore"}, c["restores"])])
        denials = [({"reason": k[len("deny_"):]}, v)
                   for k, v in sorted(self.decision_counts.items())
                   if k.startswith("deny_")]
        fam("repro_serving_admission_denials_total", "counter",
            "Scheduler denials by reason code.", denials or [(None, 0)])
        fam("repro_serving_scheduler_decisions_total", "counter",
            "All scheduler decision codes.",
            [({"code": k}, v)
             for k, v in sorted(self.decision_counts.items())]
            or [(None, 0)])
        fam("repro_serving_phase_seconds_total", "counter",
            "Wall time per engine step phase.",
            [({"phase": k}, repr(v))
             for k, v in sorted(self.phase_s.items())] or [(None, 0)])
        if self.ledger is not None:
            tot = self.ledger.totals()
            fam("repro_serving_tier_bytes_total", "counter",
                "Simulated bytes moved per memory tier.",
                [({"tier": k[:-len("_bytes")]}, repr(tot[k]))
                 for k in ("dram_hot_ring_bytes", "rram_cold_read_bytes",
                           "rram_spill_bytes", "prefix_adopt_bytes",
                           "dram_stream_bytes", "rram_stream_bytes",
                           "weight_stream_bytes", "kv_append_bytes",
                           "ucie_bytes")])
            fam("repro_serving_sim_energy_joules_total", "counter",
                "Simulated energy by cost-term domain.",
                [({"domain": d}, repr(e))
                 for d, e in tot["sim_energy_split_j"].items()])
            fam("repro_serving_sim_seconds_total", "counter",
                "Simulated sequential execution time.",
                [(None, repr(tot["sim_total_s"]))])
        g = self.gauges
        for key, help_ in (("slots_active", "Occupied KV slots."),
                           ("slots_free", "Free KV slots."),
                           ("lanes_free", "Free RRAM spill lanes."),
                           ("spilled_requests",
                            "Requests parked in the spill store."),
                           ("inflight",
                            "Prompts currently prefilling (0 or 1)."),
                           ("prefix_blocks_used",
                            "Live prefix-cache blocks."),
                           ("prefix_blocks_free",
                            "Free prefix-cache blocks."),
                           ("prefix_max_refcount",
                            "Max concurrent sharers on one block."),
                           ("prefix_hits",
                            "Admissions that adopted a cached prefix."),
                           ("prefix_hit_tokens",
                            "Prompt positions skipped via prefix hits."),
                           ("prefix_cow_copies",
                            "Copy-on-write block copies."),
                           ("prefix_blocks_registered",
                            "Blocks ever registered (physical writes)."),
                           ("prefix_blocks_evicted",
                            "Blocks reclaimed from the prefix store.")):
            if key in g:
                fam(f"repro_serving_{key}", "gauge", help_,
                    [(None, g[key])])
        qd = g.get("queue_depth") or {}
        fam("repro_serving_queue_depth", "gauge",
            "Queued requests per priority class.",
            [({"priority": str(p)}, n) for p, n in sorted(qd.items())]
            or [({"priority": "0"}, 0)])
        if self._on_snapshot is not None:
            rep = self._on_snapshot()
            fam("repro_serving_endurance", "gauge",
                "Endurance audit watermarks (bool keys are 0/1).",
                [({"key": k},
                  int(v) if isinstance(v, (bool, int))
                  else repr(float(v)))
                 for k, v in sorted(rep.items())
                 if isinstance(v, (int, float, bool))])
        fam("repro_serving_trace_events_dropped_total", "counter",
            "Trace events dropped at the max_events cap.",
            [(None, self.dropped_events)])
        return "\n".join(lines) + "\n"

    def write_prometheus(self, path: str):
        with open(path, "w") as f:
            f.write(self.prometheus())

    def summary(self) -> dict:
        """Compact end-of-run record (what `serving_bench` persists):
        counters, decision-code counts, span-phase time breakdown, and
        the ledger's per-tier bytes + energy split."""
        out = {
            "counters": dict(self.counters),
            "decisions": dict(self.decision_counts),
            "phase_s": dict(sorted(self.phase_s.items())),
            "dropped_events": self.dropped_events,
        }
        if self.ledger is not None:
            out["ledger"] = self.ledger.totals()
        return out


class NullTelemetry:
    """Disabled-telemetry stand-in: every hook is an empty method, so
    the engine's instrumented hot path costs a handful of no-op calls
    per step (<2% throughput — the contract the bench asserts).
    `Engine(telemetry=None)` installs this."""

    enabled = False
    ledger = None

    def bind(self, **kw):
        pass

    def step_begin(self, step):
        pass

    def step_end(self, gauges=None):
        pass

    def phase_begin(self, name):
        pass

    def phase_end(self, count=None, **args):
        pass

    def request_submitted(self, req):
        pass

    def request_admitted(self, req, slot):
        pass

    def request_first_token(self, req):
        pass

    def token(self, req):
        pass

    def request_evicted(self, req, slot, lane, ctx, offload):
        pass

    def request_restored(self, req, lane, slot, ctx):
        pass

    def request_finished(self, req):
        pass

    def decision(self, code, rid=None, **args):
        pass

    def snapshot(self):
        return {}

    def close(self):
        pass


# ---------------------------------------------------------------------------
# validators (shared by tests and the CI trace-schema smoke step)
# ---------------------------------------------------------------------------
_PROM_SAMPLE = re.compile(
    r"^([a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{([^}]*)\})?"
    r" (-?(?:[0-9.]+(?:[eE][+-]?[0-9]+)?|[Ii]nf)|NaN)$")
_PROM_LABEL = re.compile(
    r'^([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"$')


def parse_prometheus(text: str) -> list[tuple[str, dict, float]]:
    """Strictly parse a Prometheus text exposition; returns
    (metric_name, labels, value) samples. Raises ValueError on any
    malformed line, undeclared metric (no # TYPE), or bad label pair —
    the CI smoke step's schema gate."""
    declared: set[str] = set()
    samples: list[tuple[str, dict, float]] = []
    for ln, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# TYPE "):
            parts = line.split()
            if len(parts) != 4 or parts[3] not in (
                    "counter", "gauge", "histogram", "summary",
                    "untyped"):
                raise ValueError(f"line {ln}: malformed TYPE: {line!r}")
            declared.add(parts[2])
            continue
        if line.startswith("# HELP "):
            if len(line.split()) < 3:
                raise ValueError(f"line {ln}: malformed HELP: {line!r}")
            continue
        if line.startswith("#"):
            continue
        m = _PROM_SAMPLE.match(line)
        if not m:
            raise ValueError(f"line {ln}: malformed sample: {line!r}")
        name, rawlab, rawval = m.groups()
        if name not in declared:
            raise ValueError(f"line {ln}: sample for undeclared metric "
                             f"{name!r}")
        labels = {}
        if rawlab:
            for pair in rawlab.split(","):
                lm = _PROM_LABEL.match(pair)
                if not lm:
                    raise ValueError(f"line {ln}: malformed label "
                                     f"{pair!r}")
                labels[lm.group(1)] = lm.group(2)
        samples.append((name, labels, float(rawval)))
    return samples


def validate_chrome_trace(trace: dict) -> dict:
    """Structurally validate a Chrome-trace/Perfetto JSON object.
    Raises ValueError on schema violations; returns a summary
    ({events, spans, instants, counters, processes, phases}) for
    assertions on content."""
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        raise ValueError("trace must be a dict with 'traceEvents'")
    evs = trace["traceEvents"]
    if not isinstance(evs, list):
        raise ValueError("traceEvents must be a list")
    spans = instants = counters = 0
    processes: set[int] = set()
    phases: set[str] = set()
    for i, ev in enumerate(evs):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i}: not an object")
        for key in ("ph", "pid", "tid", "name"):
            if key not in ev:
                raise ValueError(f"event {i}: missing {key!r}: {ev}")
        if not isinstance(ev["pid"], int) or not isinstance(ev["tid"],
                                                            int):
            raise ValueError(f"event {i}: pid/tid must be ints")
        processes.add(ev["pid"])
        ph = ev["ph"]
        if ph == "M":
            if "args" not in ev:
                raise ValueError(f"event {i}: metadata without args")
            continue
        if "ts" not in ev or not isinstance(ev["ts"], int) \
                or ev["ts"] < 0:
            raise ValueError(f"event {i}: bad ts: {ev}")
        if ph == "X":
            if not isinstance(ev.get("dur"), int) or ev["dur"] < 1:
                raise ValueError(f"event {i}: X span needs dur >= 1")
            spans += 1
            if ev["pid"] == PID_ENGINE:
                phases.add(ev["name"])
        elif ph == "i":
            instants += 1
        elif ph == "C":
            if not isinstance(ev.get("args"), dict):
                raise ValueError(f"event {i}: counter without args")
            counters += 1
        else:
            raise ValueError(f"event {i}: unexpected phase {ph!r}")
    return {"events": len(evs), "spans": spans, "instants": instants,
            "counters": counters, "processes": sorted(processes),
            "phases": sorted(phases)}
