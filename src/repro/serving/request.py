"""Request dataclasses for the serving engine.

A request is a prompt (text token ids, optionally preceded by visual
patch embeddings for VQA traffic) plus decode limits. The engine mutates
the runtime fields in place as the request moves queue -> slot -> done.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import numpy as np

QUEUED = "queued"
RUNNING = "running"
PREEMPTED = "preempted"
FINISHED = "finished"


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray                       # (S_text,) int32 prompt ids
    max_new_tokens: int
    patches: Optional[np.ndarray] = None     # (T_vis, frontend_dim) or None
    eos_id: Optional[int] = None
    on_token: Optional[Callable[["Request", int], Any]] = None
    # scheduling class: higher runs first, FCFS within a class; a
    # strictly higher-priority waiter may preempt a running request
    # (its KV state spills to RRAM and restores bit-exactly later)
    priority: int = 0

    # -- runtime state (engine-owned) ----------------------------------
    status: str = QUEUED
    slot: int = -1
    generated: list = dataclasses.field(default_factory=list)
    arrival_s: float = 0.0
    # engine-clock stamp of slot admission (0.0 = never admitted);
    # admit_s - arrival_s is the request's queue wait
    admit_s: float = 0.0
    first_token_s: float = 0.0
    finish_s: float = 0.0
    # per-token emission timestamps (engine clock); diffs are the
    # request's time-between-tokens trace for the TBT percentiles
    token_times: list = dataclasses.field(default_factory=list)
    # scheduler-owned admission recency (victim tie-break)
    admit_seq: int = -1
    # decode steps taken since this request last got a slot (admission
    # or restore); the idle-offload policy's residency clock — a runner
    # is offloadable for an equal-priority waiter once it reaches the
    # scheduler's idle_offload_steps
    resident_steps: int = 0
    # spill trace: paired evict/restore timestamps (engine clock) and
    # the context length each spill packed into its lane — preemptions
    # AND idle offloads both land here (they share the machinery);
    # ``n_idle_offloads`` says how many of the events were offloads
    evict_times: list = dataclasses.field(default_factory=list)
    restore_times: list = dataclasses.field(default_factory=list)
    evict_ctx: list = dataclasses.field(default_factory=list)
    n_idle_offloads: int = 0
    # prompt positions adopted from the shared prefix cache at admission
    # (0 = cold prefill); the telemetry ledger and the simulated-
    # efficiency model both price only the prompt tail beyond this
    prefix_hit: int = 0

    @property
    def prompt_len(self) -> int:
        """Backbone positions consumed by the prompt (visual + text)."""
        vis = 0 if self.patches is None else self.patches.shape[0]
        return vis + int(self.tokens.shape[0])

    @property
    def has_image(self) -> bool:
        return self.patches is not None

    @property
    def total_len(self) -> int:
        return self.prompt_len + self.max_new_tokens

    @property
    def n_generated(self) -> int:
        return len(self.generated)

    @property
    def done(self) -> bool:
        return self.status == FINISHED

    @property
    def n_evictions(self) -> int:
        """Total spill events (priority preemptions + idle offloads)."""
        return len(self.evict_times)

    @property
    def n_preemptions(self) -> int:
        """Spill events where a strictly higher-priority waiter forced
        this request out (excludes capacity-driven idle offloads)."""
        return len(self.evict_times) - self.n_idle_offloads

    def emit(self, token: int):
        self.generated.append(int(token))
        if self.on_token is not None:
            self.on_token(self, int(token))

    def finished_by(self, token: int) -> bool:
        """True if emitting ``token`` completes the request."""
        if self.eos_id is not None and int(token) == self.eos_id:
            return True
        return self.n_generated >= self.max_new_tokens


def make_synthetic_requests(cfg, n: int, prompt_len: int, gen_len: int,
                            seed: int = 0, image_every: int = 0,
                            jitter: int = 0,
                            priority_every: int = 0,
                            shared_prefix: int = 0) -> list[Request]:
    """A reproducible request stream for benchmarks/examples. Every
    ``image_every``-th request is a VQA request (visual patches + a text
    tail) when the config has a vision frontend; ``jitter`` varies prompt
    lengths +-jitter tokens to exercise bucketing; every
    ``priority_every``-th request is priority-1 interactive traffic
    (``priority_every=1`` marks all), so a saturated engine exercises
    preemption. ``shared_prefix`` > 0 makes every request open with the
    SAME ``shared_prefix`` leading prompt positions (one fixed system-
    prompt token run, and for VQA requests one fixed image) — the
    shared-system-prompt/shared-image stream the prefix cache is built
    for; tails stay per-request random so divergence is exercised."""
    rng = np.random.default_rng(seed)
    shared_toks = rng.integers(
        0, cfg.vocab_size, max(shared_prefix, 0)).astype(np.int32)
    shared_patches = None
    out = []
    for i in range(n):
        plen = prompt_len
        if jitter:
            plen = max(1, prompt_len + int(rng.integers(-jitter,
                                                        jitter + 1)))
        patches = None
        if image_every and cfg.frontend is not None \
                and i % image_every == 0:
            tv = cfg.frontend.num_tokens
            if shared_prefix:
                if shared_patches is None:
                    shared_patches = rng.standard_normal(
                        (tv, cfg.frontend.frontend_dim)).astype(
                            np.float32)
                patches = shared_patches
            else:
                patches = rng.standard_normal(
                    (tv, cfg.frontend.frontend_dim)).astype(np.float32)
            plen = max(1, plen - tv)
        toks = rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
        if shared_prefix:
            head = shared_toks[:min(shared_prefix, plen - 1)]
            toks[:head.shape[0]] = head
        prio = (1 if priority_every
                and i % priority_every == priority_every - 1 else 0)
        out.append(Request(rid=i, tokens=toks, max_new_tokens=gen_len,
                           patches=patches, priority=prio))
    return out
