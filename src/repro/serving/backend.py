"""Backend-executor seam: `InferenceBackend` is the protocol the serving
engine drives, decoupling scheduling from execution strategy.

A backend owns three things the engine must never reach into directly:

  * the parameters (placed however the backend likes — host, one device,
    or pjit-sharded over a mesh),
  * the KV pool layout, handed out as an explicit typed pytree
    (`kv_pool.KVPoolState`) rather than a model-aware object, and
  * the jitted step programs: `extend_step(batch, state, ext, slot, pos,
    length, commit)` — the unified multi-token cache extension (chunked
    prefill directly into an already-allocated pool slot) — and
    `decode_step(toks, state, pos, active)` (one token on every active
    slot).

The old two-phase admission surface — `prefill(batch, length)` building a
detached batch-1 cache, then `insert(state, req_cache, slot)` scattering
it into the pool — is subsumed by `extend_step`: the final (``commit``)
chunk folds the in-flight workspace into the flat/tiered stores and
scatters them into the slot inside one jitted program. (The `prefill` /
`insert` deprecation shims rode for their one release and are gone.)

Backends also carry the PAGED PREFIX BLOCK STORE (PR 7): a lazy tree of
``prefix_blocks`` x ``block_tokens`` full-precision workspace K/V rows
(plus per-block recurrent-state snapshots), with four tiny jitted block
copies — `prefix_save_ws`/`prefix_load_ws` move one block's rows between
the store and an in-flight extend workspace, `prefix_save_state`/
`prefix_load_state` snapshot/seed the SSM states. Which block holds
which prefix is host-side state in `serving.block_pool.BlockPool`; the
engine seeds hit blocks into a fresh workspace at admission ("gather on
admit") and registers new blocks at commit, so decode and the committed
slot layout are completely untouched — which is why a paged engine holds
exact token parity with the slot-pool oracle.

Two implementations ship:

  * `LocalBackend` — the single-host vmapped path (the seed engine's
    jitted closures, extracted verbatim): one jit-compiled step advances
    every slot, each slot attending its own hot ring + cold tier at its
    own position.
  * `ShardedBackend` — the same step jaxpr executed under pjit on a
    `launch/mesh.py` mesh: params are placed by the model's
    `param_shardings` rules and the KV pool by `Model.cache_shardings`
    (slots -> 'data', cold kv_seq / kv heads -> 'model', divisibility
    permitting). The decode jaxpr is built from a rules-free model twin
    and the layout is pinned with sharding constraints at the jit
    boundaries only, so a 1-device mesh is token-for-token identical to
    `LocalBackend` (tests/test_serving_sharded.py holds both meshes to
    exact parity).

The engine, scheduler, metrics and endurance audit run unmodified on
either backend; this seam is where later scale-out work (multi-host,
async prefill, disaggregated tiers) plugs in.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core import kv_tiers as KT
from repro.models import Model
from repro.models.counting import streamed_unit_indices, weight_stream_split
from repro.serving.kv_pool import (KVPoolState, TieredKVPool, batch_axes,
                                   map_spill_stores, slot_kv_bytes,
                                   spill_lane_bytes, tree_expand,
                                   tree_squeeze)
from repro.sharding import ShardingRules


def _resolve_spill_compress(flag: bool | None) -> bool:
    """Resolve the compressed-spill-lane knob: an explicit bool wins;
    None consults ``REPRO_SERVE_SPILL_COMPRESS`` (unset/empty/"0" = off,
    anything else = on — an env var must never wedge startup)."""
    if flag is not None:
        return bool(flag)
    return os.environ.get("REPRO_SERVE_SPILL_COMPRESS",
                          "").strip() not in ("", "0")


def _resolve_fused_decode(flag: bool | None) -> bool:
    """Resolve the fused paged-decode knob: an explicit bool wins; None
    consults ``REPRO_SERVE_FUSED_DECODE`` (unset/empty/"0" = off)."""
    if flag is not None:
        return bool(flag)
    return os.environ.get("REPRO_SERVE_FUSED_DECODE",
                          "").strip() not in ("", "0")


def _resolve_sparse_read(tau: float | None) -> float:
    """Resolve the SLIM-style sparse-read threshold: an explicit float
    wins; None consults ``REPRO_SERVE_SPARSE_READ``. Unparsable or
    negative values resolve to 0.0 (off) — an env var must never wedge
    startup."""
    if tau is not None:
        return max(float(tau), 0.0)
    raw = os.environ.get("REPRO_SERVE_SPARSE_READ", "").strip()
    if not raw:
        return 0.0
    try:
        return max(float(raw), 0.0)
    except ValueError:
        return 0.0


def _resolve_weight_stream(layers: int | None) -> int:
    """Resolve the RRAM weight-streaming window (W = DRAM sliding-window
    repeats per streamed scan unit; 0 = off): an explicit value wins;
    None consults ``REPRO_SERVE_WEIGHT_STREAM``. Unparsable or negative
    values resolve to 0 — an env var must never wedge startup."""
    if layers is not None:
        return max(int(layers), 0)
    raw = os.environ.get("REPRO_SERVE_WEIGHT_STREAM", "").strip()
    if not raw:
        return 0
    try:
        return max(int(raw), 0)
    except ValueError:
        return 0


@runtime_checkable
class InferenceBackend(Protocol):
    """What the engine needs from an executor. Any object with this
    surface can serve; the engine never touches model internals."""

    num_slots: int            # decode slots the pool is laid out for
    max_len: int              # per-slot KV length
    hot_window: int           # effective hot-ring length (endurance audit)
    requires_exact_prefill: bool   # recurrent states forbid padded chunks
    chunk_unit: int           # non-final chunk lengths must be multiples
    #   of this (cfg.ssm.chunk_size for recurrent archs, else 1) so the
    #   model's canonical SSM chunk grid stays split-invariant
    n_spill: int              # RRAM spill lanes for preempted slots (0 =
    #   preemption disabled); lane ARRAYS materialize lazily on the
    #   first eviction, so unpreempted pools never pay the extra copy
    spill_compress: bool      # opt-in int8 hot-ring spill codec: lanes
    #   store the hot window requantized (kv_tiers.spill_store_compress)
    #   so a parked image costs ~the cold tier's bytes; restore is then
    #   requantization-aware and bounded-error instead of bit-exact (the
    #   cold tier, scales, recurrent states and flat stores still ride
    #   verbatim). Default off: REPRO_SERVE_SPILL_COMPRESS / CLI
    #   --spill-compress.
    block_tokens: int          # prefix-page granularity (tokens/block);
    #   defaults to core.kv_tiers.ENDURANCE_BLOCK clamped to max_len and
    #   rounded to the chunk grid for recurrent architectures
    prefix_blocks: int         # physical blocks in the prefix store
    fused_decode: bool        # opt-in fused paged-decode attention
    #   (kernels/paged_decode.py): decode streams K/V pages straight
    #   from the tiered layout with in-kernel int8 dequant. GQA-only —
    #   resolves to off for architectures with no GQA attention layer.
    #   Default off: REPRO_SERVE_FUSED_DECODE / CLI --fused-decode.
    sparse_read_tau: float    # SLIM-style adaptive-threshold sparse
    #   read inside the fused kernel (0.0 = exact). Only meaningful
    #   with fused_decode; REPRO_SERVE_SPARSE_READ / CLI --sparse-read.
    weight_stream: int        # RRAM weight-streaming window W (repeats
    #   of each streamed scan unit kept DRAM-resident; 0 = off, the
    #   whole param set DRAM-resident). Resolves to 0 when nothing would
    #   actually stream (no scanned unit deeper than the window), so the
    #   knob stays truthful for the scheduler's weight charge and sim
    #   pricing. REPRO_SERVE_WEIGHT_STREAM / CLI --weight-stream.

    def weight_bytes(self) -> tuple[int, int]:
        """(dram_resident, rram_streamed) param bytes under the resolved
        weight-streaming window — what the engine hands the scheduler's
        DRAM weight charge (whole set DRAM-resident at W=0)."""
        ...

    def slot_kv_bytes(self, *, length: int | None = None
                      ) -> tuple[int, int]:
        """(dram_hot, rram_cold) bytes one resident request pins —
        worst-case ``max_len`` residency by default, or the live
        block-granular charge for a request of total span ``length``
        (what the paged admission gate prices)."""
        ...

    def spill_lane_bytes(self) -> int:
        """RRAM bytes one OCCUPIED spill lane pins (the scheduler's
        per-parked-image charge; smaller under spill_compress)."""
        ...

    def sim_context(self) -> tuple:
        """(model config, spill_compress) — what the telemetry ledger
        needs to price traffic through the analytical simulator. The
        engine degrades to a ledger-less telemetry hub when a custom
        backend lacks this (it probes via getattr)."""
        ...

    def make_pool(self) -> TieredKVPool:
        """Fresh slot pool wired to this backend's insert arithmetic."""
        ...

    def fresh_extend(self) -> dict:
        """Zero chunk-resumable prefill state (one in-flight lane); built
        once and reused — every extend is functional."""
        ...

    def extend_step(self, batch: dict, state: KVPoolState, ext: dict,
                    slot, pos, length, commit: bool
                    ) -> tuple[jax.Array | None, dict | None, KVPoolState]:
        """Advance one in-flight prefill by a chunk of ``length`` valid
        tokens at absolute position ``pos``. Non-commit chunks return
        (None, new_ext, state-unchanged); the ``commit`` chunk folds the
        workspace into the stores, scatters them into pool slot ``slot``
        and returns (first greedy token, None, new state)."""
        ...

    def decode_step(self, toks, state: KVPoolState, pos, active
                    ) -> tuple[jax.Array, KVPoolState]:
        """One greedy token on every active slot; inactive slots' cache
        is kept verbatim (no phantom appends, no endurance drift)."""
        ...

    def evict_slot(self, state: KVPoolState, slot, lane, length
                   ) -> KVPoolState:
        """Pack slot ``slot``'s cache into RRAM spill lane ``lane``
        (verbatim by default; hot ring int8-requantized under
        spill_compress) and bump that lane's per-block endurance
        counters for a ``length``-token context (one write per touched
        block — the one-shot `store_from_full`-style image write,
        whatever the representation)."""
        ...

    def restore_slot(self, state: KVPoolState, lane, slot
                     ) -> KVPoolState:
        """Scatter spill lane ``lane`` back into pool slot ``slot``.
        Bit-exact when the image was packed verbatim — resumed decode is
        token-for-token identical to never-evicted decode; under
        spill_compress the hot ring dequantizes within the documented
        codec bound instead. Restore writes land in DRAM, so no RRAM
        counters move."""
        ...


class _JittedBackend:
    """Shared scaffolding: validates the config, derives the slot-axis
    tree, and builds the three jitted programs (step / prefill / insert).
    Subclasses steer placement via `_place` and `_constrain`."""

    def __init__(self, model: Model, params, num_slots: int, max_len: int,
                 n_spill: int | None = None,
                 spill_compress: bool | None = None,
                 prefix_blocks: int | None = None,
                 block_tokens: int | None = None,
                 fused_decode: bool | None = None,
                 sparse_read: float | None = None,
                 weight_stream: int | None = None):
        cfg = model.cfg
        if cfg.is_encoder:
            raise ValueError("encoder-only model cannot be served")
        if num_slots is None or max_len is None:
            raise TypeError("backend needs num_slots and max_len")
        if num_slots < 1:
            raise ValueError("backend needs at least one decode slot")
        if n_spill is None:
            n_spill = num_slots      # preemption available out of the box
        if n_spill < 0:
            raise ValueError("backend needs n_spill >= 0")
        # fused paged-decode attention is GQA-only (apply_mla_decode
        # keeps the unfused oracle), so the flag resolves to off for
        # architectures with no GQA attention layer — keeping the knob
        # truthful for sim pricing and the CLI report, exactly like
        # spill_compress on a flat cache. The sparse-read threshold only
        # exists inside the fused kernel, so it follows the same gate.
        has_gqa = any(u.block.mixer in ("attn", "attn_shared")
                      for u in model.plan)
        if fused_decode is None and getattr(cfg, "fused_decode", False):
            fused_decode = True       # cfg flag wins over an unset env var
        if sparse_read is None and getattr(cfg, "sparse_read_tau", 0.0):
            sparse_read = cfg.sparse_read_tau
        self.fused_decode = _resolve_fused_decode(fused_decode) and has_gqa
        self.sparse_read_tau = (_resolve_sparse_read(sparse_read)
                                if self.fused_decode else 0.0)
        if (self.fused_decode != bool(getattr(cfg, "fused_decode", False))
                or self.sparse_read_tau
                != float(getattr(cfg, "sparse_read_tau", 0.0))):
            cfg = cfg.replace(fused_decode=self.fused_decode,
                              sparse_read_tau=self.sparse_read_tau)
            model = Model(cfg, model.rules)
        # RRAM weight streaming: same precedence discipline (explicit
        # arg > cfg flag > env), and the same truthfulness gate — the
        # window resolves to 0 when no scan unit would actually stream
        # (python-loop layers, or nothing deeper than W repeats), so the
        # scheduler's weight charge and the sim pricing never claim a
        # transfer the model does not perform.
        if weight_stream is None and getattr(cfg, "weight_stream_layers",
                                             0):
            weight_stream = cfg.weight_stream_layers
        W = _resolve_weight_stream(weight_stream)
        if W and not streamed_unit_indices(
                cfg.replace(weight_stream_layers=W)):
            W = 0
        self.weight_stream = W
        if W != int(getattr(cfg, "weight_stream_layers", 0) or 0):
            cfg = cfg.replace(weight_stream_layers=W)
            model = Model(cfg, model.rules)
        self.model = model
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.n_spill = n_spill
        self.hot_window = min(cfg.kv_hot_window, max_len)
        # recurrent (SSM) prefill states are cumulative over the whole
        # padded sequence, so those architectures need exact-length prefill
        self.requires_exact_prefill = any(
            u.block.mixer in ("rwkv6", "mamba2") for u in model.plan)
        # non-final chunks must land on the canonical SSM chunk grid for
        # chunked prefill to stay bit-identical to whole-prompt prefill
        self.chunk_unit = (cfg.ssm.chunk_size
                           if self.requires_exact_prefill and cfg.ssm
                           else 1)
        shapes, _ = model.cache_spec(num_slots, max_len)
        self._axes = batch_axes(model, shapes)
        # compressed spill lanes restructure the tiered stores (hot ->
        # hot_q/hot_scale), so the lane tree carries its own axis tree.
        # A flat cache has no hot ring — nothing to compress — so the
        # flag resolves to off there, keeping spill_compress truthful
        # for lane-byte accounting, sim pricing and the CLI report.
        self.spill_compress = _resolve_spill_compress(spill_compress) \
            and cfg.kv_policy == "tiered"
        self._spill_axes = (map_spill_stores(self._axes,
                                             KT.spill_store_meta)
                            if self.spill_compress else self._axes)
        # paged prefix-store geometry (PR 7): the page size defaults to
        # the RRAM endurance-block granularity, rounded to the canonical
        # chunk grid for recurrent architectures (a state snapshot off
        # the grid could never seed a bit-identical resume) and clamped
        # to the slot length; the store defaults to enough blocks to
        # re-page every slot. Arrays and traces are lazy — an engine
        # that never pages pays nothing here.
        bt = block_tokens if block_tokens is not None \
            else min(KT.ENDURANCE_BLOCK, max_len)
        if bt < 1:
            raise ValueError(f"block_tokens must be >= 1, got {bt}")
        if self.chunk_unit > 1:
            bt = max((bt // self.chunk_unit) * self.chunk_unit,
                     self.chunk_unit)
        self.block_tokens = min(bt, max_len)
        if prefix_blocks is None:
            prefix_blocks = num_slots * (-(-max_len // self.block_tokens))
        if prefix_blocks < 1:
            raise ValueError(f"prefix_blocks must be >= 1, got "
                             f"{prefix_blocks}")
        self.prefix_blocks = prefix_blocks
        ext_shapes, _ = model.extend_spec(1, max_len)
        self._ext_axes = batch_axes(model, ext_shapes)
        self.has_prefix_ws = any(
            str(getattr(p[-1], "key", p[-1])).endswith("_ws")
            for p, _ in jax.tree_util.tree_flatten_with_path(ext_shapes)[0])
        self._zero_slot = None
        self._zero_ext = None
        self._step = jax.jit(self._build_step())
        self._insert = jax.jit(self._build_insert())
        self._ext_part = jax.jit(self._build_extend(commit=False))
        self._ext_commit = jax.jit(self._build_extend(commit=True))
        self._evict = jax.jit(self._build_evict())
        self._restore = jax.jit(self._build_restore())
        self._pfx_save_ws = jax.jit(self._build_prefix_ws(save=True))
        self._pfx_load_ws = jax.jit(self._build_prefix_ws(save=False))
        self._pfx_save_state = jax.jit(
            self._build_prefix_state(save=True))
        self._pfx_load_state = jax.jit(
            self._build_prefix_state(save=False))

    # ---- placement hooks (ShardedBackend overrides) ------------------
    def _place(self, cache: dict) -> dict:
        return cache

    def _constrain(self, cache: dict) -> dict:
        return cache

    def _place_ext(self, ext: dict) -> dict:
        return ext

    def _constrain_ext(self, ext: dict) -> dict:
        return ext

    def _place_spill(self, spill: dict) -> dict:
        return spill

    def _constrain_spill(self, spill: dict) -> dict:
        return spill

    def _place_prefix(self, store: dict) -> dict:
        return store

    def _constrain_prefix(self, store: dict) -> dict:
        return store

    # ---- jitted program builders -------------------------------------
    def _build_step(self):
        model, axes = self.model, self._axes

        def slot_step(p, tok, cache, pos):
            c1 = tree_expand(cache, axes)
            logits, nc = model.decode_step(p, tok[None], c1, pos)
            ntok = jnp.argmax(logits[0, -1], -1).astype(jnp.int32)
            return ntok, tree_squeeze(nc, axes)

        vm = jax.vmap(slot_step, in_axes=(None, 0, axes, 0),
                      out_axes=(0, axes))

        def step(p, toks, cache, pos, active):
            ntoks, nc = vm(p, toks, cache, pos)

            def sel(n, o, a):
                shp = [1] * n.ndim
                shp[a] = n.shape[a]
                return jnp.where(active.reshape(shp), n, o)

            # inactive slots keep their old cache verbatim: no phantom
            # appends, no endurance-counter drift while a slot is parked
            return ntoks, self._constrain(
                jax.tree.map(sel, nc, cache, axes))

        return step

    def _build_insert(self):
        axes = self._axes

        def insert(pool, req_cache, slot):
            out = jax.tree.map(
                lambda p, r, a: jax.lax.dynamic_update_slice_in_dim(
                    p, r.astype(p.dtype), slot, axis=a),
                pool, req_cache, axes)
            return self._constrain(out)

        return insert

    def _build_extend(self, commit: bool):
        model, axes = self.model, self._axes

        if not commit:
            def ext_part(p, batch, ext, pos, length):
                _, new_ext = model.extend(p, batch, ext, pos, length)
                return self._constrain_ext(new_ext)
            return ext_part

        def ext_commit(p, batch, pool, ext, slot, pos, length):
            # final chunk: the committed store-form cache scatters into
            # the already-allocated pool slot in the same program
            logits, committed = model.extend(p, batch, ext, pos, length,
                                             commit=True)
            tok = jnp.argmax(logits[0, -1], -1).astype(jnp.int32)
            pool = jax.tree.map(
                lambda pl, r, a: jax.lax.dynamic_update_slice_in_dim(
                    pl, r.astype(pl.dtype), slot, axis=a),
                pool, committed, axes)
            return tok, self._constrain(pool)

        return ext_commit

    def _build_evict(self):
        axes, spill_axes = self._axes, self._spill_axes
        compress = self.spill_compress

        def evict(cache, spill, spill_writes, slot, lane, length):
            # pack the slot's cache into the spill lane. Verbatim by
            # default: the cold tier is already RRAM-resident int8, and
            # the hot ring / scales / recurrent states / endurance
            # counters ride along untouched so the restore is bit-exact.
            # Under spill_compress the hot ring alone is requantized to
            # the int8 codec form (everything else still verbatim).
            img = jax.tree.map(
                lambda c, a: jax.lax.dynamic_slice_in_dim(c, slot, 1,
                                                          axis=a),
                cache, axes)
            if compress:
                img = map_spill_stores(img, KT.spill_store_compress)
            spill = jax.tree.map(
                lambda s, r, a: jax.lax.dynamic_update_slice_in_dim(
                    s, r.astype(s.dtype), lane, axis=a),
                spill, img, spill_axes)
            spill_writes = KT.bump_spill_writes(spill_writes, lane,
                                                length)
            return self._constrain_spill(spill), spill_writes

        return evict

    def _build_restore(self):
        axes, spill_axes = self._axes, self._spill_axes
        compress = self.spill_compress
        cd = jnp.dtype(self.model.cfg.compute_dtype)

        def restore(cache, spill, lane, slot):
            img = jax.tree.map(
                lambda s, a: jax.lax.dynamic_slice_in_dim(s, lane, 1,
                                                          axis=a),
                spill, spill_axes)
            if compress:
                img = map_spill_stores(
                    img, lambda st: KT.spill_store_decompress(st, cd))
            cache = jax.tree.map(
                lambda c, r, a: jax.lax.dynamic_update_slice_in_dim(
                    c, r.astype(c.dtype), slot, axis=a),
                cache, img, axes)
            return self._constrain(cache)

        return restore

    @staticmethod
    def _is_ws(path) -> bool:
        """Workspace leaves (`*_ws`) hold per-position K/V rows; every
        other extend leaf is a recurrent-state snapshot."""
        return str(getattr(path[-1], "key", path[-1])).endswith("_ws")

    def _build_prefix_ws(self, save: bool):
        """One-block workspace copy between the prefix store (block axis
        ``a``, ``block_tokens`` rows) and an in-flight extend workspace
        (batch-1, ``max_len`` rows at axis ``a+1``). State leaves ride
        through untouched — they move with `_build_prefix_state` only at
        a chain's terminal block."""
        axes, bt, is_ws = self._ext_axes, self.block_tokens, self._is_ws

        if save:
            def save_ws(store, ext, bid, pos):
                def leaf(path, s, e, a):
                    if not is_ws(path):
                        return s
                    row = jax.lax.dynamic_slice_in_dim(e, pos, bt,
                                                       axis=a + 1)
                    return jax.lax.dynamic_update_slice_in_dim(
                        s, row.astype(s.dtype), bid, axis=a)
                return self._constrain_prefix(
                    jax.tree_util.tree_map_with_path(leaf, store, ext,
                                                     axes))
            return save_ws

        def load_ws(ext, store, bid, pos):
            def leaf(path, e, s, a):
                if not is_ws(path):
                    return e
                row = jax.lax.dynamic_slice_in_dim(s, bid, 1, axis=a)
                return jax.lax.dynamic_update_slice_in_dim(
                    e, row.astype(e.dtype), pos, axis=a + 1)
            return self._constrain_ext(
                jax.tree_util.tree_map_with_path(leaf, ext, store, axes))
        return load_ws

    def _build_prefix_state(self, save: bool):
        """Recurrent-state snapshot copy: the non-workspace extend
        leaves (SSM/rwkv states after the whole prefix) move wholesale
        between block ``bid``'s state rows and the batch-1 extend tree.
        A pure-attention model has no such leaves and these programs are
        identity copies that never run (`has_prefix_ws` gating)."""
        axes, is_ws = self._ext_axes, self._is_ws

        if save:
            def save_state(store, ext, bid):
                def leaf(path, s, e, a):
                    if is_ws(path):
                        return s
                    return jax.lax.dynamic_update_slice_in_dim(
                        s, e.astype(s.dtype), bid, axis=a)
                return self._constrain_prefix(
                    jax.tree_util.tree_map_with_path(leaf, store, ext,
                                                     axes))
            return save_state

        def load_state(ext, store, bid):
            def leaf(path, e, s, a):
                if is_ws(path):
                    return e
                return jax.lax.dynamic_slice_in_dim(
                    s, bid, 1, axis=a).astype(e.dtype)
            return self._constrain_ext(
                jax.tree_util.tree_map_with_path(leaf, ext, store, axes))
        return load_state

    # ---- InferenceBackend surface ------------------------------------
    def slot_kv_bytes(self, *, length: int | None = None
                      ) -> tuple[int, int]:
        return slot_kv_bytes(self.model, self.max_len, length=length,
                             block_tokens=self.block_tokens)

    def spill_lane_bytes(self) -> int:
        return spill_lane_bytes(self.model, self.max_len,
                                self.spill_compress)

    def weight_bytes(self) -> tuple[int, int]:
        return weight_stream_split(self.model.cfg)

    def sim_context(self) -> tuple:
        return self.model.cfg, self.spill_compress

    def init_pool(self) -> KVPoolState:
        # spill buffers are LAZY: n_spill lanes are reserved (host-side
        # bookkeeping) but the RRAM-image arrays — a full extra copy of
        # the pool — only materialize on the first eviction, so engines
        # that never preempt pay nothing
        cache = self._place(
            self.model.init_cache(self.num_slots, self.max_len))
        return KVPoolState(cache=cache, axes=self._axes)

    def fresh_slot(self) -> dict:
        """Batch-1 zero cache (explicit slot scrub); built once, reused —
        insert is functional, so sharing the tree is safe."""
        if self._zero_slot is None:
            self._zero_slot = self.model.init_cache(1, self.max_len)
        return self._zero_slot

    def fresh_extend(self) -> dict:
        """Zero extend state (one in-flight prefill lane); built once and
        reused — extend is functional, and stale workspace tails beyond a
        committed length are never attendable, so sharing is safe. Only
        the recurrent-state leaves genuinely need the zeros."""
        if self._zero_ext is None:
            self._zero_ext = self._place_ext(
                self.model.init_extend_cache(1, self.max_len))
        return self._zero_ext

    def make_pool(self) -> TieredKVPool:
        return TieredKVPool(self.init_pool(), self._insert_state,
                            self.fresh_slot, num_spill_lanes=self.n_spill)

    def extend_step(self, batch: dict, state: KVPoolState, ext: dict,
                    slot, pos, length, commit: bool
                    ) -> tuple[jax.Array | None, dict | None, KVPoolState]:
        pos = jnp.asarray(pos, jnp.int32)
        length = jnp.asarray(length, jnp.int32)
        if not commit:
            new_ext = self._ext_part(self.params, batch, ext, pos, length)
            return None, new_ext, state
        tok, cache = self._ext_commit(
            self.params, batch, state.cache, ext,
            jnp.asarray(slot, jnp.int32), pos, length)
        return tok, None, dataclasses.replace(state, cache=cache)

    def decode_step(self, toks, state: KVPoolState, pos, active
                    ) -> tuple[jax.Array, KVPoolState]:
        ntoks, cache = self._step(
            self.params, jnp.asarray(toks), state.cache,
            jnp.asarray(pos), jnp.asarray(active))
        return ntoks, dataclasses.replace(state, cache=cache)

    def evict_slot(self, state: KVPoolState, slot, lane, length
                   ) -> KVPoolState:
        if self.n_spill == 0:
            raise ValueError("backend was built with n_spill=0; nothing "
                             "can be evicted")
        if state.spill is None:           # first eviction: materialize
            lanes = self.model.init_cache(self.n_spill, self.max_len)
            if self.spill_compress:
                lanes = map_spill_stores(lanes, KT.spill_store_template)
            state = dataclasses.replace(
                state,
                spill=self._place_spill(lanes),
                spill_writes=KT.init_spill_writes(self.n_spill,
                                                  self.max_len),
                spill_axes=(self._spill_axes if self.spill_compress
                            else None))
        spill, writes = self._evict(
            state.cache, state.spill, state.spill_writes,
            jnp.asarray(slot, jnp.int32), jnp.asarray(lane, jnp.int32),
            jnp.asarray(length, jnp.int32))
        return dataclasses.replace(state, spill=spill, spill_writes=writes)

    def restore_slot(self, state: KVPoolState, lane, slot
                     ) -> KVPoolState:
        if state.spill is None:
            raise ValueError("nothing has been spilled; there is no "
                             "lane to restore from")
        cache = self._restore(state.cache, state.spill,
                              jnp.asarray(lane, jnp.int32),
                              jnp.asarray(slot, jnp.int32))
        return dataclasses.replace(state, cache=cache)

    def _insert_state(self, state: KVPoolState, req_cache: dict, slot
                     ) -> KVPoolState:
        """Scatter a batch-1 cache into slot ``slot`` (pool internals:
        recycling scrubs; not part of the serving step surface)."""
        cache = self._insert(state.cache, req_cache,
                             jnp.asarray(slot, jnp.int32))
        return dataclasses.replace(state, cache=cache)

    # ---- paged prefix block store (PR 7) -----------------------------
    def ensure_prefix(self, state: KVPoolState) -> KVPoolState:
        """Materialize the prefix block store on first use (lazy, like
        the spill lanes): a zero extend tree with the batch axis sized
        to ``prefix_blocks`` and the sequence axis to ``block_tokens``."""
        if state.prefix is not None:
            return state
        store = self._place_prefix(self.model.init_extend_cache(
            self.prefix_blocks, self.block_tokens))
        return dataclasses.replace(state, prefix=store,
                                   prefix_axes=self._ext_axes)

    def prefix_block_bytes(self) -> int:
        """Bytes ONE prefix block pins (workspace rows + state-snapshot
        rows) — what the scheduler charges the shared store against the
        RRAM budget per live block."""
        shapes, _ = self.model.extend_spec(1, self.block_tokens)
        total = 0
        for leaf in jax.tree.leaves(shapes):
            n = jnp.dtype(leaf.dtype).itemsize
            for d in leaf.shape:
                n *= d
            total += n
        return int(total)

    def prefix_save_ws(self, state: KVPoolState, ext: dict, bid, pos
                       ) -> KVPoolState:
        """Write workspace rows [pos, pos+block_tokens) of ``ext`` into
        block ``bid`` — the ONE physical write a shared block ever
        takes."""
        store = self._pfx_save_ws(state.prefix, ext,
                                  jnp.asarray(bid, jnp.int32),
                                  jnp.asarray(pos, jnp.int32))
        return dataclasses.replace(state, prefix=store)

    def prefix_load_ws(self, state: KVPoolState, ext: dict, bid, pos
                       ) -> dict:
        """Seed block ``bid``'s rows into ``ext`` at position ``pos``
        (admission gather of one hit block)."""
        return self._pfx_load_ws(ext, state.prefix,
                                 jnp.asarray(bid, jnp.int32),
                                 jnp.asarray(pos, jnp.int32))

    def prefix_save_state(self, state: KVPoolState, ext: dict, bid
                          ) -> KVPoolState:
        """Snapshot ``ext``'s recurrent states into block ``bid`` (the
        chain-terminal resume point for SSM architectures)."""
        store = self._pfx_save_state(state.prefix, ext,
                                     jnp.asarray(bid, jnp.int32))
        return dataclasses.replace(state, prefix=store)

    def prefix_load_state(self, state: KVPoolState, ext: dict, bid
                          ) -> dict:
        return self._pfx_load_state(ext, state.prefix,
                                    jnp.asarray(bid, jnp.int32))


class LocalBackend(_JittedBackend):
    """Single-host vmapped executor: the seed engine's decode path,
    extracted. Params and pool live wherever jax's default device is."""


class ShardedBackend(_JittedBackend):
    """pjit executor over a device mesh.

    Params are committed to the model's `param_shardings` resolution and
    the KV pool to `Model.cache_shardings` (slots -> 'data' axis, cold
    kv_seq / kv heads -> 'model' axis, with the resolver's divisibility
    fallback). The decode/prefill jaxpr is built from a rules-free model
    twin — identical to `LocalBackend`'s — and the pool layout is pinned
    by `with_sharding_constraint` at the step/insert outputs, so XLA's
    SPMD partitioner steers the interior while a 1-device mesh stays
    numerically identical to the local path.
    """

    def __init__(self, model: Model, params, num_slots: int, max_len: int,
                 mesh: jax.sharding.Mesh | None = None,
                 rules: ShardingRules | None = None,
                 n_spill: int | None = None,
                 spill_compress: bool | None = None,
                 prefix_blocks: int | None = None,
                 block_tokens: int | None = None,
                 fused_decode: bool | None = None,
                 sparse_read: float | None = None,
                 weight_stream: int | None = None):
        if mesh is None:
            from repro.launch.mesh import make_local_mesh
            mesh = make_local_mesh()
        # rules-free twin: the step jaxpr matches LocalBackend exactly;
        # sharding enters only at the jit boundaries below
        if model.rules is not None:
            model = Model(model.cfg)
        self.mesh = mesh
        self.rules = rules or ShardingRules(mesh)
        self._pool_sh = model.cache_shardings(self.rules, num_slots,
                                              max_len)
        self._ext_sh = model.extend_shardings(self.rules, 1, max_len)
        if n_spill is None:
            n_spill = num_slots
        # spill lanes shard exactly like pool slots (lanes -> 'data',
        # cold kv_seq / kv heads -> 'model'), so evict/restore stay
        # device-local tree copies wherever divisibility allows. A
        # compressed lane's hot_q inherits the hot ring's sharding and
        # hot_scale its leading axes (the size-1 scale axis was already
        # unsharded in the hot spec).
        spill_compress = _resolve_spill_compress(spill_compress)
        self._spill_sh = (model.cache_shardings(self.rules, n_spill,
                                                max_len)
                          if n_spill else None)
        if self._spill_sh is not None and spill_compress:
            self._spill_sh = map_spill_stores(self._spill_sh,
                                              KT.spill_store_meta)
        params = jax.device_put(params,
                                model.param_shardings(self.rules))
        # prefix-store shardings depend on the block geometry the base
        # ctor resolves, so they build lazily on first prefix use
        self._pfx_sh = None
        super().__init__(model, params, num_slots, max_len,
                         n_spill=n_spill, spill_compress=spill_compress,
                         prefix_blocks=prefix_blocks,
                         block_tokens=block_tokens,
                         fused_decode=fused_decode,
                         sparse_read=sparse_read,
                         weight_stream=weight_stream)

    def _place(self, cache: dict) -> dict:
        return jax.device_put(cache, self._pool_sh)

    def _constrain(self, cache: dict) -> dict:
        return jax.lax.with_sharding_constraint(cache, self._pool_sh)

    def _place_ext(self, ext: dict) -> dict:
        return jax.device_put(ext, self._ext_sh)

    def _constrain_ext(self, ext: dict) -> dict:
        return jax.lax.with_sharding_constraint(ext, self._ext_sh)

    def _place_spill(self, spill: dict) -> dict:
        return jax.device_put(spill, self._spill_sh)

    def _constrain_spill(self, spill: dict) -> dict:
        return jax.lax.with_sharding_constraint(spill, self._spill_sh)

    def _prefix_shardings(self):
        """Blocks shard like slots ('data'); the per-block sequence axis
        (block_tokens wide) keeps the workspace logical axes, with the
        resolver's divisibility fallback to replicated."""
        if self._pfx_sh is None:
            self._pfx_sh = self.model.extend_shardings(
                self.rules, self.prefix_blocks, self.block_tokens)
        return self._pfx_sh

    def _place_prefix(self, store: dict) -> dict:
        return jax.device_put(store, self._prefix_shardings())

    def _constrain_prefix(self, store: dict) -> dict:
        return jax.lax.with_sharding_constraint(store,
                                                self._prefix_shardings())


def make_backend(kind: str, model: Model, params, *, num_slots: int,
                 max_len: int, mesh=None,
                 n_spill: int | None = None,
                 spill_compress: bool | None = None,
                 prefix_blocks: int | None = None,
                 block_tokens: int | None = None,
                 fused_decode: bool | None = None,
                 sparse_read: float | None = None,
                 weight_stream: int | None = None) -> InferenceBackend:
    """CLI-facing factory: ``kind`` in {'local', 'sharded'}."""
    if kind == "local":
        return LocalBackend(model, params, num_slots, max_len,
                            n_spill=n_spill,
                            spill_compress=spill_compress,
                            prefix_blocks=prefix_blocks,
                            block_tokens=block_tokens,
                            fused_decode=fused_decode,
                            sparse_read=sparse_read,
                            weight_stream=weight_stream)
    if kind == "sharded":
        return ShardedBackend(model, params, num_slots, max_len, mesh=mesh,
                              n_spill=n_spill,
                              spill_compress=spill_compress,
                              prefix_blocks=prefix_blocks,
                              block_tokens=block_tokens,
                              fused_decode=fused_decode,
                              sparse_read=sparse_read,
                              weight_stream=weight_stream)
    raise ValueError(f"unknown backend kind {kind!r}")
