"""Backend-executor seam: `InferenceBackend` is the protocol the serving
engine drives, decoupling scheduling from execution strategy.

A backend owns three things the engine must never reach into directly:

  * the parameters (placed however the backend likes — host, one device,
    or pjit-sharded over a mesh),
  * the KV pool layout, handed out as an explicit typed pytree
    (`kv_pool.KVPoolState`) rather than a model-aware object, and
  * the jitted `prefill(batch, length)` / `decode_step(toks, state, pos,
    active)` entry points plus the slot-insert arithmetic.

Two implementations ship:

  * `LocalBackend` — the single-host vmapped path (the seed engine's
    jitted closures, extracted verbatim): one jit-compiled step advances
    every slot, each slot attending its own hot ring + cold tier at its
    own position.
  * `ShardedBackend` — the same step jaxpr executed under pjit on a
    `launch/mesh.py` mesh: params are placed by the model's
    `param_shardings` rules and the KV pool by `Model.cache_shardings`
    (slots -> 'data', cold kv_seq / kv heads -> 'model', divisibility
    permitting). The decode jaxpr is built from a rules-free model twin
    and the layout is pinned with sharding constraints at the jit
    boundaries only, so a 1-device mesh is token-for-token identical to
    `LocalBackend` (tests/test_serving_sharded.py holds both meshes to
    exact parity).

The engine, scheduler, metrics and endurance audit run unmodified on
either backend; this seam is where later scale-out work (multi-host,
async prefill, disaggregated tiers) plugs in.
"""

from __future__ import annotations

from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.models import Model
from repro.serving.kv_pool import (KVPoolState, TieredKVPool, batch_axes,
                                   slot_kv_bytes, tree_expand, tree_squeeze)
from repro.sharding import ShardingRules


@runtime_checkable
class InferenceBackend(Protocol):
    """What the engine needs from an executor. Any object with this
    surface can serve; the engine never touches model internals."""

    num_slots: int            # decode slots the pool is laid out for
    max_len: int              # per-slot KV length
    hot_window: int           # effective hot-ring length (endurance audit)
    requires_exact_prefill: bool   # recurrent states forbid padded buckets

    def slot_kv_bytes(self) -> tuple[int, int]:
        """(dram_hot, rram_cold) bytes one resident request pins."""
        ...

    def make_pool(self) -> TieredKVPool:
        """Fresh slot pool wired to this backend's insert arithmetic."""
        ...

    def prefill(self, batch: dict, length: int
                ) -> tuple[jax.Array, dict]:
        """Prefill one request -> (first greedy token, batch-1 cache)."""
        ...

    def decode_step(self, toks, state: KVPoolState, pos, active
                    ) -> tuple[jax.Array, KVPoolState]:
        """One greedy token on every active slot; inactive slots' cache
        is kept verbatim (no phantom appends, no endurance drift)."""
        ...

    def insert(self, state: KVPoolState, req_cache: dict, slot
               ) -> KVPoolState:
        """Overwrite slot ``slot`` with a batch-1 per-request cache."""
        ...


class _JittedBackend:
    """Shared scaffolding: validates the config, derives the slot-axis
    tree, and builds the three jitted programs (step / prefill / insert).
    Subclasses steer placement via `_place` and `_constrain`."""

    def __init__(self, model: Model, params, num_slots: int, max_len: int):
        cfg = model.cfg
        if cfg.is_encoder:
            raise ValueError("encoder-only model cannot be served")
        if num_slots is None or max_len is None:
            raise TypeError("backend needs num_slots and max_len")
        if num_slots < 1:
            raise ValueError("backend needs at least one decode slot")
        self.model = model
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.hot_window = min(cfg.kv_hot_window, max_len)
        # recurrent (SSM) prefill states are cumulative over the whole
        # padded sequence, so those architectures need exact-length prefill
        self.requires_exact_prefill = any(
            u.block.mixer in ("rwkv6", "mamba2") for u in model.plan)
        shapes, _ = model.cache_spec(num_slots, max_len)
        self._axes = batch_axes(model, shapes)
        self._zero_slot = None
        self._step = jax.jit(self._build_step())
        self._prefill = jax.jit(self._build_prefill())
        self._insert = jax.jit(self._build_insert())

    # ---- placement hooks (ShardedBackend overrides) ------------------
    def _place(self, cache: dict) -> dict:
        return cache

    def _constrain(self, cache: dict) -> dict:
        return cache

    # ---- jitted program builders -------------------------------------
    def _build_step(self):
        model, axes = self.model, self._axes

        def slot_step(p, tok, cache, pos):
            c1 = tree_expand(cache, axes)
            logits, nc = model.decode_step(p, tok[None], c1, pos)
            ntok = jnp.argmax(logits[0, -1], -1).astype(jnp.int32)
            return ntok, tree_squeeze(nc, axes)

        vm = jax.vmap(slot_step, in_axes=(None, 0, axes, 0),
                      out_axes=(0, axes))

        def step(p, toks, cache, pos, active):
            ntoks, nc = vm(p, toks, cache, pos)

            def sel(n, o, a):
                shp = [1] * n.ndim
                shp[a] = n.shape[a]
                return jnp.where(active.reshape(shp), n, o)

            # inactive slots keep their old cache verbatim: no phantom
            # appends, no endurance-counter drift while a slot is parked
            return ntoks, self._constrain(
                jax.tree.map(sel, nc, cache, axes))

        return step

    def _build_prefill(self):
        model, max_len = self.model, self.max_len

        def prefill(p, batch, length):
            logits, cache = model.prefill(p, batch, max_len, length)
            tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
            return tok[0], cache

        return prefill

    def _build_insert(self):
        axes = self._axes

        def insert(pool, req_cache, slot):
            out = jax.tree.map(
                lambda p, r, a: jax.lax.dynamic_update_slice_in_dim(
                    p, r.astype(p.dtype), slot, axis=a),
                pool, req_cache, axes)
            return self._constrain(out)

        return insert

    # ---- InferenceBackend surface ------------------------------------
    def slot_kv_bytes(self) -> tuple[int, int]:
        return slot_kv_bytes(self.model, self.max_len)

    def init_pool(self) -> KVPoolState:
        cache = self._place(
            self.model.init_cache(self.num_slots, self.max_len))
        return KVPoolState(cache=cache, axes=self._axes)

    def fresh_slot(self) -> dict:
        """Batch-1 zero cache (explicit slot scrub); built once, reused —
        insert is functional, so sharing the tree is safe."""
        if self._zero_slot is None:
            self._zero_slot = self.model.init_cache(1, self.max_len)
        return self._zero_slot

    def make_pool(self) -> TieredKVPool:
        return TieredKVPool(self.init_pool(), self.insert, self.fresh_slot)

    def prefill(self, batch: dict, length) -> tuple[jax.Array, dict]:
        return self._prefill(self.params, batch,
                             jnp.asarray(length, jnp.int32))

    def decode_step(self, toks, state: KVPoolState, pos, active
                    ) -> tuple[jax.Array, KVPoolState]:
        ntoks, cache = self._step(
            self.params, jnp.asarray(toks), state.cache,
            jnp.asarray(pos), jnp.asarray(active))
        return ntoks, KVPoolState(cache=cache, axes=state.axes)

    def insert(self, state: KVPoolState, req_cache: dict, slot
               ) -> KVPoolState:
        cache = self._insert(state.cache, req_cache,
                             jnp.asarray(slot, jnp.int32))
        return KVPoolState(cache=cache, axes=state.axes)


class LocalBackend(_JittedBackend):
    """Single-host vmapped executor: the seed engine's decode path,
    extracted. Params and pool live wherever jax's default device is."""


class ShardedBackend(_JittedBackend):
    """pjit executor over a device mesh.

    Params are committed to the model's `param_shardings` resolution and
    the KV pool to `Model.cache_shardings` (slots -> 'data' axis, cold
    kv_seq / kv heads -> 'model' axis, with the resolver's divisibility
    fallback). The decode/prefill jaxpr is built from a rules-free model
    twin — identical to `LocalBackend`'s — and the pool layout is pinned
    by `with_sharding_constraint` at the step/insert outputs, so XLA's
    SPMD partitioner steers the interior while a 1-device mesh stays
    numerically identical to the local path.
    """

    def __init__(self, model: Model, params, num_slots: int, max_len: int,
                 mesh: jax.sharding.Mesh | None = None,
                 rules: ShardingRules | None = None):
        if mesh is None:
            from repro.launch.mesh import make_local_mesh
            mesh = make_local_mesh()
        # rules-free twin: the step jaxpr matches LocalBackend exactly;
        # sharding enters only at the jit boundaries below
        if model.rules is not None:
            model = Model(model.cfg)
        self.mesh = mesh
        self.rules = rules or ShardingRules(mesh)
        self._pool_sh = model.cache_shardings(self.rules, num_slots,
                                              max_len)
        params = jax.device_put(params,
                                model.param_shardings(self.rules))
        super().__init__(model, params, num_slots, max_len)

    def _place(self, cache: dict) -> dict:
        return jax.device_put(cache, self._pool_sh)

    def _constrain(self, cache: dict) -> dict:
        return jax.lax.with_sharding_constraint(cache, self._pool_sh)


def make_backend(kind: str, model: Model, params, *, num_slots: int,
                 max_len: int, mesh=None) -> InferenceBackend:
    """CLI-facing factory: ``kind`` in {'local', 'sharded'}."""
    if kind == "local":
        return LocalBackend(model, params, num_slots, max_len)
    if kind == "sharded":
        return ShardedBackend(model, params, num_slots, max_len, mesh=mesh)
    raise ValueError(f"unknown backend kind {kind!r}")
