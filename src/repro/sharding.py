"""Logical-axis sharding rules with divisibility fallback.

MaxText-style: every tensor dimension carries a *logical* axis name; a rule
table maps logical names to an ordered chain of mesh-axis candidates. A
candidate binds only if (a) every mesh axis in it exists in the mesh, (b) the
dimension size is divisible by the product of the candidate axis sizes, and
(c) none of its mesh axes is already used by another dimension of the same
tensor. Otherwise the resolver falls through to the next candidate and
ultimately replicates that dimension.

This is what lets a single config-driven model zoo compile on every
(arch x shape x mesh) cell: awkward head counts (e.g. starcoder2's 36 Q /
4 KV heads vs a model axis of 16) degrade gracefully to replication instead
of erroring, and the roofline analysis then quantifies the cost.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Each logical axis maps to an ordered chain of candidates. A candidate is a
# tuple of mesh axis names (sharded over their product) or () for "replicate".
# "pod" appears jointly with "data" for batch so multi-pod meshes shard the
# global batch over pods too.
DEFAULT_RULES: dict[str, tuple[tuple[str, ...], ...]] = {
    # data-parallel dims
    "batch": (("pod", "data"), ("data",)),
    # sequence: replicated for training activations by default; long-context
    # decode re-binds kv_seq below (sequence parallelism).
    "seq": ((),),
    # Megatron-style sequence parallelism for the residual stream: the
    # saved layer activations shard their seq dim over 'model' (enabled by
    # cfg.seq_sharding; XLA inserts the gather/scatter at attention edges)
    "seq_sp": (("model",), ()),
    "kv_seq": ((),),
    # KV-cache sequence dim: long-context decode shards it over 'data'
    # (batch too small), otherwise over 'model' — GQA kv-head counts (1-8)
    # rarely divide a 16-way model axis, so sharding the cache's seq dim is
    # what actually distributes the KV bytes (softmax partials reduce over
    # the shards via XLA collectives).
    "kv_seq_shard": (("data",), ("model",), ()),
    # model-parallel dims
    "embed": ((),),
    "fsdp_embed": (("data",), ()),           # ZeRO-3-ish weight storage dim
    "mlp": (("model",), ()),
    "heads": (("model",), ()),
    "kv_heads": (("model",), ()),
    "qkv_flat": (("model",), ()),
    "vocab": (("model",), ()),
    "experts": (("model",), ()),
    "moe_ff": (("data",), ()),   # expert-FFN dim FSDP (cfg.moe_ff_fsdp)
    "head_dim": ((),),
    "state": ((),),
    # generic never-sharded
    "none": ((),),
}


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Resolves logical axis names -> PartitionSpec for a given mesh."""

    mesh: Mesh
    rules: Mapping[str, tuple[tuple[str, ...], ...]] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_RULES)
    )

    def with_overrides(self, **over: tuple[tuple[str, ...], ...]) -> "ShardingRules":
        merged = dict(self.rules)
        merged.update(over)
        return ShardingRules(self.mesh, merged)

    def _axis_size(self, axes: tuple[str, ...]) -> int:
        size = 1
        for a in axes:
            size *= self.mesh.shape[a]
        return size

    def _candidate_ok(self, cand: tuple[str, ...], dim: int | None,
                      used: set[str]) -> bool:
        for a in cand:
            if a not in self.mesh.shape or a in used:
                return False
        if dim is not None and cand and dim % self._axis_size(cand) != 0:
            return False
        return True

    def spec(self, logical: Sequence[str | None],
             shape: Sequence[int] | None = None) -> P:
        """Resolve a logical-axis tuple (one entry per tensor dim) to a
        PartitionSpec, applying the divisibility fallback chain per dim."""
        if shape is not None and len(shape) != len(logical):
            raise ValueError(f"logical {logical} vs shape {shape} rank mismatch")
        used: set[str] = set()
        out: list[tuple[str, ...] | None] = []
        for i, name in enumerate(logical):
            if name is None:
                out.append(None)
                continue
            if name not in self.rules:
                raise KeyError(f"unknown logical axis {name!r}")
            dim = None if shape is None else shape[i]
            chosen: tuple[str, ...] = ()
            for cand in self.rules[name]:
                if self._candidate_ok(cand, dim, used):
                    chosen = cand
                    break
            used.update(chosen)
            if not chosen:
                out.append(None)
            elif len(chosen) == 1:
                out.append(chosen[0])  # type: ignore[arg-type]
            else:
                out.append(chosen)
        return P(*out)

    def sharding(self, logical: Sequence[str | None],
                 shape: Sequence[int] | None = None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical, shape))


def logical_constraint(rules: ShardingRules, x: jax.Array,
                       logical: Sequence[str | None]) -> jax.Array:
    """with_sharding_constraint by logical names (divisibility-aware)."""
    return jax.lax.with_sharding_constraint(
        x, rules.sharding(logical, x.shape))


def tree_shardings(rules: ShardingRules, logical_tree, shape_tree):
    """Map a pytree of logical-axis tuples + matching ShapeDtypeStructs to a
    pytree of NamedShardings."""
    return jax.tree.map(
        lambda lg, sd: rules.sharding(lg, sd.shape),
        logical_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )
