import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes, and extract the roofline terms from the
compiled artifact.

Usage:
    python -m repro.launch.dryrun --arch granite-3-2b --shape train_4k
    python -m repro.launch.dryrun --arch ... --shape ... --multi-pod
    python -m repro.launch.dryrun --all            # every runnable cell
    python -m repro.launch.dryrun --list

Results land in experiments/dryrun/<arch>@<shape>@<mesh>.json:
memory_analysis (bytes/device), cost_analysis (FLOPs, bytes), the
collective-byte breakdown parsed from the optimized HLO, and timing.
"""  # noqa: E402

import argparse
import json
import pathlib
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import shape_by_name
from repro.launch import hlo_analysis
from repro.launch.cells import (
    all_cells, cell_config, grad_accum_dtype, input_specs, runnable_cells,
    train_microbatches)
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (
    abstract_train_state, batch_shardings, cache_shardings,
    make_prefill_step, make_serve_step, make_train_step, replicated,
    train_state_shardings)
from repro.models import Model
from repro.optim import AdamWConfig
from repro.sharding import ShardingRules

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               overrides: dict | None = None):
    """Lower + compile one cell. Returns (lowered, compiled, model, meta).
    overrides may include the exec-level key "microbatches"."""
    shape = shape_by_name(shape_name)
    overrides = dict(overrides or {})
    mb_override = overrides.pop("microbatches", None)
    cfg = cell_config(arch, shape, **overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = ShardingRules(mesh)
    model = Model(cfg, rules)
    specs = input_specs(cfg, shape)
    b_sh = batch_shardings(cfg, shape, rules, specs)

    with mesh:
        if shape.kind == "train":
            opt_cfg = AdamWConfig(
                state_dtype="bfloat16" if cfg.param_dtype == "bfloat16"
                else "float32")
            step = make_train_step(
                model, opt_cfg,
                microbatches=(mb_override if mb_override is not None
                              else train_microbatches(arch, shape)),
                grad_accum_dtype=grad_accum_dtype(arch))
            state_sds = abstract_train_state(model, opt_cfg)
            state_sh = train_state_shardings(model, rules, opt_cfg)
            lowered = jax.jit(
                step,
                in_shardings=(state_sh, b_sh),
                out_shardings=(state_sh, None),
                donate_argnums=(0,),
            ).lower(state_sds, specs)
        elif shape.kind == "prefill":
            step = make_prefill_step(model, max_len=shape.seq_len)
            p_sds, _ = model.abstract_params()
            p_sh = model.param_shardings(rules)
            lowered = jax.jit(
                step, in_shardings=(p_sh, b_sh),
            ).lower(p_sds, specs)
        else:  # decode
            step = make_serve_step(model)
            p_sds, _ = model.abstract_params()
            p_sh = model.param_shardings(rules)
            c_sds, _ = model.cache_spec(shape.global_batch, shape.seq_len)
            c_sh = cache_shardings(model, rules, shape.global_batch,
                                   shape.seq_len)
            pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = jax.jit(
                step,
                in_shardings=(p_sh, b_sh["tokens"], c_sh, replicated(rules)),
                out_shardings=(None, c_sh),
                donate_argnums=(2,),
            ).lower(p_sds, specs["tokens"], c_sds, pos_sds)
        compiled = lowered.compile()
    return lowered, compiled, model, {"cfg": cfg, "shape": shape,
                                      "mesh": mesh}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides: dict | None = None, tag: str = "") -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    name = f"{arch}@{shape_name}@{mesh_name}" + (f"@{tag}" if tag else "")
    t0 = time.time()
    lowered, compiled, model, meta = lower_cell(
        arch, shape_name, multi_pod, overrides)
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = hlo_analysis.collective_bytes(compiled.as_text(), model)
    n_chips = 512 if multi_pod else 256

    result = {
        "cell": name,
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "n_chips": n_chips,
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": (getattr(mem, "temp_size_in_bytes", 0) or 0)
            + (getattr(mem, "argument_size_in_bytes", 0) or 0),
        },
        "cost": {
            "flops_per_device": cost.get("flops"),
            "bytes_accessed_per_device": cost.get("bytes accessed"),
        },
        "collectives": coll,
        "params": model.cfg.param_count(),
        "active_params": model.cfg.active_param_count(),
        "padded_vocab": model.padded_vocab,
    }
    roof = hlo_analysis.roofline_terms(result, meta["shape"])
    result["roofline"] = roof
    print(f"[dryrun] {name}: compile={t_compile:.1f}s "
          f"peak={result['memory']['peak_bytes'] / 1e9:.2f}GB/dev "
          f"dominant={roof['dominant']}")
    return result


def save_result(res: dict):
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    path = OUT_DIR / (res["cell"].replace("/", "_") + ".json")
    path.write_text(json.dumps(res, indent=2, default=str))
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--kv-policy", default=None,
                    help="override kv policy (flat|tiered)")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (repeatable), e.g. "
                         "--set mla_absorbed=true --set kv_hot_window=4096")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    if args.list:
        for c in all_cells():
            status = f"SKIP({c.skip_reason})" if c.skip_reason else "RUN"
            print(f"{c.name:45s} {status}")
        return

    overrides = {}
    if args.kv_policy:
        overrides["kv_policy"] = args.kv_policy
    for kv in args.set:
        k, v = kv.split("=", 1)
        if v.lower() in ("true", "false"):
            overrides[k] = v.lower() == "true"
        else:
            try:
                overrides[k] = int(v)
            except ValueError:
                overrides[k] = v

    if args.all:
        ok, fail = 0, 0
        for c in runnable_cells():
            for mp in ((False, True) if args.both_meshes else (False,)):
                try:
                    res = run_cell(c.arch, c.shape.name, mp, overrides,
                                   args.tag)
                    save_result(res)
                    ok += 1
                except Exception as e:  # noqa: BLE001
                    fail += 1
                    print(f"[dryrun] FAIL {c.name} multi_pod={mp}: {e}")
                    traceback.print_exc()
        print(f"[dryrun] done: {ok} ok, {fail} failed")
        sys.exit(1 if fail else 0)

    meshes = ((False, True) if args.both_meshes
              else ((args.multi_pod,)))
    for mp in meshes:
        res = run_cell(args.arch, args.shape, mp, overrides, args.tag)
        path = save_result(res)
        print(f"[dryrun] wrote {path}")


if __name__ == "__main__":
    main()
