"""Batched serving driver: prefill + decode with the CHIME tiered KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch paligemma-3b \
        --reduced --batch 4 --prompt-len 32 --gen 16 --kv-policy tiered
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core import kv_tiers as KT
from repro.data import DataConfig, SyntheticPipeline
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models import Model
from repro.sharding import ShardingRules


def generate(model: Model, params, batch: dict, prompt_len: int,
             gen_len: int, greedy: bool = True, rng=None):
    """Prefill then autoregressive decode; returns (tokens, cache)."""
    max_len = prompt_len + gen_len
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, max_len))(params, batch)
    step = jax.jit(model.decode_step)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    toks = [tok]
    for i in range(gen_len - 1):
        pos = jnp.asarray(prompt_len + i, jnp.int32)
        logits, cache = step(params, tok, cache, pos)
        if greedy or rng is None:
            tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        else:
            rng, k = jax.random.split(rng)
            tok = jax.random.categorical(
                k, logits[:, -1])[:, None].astype(jnp.int32)
        toks.append(tok)
    return jnp.concatenate(toks, axis=1), cache


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paligemma-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--kv-policy", default="tiered",
                    choices=["flat", "tiered"])
    ap.add_argument("--hot-window", type=int, default=16)
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced).replace(
        param_dtype="float32", compute_dtype="float32", remat="none",
        kv_policy=args.kv_policy, kv_hot_window=args.hot_window)
    mesh = (make_production_mesh() if args.production_mesh
            else make_local_mesh())
    rules = ShardingRules(mesh)
    model = Model(cfg, rules)

    pipe = SyntheticPipeline(cfg, DataConfig(args.batch, args.prompt_len))
    batch = pipe.host_slice(0)
    batch.pop("labels", None)
    batch.pop("loss_mask", None)

    with mesh:
        params = model.init(jax.random.PRNGKey(0))
        t0 = time.time()
        toks, cache = generate(model, params, batch, args.prompt_len,
                               args.gen)
        dt = time.time() - t0
        total = args.batch * args.gen
        print(f"[serve] arch={args.arch} kv={args.kv_policy} "
              f"generated {toks.shape} in {dt:.2f}s "
              f"({total / dt:.1f} tok/s incl. compile)")
        if args.kv_policy == "tiered":
            # endurance report from the first attention layer's K store
            for ucache in jax.tree.leaves(
                    {k: v for k, v in cache.items()},
                    is_leaf=lambda x: isinstance(x, dict) and "hot" in x):
                if isinstance(ucache, dict) and "hot" in ucache:
                    rep = KT.endurance_report(ucache)
                    print(f"[serve] cold-tier writes: total="
                          f"{int(rep['total_cold_writes'])} "
                          f"max/block={int(rep['max_writes_per_block'])}")
                    break
        print("[serve] sample token ids:", toks[0, :12].tolist())
        return toks


if __name__ == "__main__":
    main()
