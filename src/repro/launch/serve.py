"""Serving CLI: a thin front-end over the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch paligemma-3b \
        --reduced --requests 8 --concurrency 4 --prompt-len 32 --gen 16 \
        --kv-policy tiered

`generate` below is the single-request reference path (prefill + one
sequence of decode steps); the engine's per-slot decode is numerically
identical to it, and tests/test_serving.py holds the two to exact token
agreement.

Execution strategy is picked by --backend: 'local' runs the single-host
vmapped decode, 'sharded' runs the same step under pjit on a --mesh
(local | production | multipod | DxM) with params sharded by the model's
rules and the KV pool slots over 'data' / cold kv_seq over 'model'.
tests/test_serving_sharded.py holds the two backends to exact token
parity.

--chunk-tokens N enables Sarathi-style chunked prefill: prompts stream
into their pool slot in N-token chunks through the backend's unified
`extend_step`, so decode slots keep emitting between chunks instead of
stalling for a whole (vision) prompt. Chunked and whole-prompt prefill
are token-for-token identical (tests/test_serving_chunked.py).

--oversubscribe F relaxes the scheduler's DRAM admission gate by F
(spill-lane-backed; see serving/scheduler.py), and --priority-every K
marks every K-th request as priority-1 interactive traffic, submitted
mid-run so it preempts a running victim: the victim's KV slot spills to
an RRAM lane and restores bit-exactly (tests/test_serving_preempt.py
holds preempted == uninterrupted == generate()).

--idle-offload-steps N turns RRAM into a first-class capacity tier:
runners resident >= N decode steps proactively offload (bit-exact,
verbatim lanes) so blocked equal-priority waiters admit under the base
DRAM gate — no oversubscribe factor needed
(tests/test_serving_spill.py holds offloaded == uninterrupted ==
generate()). --spill-compress stores the lanes' hot ring int8-quantized
(a parked image costs ~the cold tier's bytes; restore is then
bounded-error rather than bit-exact — see the codec contract in
core/quant.py).

--trace-out / --metrics-out / --snapshots-out / --stats-every turn on
the serving telemetry hub (serving/telemetry.py): a Perfetto/Chrome
timeline of engine phases and per-slot/lane/request lifecycles, a
Prometheus text exposition of the counters/gauges/decision codes, and
periodic JSONL snapshots including the simulated per-tier traffic
ledger (which reconciles bit-for-bit with `simulated_efficiency` on a
drained run). Telemetry is off — a no-op null object — unless one of
these flags is given.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models import Model


def generate(model: Model, params, batch: dict, prompt_len: int,
             gen_len: int, greedy: bool = True, rng=None):
    """Prefill then autoregressive decode; returns (tokens, cache)."""
    max_len = prompt_len + gen_len
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, max_len))(params, batch)
    step = jax.jit(model.decode_step)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    toks = [tok]
    for i in range(gen_len - 1):
        pos = jnp.asarray(prompt_len + i, jnp.int32)
        logits, cache = step(params, tok, cache, pos)
        if greedy or rng is None:
            tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        else:
            rng, k = jax.random.split(rng)
            tok = jax.random.categorical(
                k, logits[:, -1])[:, None].astype(jnp.int32)
        toks.append(tok)
    return jnp.concatenate(toks, axis=1), cache


def main(argv=None):
    from repro.launch.mesh import get_mesh
    from repro.serving import (Engine, Telemetry, aggregate_metrics,
                               make_backend, make_synthetic_requests,
                               simulated_efficiency)

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paligemma-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--backend", default="local",
                    choices=["local", "sharded"],
                    help="executor: single-host vmapped decode or "
                         "pjit-sharded over --mesh")
    ap.add_argument("--mesh", default="local",
                    help="sharded backend mesh: local | production | "
                         "multipod | DxM (e.g. 4x2)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--concurrency", type=int, default=4,
                    help="decode slots (continuous-batching width)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--kv-policy", default="tiered",
                    choices=["flat", "tiered"])
    ap.add_argument("--hot-window", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=0,
                    help="KV pool length per slot (0 = prompt+gen)")
    ap.add_argument("--image-every", type=int, default=0,
                    help="every k-th request is a VQA request (0 = none)")
    ap.add_argument("--shared-prefix", type=int, default=0, metavar="N",
                    help="every request opens with the same N-token "
                         "system prompt (VQA requests also share one "
                         "image) — the stream shape --prefix-cache "
                         "exists for (0 = fully distinct prompts)")
    ap.add_argument("--chunk-tokens", type=int, default=None,
                    help="chunked prefill: cap a prefill chunk at this "
                         "many tokens (0 = whole-prompt chunks, even if "
                         "REPRO_SERVE_CHUNK_TOKENS is set; default: "
                         "consult the env knob)")
    ap.add_argument("--token-budget", type=int, default=None,
                    help="per-step token budget split between decode "
                         "slots and prefill chunks (0 = unbounded; "
                         "default: consult REPRO_SERVE_TOKEN_BUDGET, "
                         "else chunk+slots when chunking)")
    ap.add_argument("--oversubscribe", type=float, default=None,
                    help="relax the DRAM admission gate by this factor "
                         "(>= 1), spill-lane-backed (0 = off even under "
                         "REPRO_SERVE_OVERSUBSCRIBE; default: consult "
                         "the env knob)")
    ap.add_argument("--priority-every", type=int, default=0,
                    help="every k-th request is priority-1 interactive "
                         "traffic, submitted mid-run so it preempts "
                         "(0 = uniform priority)")
    ap.add_argument("--spill-lanes", type=int, default=None,
                    help="RRAM spill lanes for preempted slots "
                         "(default: one per decode slot; 0 disables "
                         "preemption)")
    ap.add_argument("--spill-compress", action="store_true", default=None,
                    help="int8-compress the hot ring in spill lanes "
                         "(bounded-error restore; a parked image then "
                         "costs ~the cold tier's RRAM bytes; default: "
                         "consult REPRO_SERVE_SPILL_COMPRESS)")
    ap.add_argument("--fused-decode", action="store_true", default=None,
                    help="fused Pallas paged-decode attention: stream "
                         "K/V pages straight from the tiered layout "
                         "with in-kernel int8 dequant (GQA archs only; "
                         "default: consult REPRO_SERVE_FUSED_DECODE)")
    ap.add_argument("--weight-stream", type=int, default=None,
                    metavar="W",
                    help="RRAM weight streaming: keep a W-repeat DRAM "
                         "sliding window per scanned unit and prefetch "
                         "the remaining per-layer weight slices from "
                         "RRAM one layer ahead inside the scan "
                         "(0 = off even under "
                         "REPRO_SERVE_WEIGHT_STREAM; default: consult "
                         "the env knob)")
    ap.add_argument("--sparse-read", type=float, default=None,
                    metavar="TAU",
                    help="SLIM-style adaptive-threshold sparse read "
                         "inside the fused kernel: skip cold pages whose "
                         "score upper bound is < running-max + log(TAU) "
                         "(0 = exact; needs --fused-decode; default: "
                         "consult REPRO_SERVE_SPARSE_READ)")
    ap.add_argument("--paged", action="store_true", default=None,
                    help="charge the admission gate per live KV block "
                         "instead of per worst-case slot (default: "
                         "consult REPRO_SERVE_PAGED; implies "
                         "--prefix-cache)")
    ap.add_argument("--prefix-cache", action="store_true", default=None,
                    help="hash-indexed prefix reuse over the paged pool: "
                         "admissions whose prompt head matches a cached "
                         "block chain skip prefill for the hit blocks "
                         "(default: on whenever paged)")
    ap.add_argument("--idle-offload-steps", type=int, default=None,
                    help="proactively offload a runner resident >= this "
                         "many decode steps to an RRAM lane when an "
                         "equal-or-higher-priority waiter is blocked "
                         "(0 = off even under "
                         "REPRO_SERVE_IDLE_OFFLOAD_STEPS; default: "
                         "consult the env knob)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome-trace/Perfetto JSON timeline "
                         "(open in ui.perfetto.dev) — enables telemetry")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write a Prometheus text exposition at the end "
                         "of the run — enables telemetry")
    ap.add_argument("--snapshots-out", default=None, metavar="PATH",
                    help="append JSONL telemetry snapshots every "
                         "--stats-every steps — enables telemetry")
    ap.add_argument("--stats-every", type=int, default=0,
                    help="print (and --snapshots-out: persist) a "
                         "telemetry snapshot every N engine steps "
                         "(0 = only at exit) — enables telemetry")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced).replace(
        param_dtype="float32", compute_dtype="float32", remat="none",
        kv_policy=args.kv_policy, kv_hot_window=args.hot_window)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # VQA requests occupy cfg.frontend.num_tokens visual positions on top
    # of (at least one) text token — size the pool for the larger of the
    # two request shapes
    vis = (cfg.frontend.num_tokens
           if args.image_every and cfg.frontend is not None else 0)
    max_len = args.max_len or (max(args.prompt_len, vis + 1) + args.gen)

    backend = make_backend(
        args.backend, model, params, num_slots=args.concurrency,
        max_len=max_len,
        mesh=get_mesh(args.mesh) if args.backend == "sharded" else None,
        n_spill=args.spill_lanes, spill_compress=args.spill_compress,
        fused_decode=args.fused_decode, sparse_read=args.sparse_read,
        weight_stream=args.weight_stream)
    # telemetry is opt-in: any of the export flags (or --stats-every)
    # turns the hub on; otherwise Engine installs the no-op NullTelemetry
    want_tel = (args.trace_out or args.metrics_out or args.snapshots_out
                or args.stats_every)
    tel = Telemetry(stats_every=args.stats_every,
                    snapshot_path=args.snapshots_out,
                    printer=print) if want_tel else None
    # pass through verbatim: None consults the env knobs, an explicit 0
    # disables (Engine treats 0 as the disable sentinel)
    engine = Engine(backend, chunk_tokens=args.chunk_tokens,
                    token_budget=args.token_budget,
                    oversubscribe=args.oversubscribe,
                    idle_offload_steps=args.idle_offload_steps,
                    paged=args.paged, prefix_cache=args.prefix_cache,
                    telemetry=tel)
    reqs = make_synthetic_requests(cfg, args.requests, args.prompt_len,
                                   args.gen, image_every=args.image_every,
                                   priority_every=args.priority_every,
                                   shared_prefix=args.shared_prefix)
    t0 = time.time()
    if args.priority_every:
        # interactive traffic lands mid-run: batch work first, then the
        # priority-1 requests once the slots are saturated — the
        # preemption path a single up-front submit would never take
        batch_reqs = [r for r in reqs if r.priority == 0]
        prio_reqs = [r for r in reqs if r.priority > 0]
        for r in batch_reqs:
            engine.submit(r)
        for _ in range(3):
            engine.step()
        engine.run(prio_reqs)
        done = engine.finished
    else:
        done = engine.run(reqs)
    wall = time.time() - t0

    m = aggregate_metrics(done, wall)
    print(f"[serve] arch={args.arch} kv={args.kv_policy} "
          f"backend={args.backend} "
          f"slots={args.concurrency}: {m['requests']} requests, "
          f"{m['total_tokens']} tokens in {wall:.2f}s "
          f"({m['tok_per_s']:.1f} tok/s incl. compile; "
          f"ttft p95 {m.get('ttft_p95_s', 0.0) * 1e3:.0f} ms, "
          f"tbt p95 {m.get('tbt_p95_s', 0.0) * 1e3:.0f} ms, "
          f"p95 latency {m.get('p95_latency_s', 0.0):.2f} s)")
    if args.chunk_tokens:
        s = engine.stats
        print(f"[serve] chunked prefill: {s['prefill_chunks']} chunks / "
              f"{s['extend_calls']} extend calls over {s['steps']} steps")
    if engine.stats["evictions"] or engine.stats["idle_offloads"]:
        lane_kind = ("int8-compressed" if backend.spill_compress
                     else "verbatim")
        print(f"[serve] spill ({lane_kind} lanes): "
              f"{engine.stats['evictions']} preemptions / "
              f"{engine.stats['idle_offloads']} idle offloads / "
              f"{engine.stats['restores']} restores "
              f"(restore latency p95 "
              f"{m.get('restore_latency_p95_s', 0.0) * 1e3:.1f} ms)")
    if engine.block_pool is not None:
        bp = engine.block_pool
        print(f"[serve] prefix cache: {m.get('prefix_hits', 0)} hits / "
              f"{m.get('prefix_hit_tokens', 0)} tokens skipped "
              f"(hit rate {m.get('prefix_hit_rate', 0.0):.2f}); "
              f"{bp.stats['cow_copies']} CoW copies, "
              f"{bp.used_blocks}/{bp.num_blocks} blocks live")
    if args.kv_policy == "tiered":
        rep = engine.endurance_report()
        print(f"[serve] endurance: max writes/cold-slot="
              f"{rep['max_writes_per_cold_slot']:.2f} "
              f"(write-once {'OK' if rep['write_once_ok'] else 'VIOLATED'})")
    # price with the backend's RESOLVED cfg (weight_stream_layers /
    # fused_decode baked in): the per-layer "streamed" flags the weight-
    # stream pricing keys off live in cost_layers(cfg)
    sim_cfg, _ = backend.sim_context()
    sim = simulated_efficiency(sim_cfg, done,
                               spill_compressed=backend.spill_compress,
                               fused_decode=backend.fused_decode,
                               sparse_read_tau=backend.sparse_read_tau,
                               weight_stream=bool(backend.weight_stream))
    fused_note = ""
    if backend.fused_decode:
        fused_note = " [fused decode" + (
            f", sparse tau={backend.sparse_read_tau:g}]"
            if backend.sparse_read_tau else "]")
    if backend.weight_stream:
        dram_w, rram_w = backend.weight_bytes()
        fused_note += (f" [weight stream W={backend.weight_stream}: "
                       f"{dram_w / 2**20:.1f} MiB DRAM working set, "
                       f"{rram_w / 2**20:.1f} MiB streamed from RRAM]")
    print(f"[serve] simulated on {sim['platform']}: "
          f"{sim['sim_tokens_per_j']:.1f} tok/J, "
          f"{sim['sim_energy_j']:.3f} J total{fused_note}")
    if tel is not None:
        if args.trace_out:
            tel.write_chrome_trace(args.trace_out)
            print(f"[serve] telemetry: Perfetto trace -> {args.trace_out}")
        if args.metrics_out:
            tel.write_prometheus(args.metrics_out)
            print(f"[serve] telemetry: Prometheus exposition -> "
                  f"{args.metrics_out}")
        if args.snapshots_out:
            print(f"[serve] telemetry: JSONL snapshots -> "
                  f"{args.snapshots_out}")
        led = tel.ledger.totals() if tel.ledger is not None else {}
        if led:
            split = led["sim_energy_split_j"]
            drift = abs(led["sim_energy_j"] - sim["sim_energy_j"])
            print(f"[serve] ledger: dram={split.get('dram', 0.0):.4g} J "
                  f"rram={split.get('rram', 0.0):.4g} J "
                  f"compute={split.get('compute', 0.0):.4g} J "
                  f"(reconciles with simulated_efficiency: "
                  f"{'EXACT' if drift == 0.0 else f'drift {drift:.3g} J'})")
        tel.close()
    print("[serve] sample token ids:", done[0].generated[:12])
    return done


if __name__ == "__main__":
    main()
