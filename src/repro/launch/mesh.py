"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the leading "pod" axis
carries pure data parallelism across the pod-level DCN/ICI boundary — the
axis CHIME's cross-pod gradient compression and the elastic re-mesh policy
(runtime/fault.py) operate on.

``make_production_mesh`` is a function (never a module-level constant) so
importing this module touches no jax device state; the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax use.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh() -> jax.sharding.Mesh:
    """1-device mesh with the same axis names — lets every pjit code path
    run unmodified in tests on CPU."""
    return jax.make_mesh((1, 1), ("data", "model"))


def get_mesh(name: str) -> jax.sharding.Mesh:
    """CLI-facing mesh resolver: 'local' | 'production' | 'multipod' |
    an explicit 'DxM' shape (e.g. '4x2' = data=4, model=2 over the first
    8 visible devices)."""
    if name == "local":
        return make_local_mesh()
    if name == "production":
        return make_production_mesh()
    if name == "multipod":
        return make_production_mesh(multi_pod=True)
    try:
        d, m = (int(p) for p in name.split("x"))
        return jax.make_mesh((d, m), ("data", "model"))
    except ValueError:
        raise ValueError(f"unknown mesh {name!r} (want local | production "
                         f"| multipod | DxM)") from None
