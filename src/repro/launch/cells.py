"""The (architecture x input-shape) evaluation grid: 10 archs x 4 shapes =
40 cells, with principled skips (DESIGN.md §Arch-applicability):

* long_500k needs sub-quadratic sequence mixing -> only rwkv6-7b (ssm) and
  zamba2-1.2b (hybrid) run it; pure full-attention archs skip.
* encoder-only (hubert-xlarge) has no decode step -> decode shapes skip.

Also provides ``input_specs`` — ShapeDtypeStruct stand-ins for every model
input (weak-type-correct, shardable, no device allocation) — and the
per-arch dry-run overrides (FSDP + bf16 optimizer state for the >100B
models so params+optimizer fit per-chip HBM).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import (
    ASSIGNED_ARCHS, LM_SHAPES, ModelConfig, ShapeSpec, get_config)

SUBQUADRATIC = ("rwkv6-7b", "zamba2-1.2b")
ENCODER_ONLY = ("hubert-xlarge",)

# archs where params+optimizer need FSDP + low-precision optimizer state
HUGE = ("nemotron-4-340b", "llama4-maverick-400b")


@dataclasses.dataclass(frozen=True)
class Cell:
    arch: str
    shape: ShapeSpec
    skip_reason: str | None = None

    @property
    def name(self) -> str:
        return f"{self.arch}@{self.shape.name}"


def all_cells() -> list[Cell]:
    cells = []
    for arch in ASSIGNED_ARCHS:
        for shape in LM_SHAPES:
            skip = None
            if shape.name == "long_500k" and arch not in SUBQUADRATIC:
                skip = ("full-attention at 524k context is out of scope "
                        "(sub-quadratic archs only per assignment)")
            if arch in ENCODER_ONLY and shape.kind == "decode":
                skip = "encoder-only arch has no decode step"
            cells.append(Cell(arch, shape, skip))
    return cells


def runnable_cells() -> list[Cell]:
    return [c for c in all_cells() if c.skip_reason is None]


def cell_config(arch: str, shape: ShapeSpec, **overrides) -> ModelConfig:
    """The dry-run/runtime config for one cell."""
    cfg = get_config(arch)
    kw: dict = {}
    if shape.kind == "train":
        # FSDP (ZeRO-3-style weight/optimizer sharding over 'data') + full
        # per-layer remat is the memory-sane default at 256-512 chips
        kw.update(param_dtype="float32", compute_dtype="bfloat16",
                  remat="full", fsdp=True)
        if arch in HUGE:
            # bf16 params/opt state + Megatron sequence parallelism are
            # what make 340B-770B x 1M-token steps fit 16 GB/chip
            kw.update(param_dtype="bfloat16", seq_sharding=True)
    else:
        kw.update(param_dtype="bfloat16", compute_dtype="bfloat16",
                  remat="none")
        if arch in HUGE:
            kw.update(fsdp=True)
    if shape.kind == "decode":
        # CHIME tiered KV is evaluated as the optimized variant; the
        # baseline dry-run uses the flat cache (see benchmarks/roofline.py)
        kw.setdefault("kv_policy", "flat")
    kw.update(overrides)
    return cfg.replace(**kw)


def train_microbatches(arch: str, shape: ShapeSpec) -> int:
    """Gradient-accumulation factor per cell. Keeps per-microbatch batch
    >= 32 (the multi-pod batch sharding) while bounding activations."""
    if shape.kind != "train":
        return 1
    return 16 if arch in HUGE else 4


def grad_accum_dtype(arch: str) -> str:
    return "bfloat16" if arch in HUGE else "float32"


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for the step function's *batch* argument.
    (KV caches/TrainState are derived separately — see launch/steps.py.)"""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = jnp.float32
    sds = jax.ShapeDtypeStruct
    if shape.kind == "decode":
        return {"tokens": sds((B, 1), i32)}
    if cfg.family == "audio":
        batch = {"frames": sds((B, S, cfg.frontend.frontend_dim), f32)}
        if shape.kind == "train":
            batch["labels"] = sds((B, S), i32)
        return batch
    if cfg.frontend is not None:
        tv = cfg.frontend.num_tokens
        batch = {"tokens": sds((B, S - tv), i32),
                 "patches": sds((B, tv, cfg.frontend.frontend_dim), f32)}
        if shape.kind == "train":
            batch["labels"] = sds((B, S), i32)
        return batch
    batch = {"tokens": sds((B, S), i32)}
    if shape.kind == "train":
        batch["labels"] = sds((B, S), i32)
    return batch


def batch_logical(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    lg = {}
    for k in input_specs(cfg, shape):
        if k == "patches":
            lg[k] = ("batch", None, None)
        elif k == "frames":
            lg[k] = ("batch", None, None)
        else:
            lg[k] = ("batch", None)
    return lg
