"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --reduced --steps 50 --batch 8 --seq 64

Runs on whatever devices exist (1 CPU locally; a pod via the production
mesh) through the same pjit path: mesh -> sharding rules -> model ->
fault-tolerant loop with checkpoint/restart.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs.base import get_config
from repro.data import DataConfig, SyntheticPipeline
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.launch.steps import make_train_step, train_state_shardings
from repro.models import Model
from repro.optim import AdamWConfig, adamw_init
from repro.runtime.fault import FaultPolicy, FaultTolerantLoop
from repro.sharding import ShardingRules


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced).replace(
        param_dtype="float32", compute_dtype="float32", remat="none")
    mesh = (make_production_mesh() if args.production_mesh
            else make_local_mesh())
    rules = ShardingRules(mesh)
    model = Model(cfg, rules)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                          total_steps=args.steps)
    pipe = SyntheticPipeline(cfg, DataConfig(args.batch, args.seq,
                                             seed=args.seed))

    with mesh:
        params = model.init(jax.random.PRNGKey(args.seed))
        state = adamw_init(params, opt_cfg)
        step_fn = jax.jit(
            make_train_step(model, opt_cfg, args.microbatches),
            in_shardings=(train_state_shardings(model, rules, opt_cfg),
                          None),
            donate_argnums=(0,))

        ckpt = CheckpointManager(args.ckpt_dir, keep=2)
        loop = FaultTolerantLoop(
            step_fn, ckpt,
            FaultPolicy(checkpoint_every=args.ckpt_every))
        state, start = loop.resume_or_init(state)
        if start:
            print(f"[train] resumed from checkpoint at step {start}")

        losses = []

        def on_metrics(step, metrics):
            loss = float(metrics["loss"])
            losses.append(loss)
            if step % 10 == 0 or step == start:
                print(f"[train] step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e}")

        t0 = time.time()
        state, end = loop.run(state, pipe.host_slice, start, args.steps,
                              on_metrics)
        dt = time.time() - t0
        tput = args.steps * args.batch * args.seq / max(dt, 1e-9)
        print(f"[train] {args.steps} steps in {dt:.1f}s "
              f"({tput:,.0f} tok/s), loss {losses[0]:.4f} -> "
              f"{losses[-1]:.4f}")
        return losses


if __name__ == "__main__":
    main()
