"""Roofline-term extraction from compiled dry-run artifacts.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified
in experiments/probe_cost_semantics.py), which under-counts layer-scanned
models by ~num_layers. So this module re-derives FLOPs / bytes / collective
traffic by walking the optimized HLO text with *execution multiplicities*:

  1. parse every computation and its ops (result shapes, operands, attrs);
  2. build the call graph (while body/condition, fusion calls, to_apply)
     and extract while trip counts from the loop-condition constants;
  3. propagate multiplicity from ENTRY; aggregate
       - dot FLOPs (2 * prod(out_dims) * prod(lhs contracting dims)),
       - bytes accessed (operands + outputs of executed top-level ops;
         fusion internals counted at the fusion call site),
       - collective bytes by op kind (all-gather / all-reduce /
         reduce-scatter / all-to-all / collective-permute).

All quantities are per-device (the HLO module is the post-SPMD partition).

Roofline terms (TPU v5e):
  compute    = FLOPs / 197 TFLOP/s
  memory     = bytes_accessed / 819 GB/s
  collective = collective_bytes / 50 GB/s per link
"""

from __future__ import annotations

import math
import re
from collections import defaultdict

# TPU v5e per-chip constants
PEAK_FLOPS = 197e12         # bf16 FLOP/s
HBM_BW = 819e9              # B/s
ICI_BW = 50e9               # B/s per link

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "s8": 1, "u8": 1, "pred": 1,
    "s4": 0.5, "u4": 0.5,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "after-all", "iota", "broadcast", "reshape", "copy-start",
    "copy-done",
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*{")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w-]+)\((.*)$")
_CONST_RE = re.compile(r"\bconstant\((\d+)\)")


def _shapes_in(segment: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(segment):
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(segment: str) -> float:
    return sum(_DTYPE_BYTES[dt] * math.prod(dims or [1])
               for dt, dims in _shapes_in(segment))


class Op:
    __slots__ = ("name", "result", "opcode", "rest", "line")

    def __init__(self, name, result, opcode, rest, line):
        self.name = name
        self.result = result      # result-shape text segment
        self.opcode = opcode
        self.rest = rest          # operand list + attrs (raw text)
        self.line = line


def parse_computations(hlo_text: str) -> tuple[dict, str]:
    """-> ({comp_name: [Op, ...]}, entry_name)."""
    comps: dict[str, list[Op]] = {}
    entry = None
    cur: list[Op] | None = None
    cur_name = None
    for line in hlo_text.splitlines():
        m = _COMP_HDR.match(line)
        if m:
            cur_name = m.group(2)
            cur = []
            comps[cur_name] = cur
            if m.group(1):
                entry = cur_name
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        om = _OP_RE.match(line)
        if om:
            cur.append(Op(om.group(1), om.group(2), om.group(3),
                          om.group(4), line))
    return comps, entry


_CALL_ATTRS = (("body", True), ("calls", False), ("to_apply", False),
               ("condition", False), ("true_computation", False),
               ("false_computation", False), ("branch_computations", False))


def _callees(op: Op) -> list[tuple[str, bool]]:
    """[(callee_name, is_while_body)]."""
    out = []
    for attr, is_body in _CALL_ATTRS:
        for m in re.finditer(attr + r"=\{?%?([\w\.\-, %]+)\}?", op.rest):
            names = [n.strip().lstrip("%") for n in m.group(1).split(",")]
            out.extend((n, is_body and op.opcode == "while") for n in names
                       if n)
    return out


def _trip_count(comps: dict, cond_name: str | None) -> int:
    """Max integer constant in the while condition computation."""
    if cond_name is None or cond_name not in comps:
        return 1
    best = 1
    for op in comps[cond_name]:
        for c in _CONST_RE.findall(op.line):
            best = max(best, int(c))
    return best


def _multiplicities(comps: dict, entry: str) -> dict[str, float]:
    mult: dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    # topological-ish propagation: repeat until fixpoint (call graphs here
    # are DAGs; a few passes suffice)
    for _ in range(12):
        changed = False
        snapshot = dict(mult)
        for comp, ops in comps.items():
            m = snapshot.get(comp, 0.0)
            if m <= 0:
                continue
            for op in ops:
                cond = None
                if op.opcode == "while":
                    cm = re.search(r"condition=%?([\w\.\-]+)", op.rest)
                    cond = cm.group(1) if cm else None
                trip = _trip_count(comps, cond) if op.opcode == "while" else 1
                for callee, is_body in _callees(op):
                    add = m * (trip if (is_body or callee == cond) else 1)
                    if mult[callee] < add:
                        mult[callee] = add
                        changed = True
        if not changed:
            break
    return mult


def _dot_flops(op: Op, symbols: dict[str, list[int]]) -> float:
    out_shapes = _shapes_in(op.result)
    if not out_shapes:
        return 0.0
    out_elems = math.prod(out_shapes[0][1] or [1])
    # lhs operand name = first operand
    opnd = op.rest.split(")")[0]
    first = opnd.split(",")[0].strip().lstrip("%")
    lhs_dims = symbols.get(first.split(" ")[-1].lstrip("%"), [])
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    contract = 1
    if cm and lhs_dims:
        for idx in cm.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                contract *= lhs_dims[int(idx)]
    return 2.0 * out_elems * contract


def analyze(hlo_text: str) -> dict:
    comps, entry = parse_computations(hlo_text)
    if entry is None:
        return {"flops": 0, "bytes": 0, "collectives": {}, "error": "no entry"}
    mult = _multiplicities(comps, entry)

    # which computations are fusion bodies (bytes counted at call site)
    fusion_bodies: set[str] = set()
    for ops in comps.values():
        for op in ops:
            if op.opcode == "fusion":
                m = re.search(r"calls=%?([\w\.\-]+)", op.rest)
                if m:
                    fusion_bodies.add(m.group(1))

    flops = 0.0
    bytes_acc = 0.0
    coll: dict[str, float] = {c: 0.0 for c in _COLLECTIVES}
    coll_ops = 0

    for comp, ops in comps.items():
        m = mult.get(comp, 0.0)
        if m <= 0:
            continue
        symbols = {}
        for op in ops:
            shp = _shapes_in(op.result)
            symbols[op.name] = shp[0][1] if shp else []
        for op in ops:
            if op.opcode == "dot":
                flops += m * _dot_flops(op, symbols)
            base = op.opcode.replace("-start", "")
            if base in _COLLECTIVES and not op.opcode.endswith("-done"):
                b = _shape_bytes(op.result)
                coll[base] += m * b
                coll_ops += 1
            if comp in fusion_bodies:
                continue  # bytes for fusion internals counted at call site
            if op.opcode in _SKIP_BYTES_OPS or op.opcode.endswith("-done"):
                continue
            # output bytes + operand bytes (operands resolved via symbols)
            b = _shape_bytes(op.result)
            for name in re.findall(r"%([\w\.\-]+)", op.rest.split(")")[0]):
                dims = symbols.get(name)
                if dims is not None:
                    # dtype unknown from symbol table; re-find in def line
                    b += _shape_bytes_from_dims(comp, name, comps, dims)
            bytes_acc += m * b

    return {
        "flops": flops,
        "bytes": bytes_acc,
        "collective_bytes": coll,
        "collective_total": sum(coll.values()),
        "collective_op_count": coll_ops,
        "computations": len(comps),
    }


_defline_cache: dict[int, dict] = {}


def _shape_bytes_from_dims(comp: str, name: str, comps: dict,
                           dims: list[int]) -> float:
    """Bytes of an operand; dtype looked up from its definition line."""
    key = id(comps)
    table = _defline_cache.setdefault(key, {})
    ckey = (comp, name)
    if ckey in table:
        return table[ckey]
    val = 0.0
    for op in comps.get(comp, []):
        if op.name == name:
            val = _shape_bytes(op.result)
            break
    table[ckey] = val
    return val


# ---------------------------------------------------------------------------
# public API used by dryrun.py
# ---------------------------------------------------------------------------
def collective_bytes(hlo_text: str, model=None) -> dict:
    a = analyze(hlo_text)
    return {
        "ops": a["collective_op_count"],
        "by_kind": a["collective_bytes"],
        "total": a["collective_total"],
        "hlo_flops": a["flops"],
        "hlo_bytes": a["bytes"],
    }


def model_flops(params_active: int, shape) -> float:
    """MODEL_FLOPS: 6·N·D train, 2·N·D inference (D = processed tokens)."""
    if shape.kind == "train":
        return 6.0 * params_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * params_active * shape.global_batch * shape.seq_len
    return 2.0 * params_active * shape.global_batch


def roofline_terms(result: dict, shape) -> dict:
    n_chips = result["n_chips"]
    coll = result["collectives"]
    # prefer the multiplicity-corrected HLO walk; fall back to cost_analysis
    flops_dev = coll.get("hlo_flops") or \
        (result["cost"]["flops_per_device"] or 0.0)
    bytes_dev = coll.get("hlo_bytes") or \
        (result["cost"]["bytes_accessed_per_device"] or 0.0)
    coll_dev = coll["total"]

    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_dev / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)

    mf = model_flops(result["active_params"], shape)
    hlo_flops_total = flops_dev * n_chips
    return {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "bound_s": terms[dominant],
        "model_flops": mf,
        "hlo_flops_total": hlo_flops_total,
        "useful_flops_ratio": (mf / hlo_flops_total
                               if hlo_flops_total else None),
        "roofline_fraction": (compute_s / terms[dominant]
                              if terms[dominant] else None),
    }
