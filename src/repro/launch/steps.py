"""Step functions (train / prefill / serve) + their sharding derivations.

Everything is pjit end-to-end: parameters, optimizer state, KV caches and
batches get NamedShardings resolved from logical axes (repro/sharding.py);
activations are steered by with_sharding_constraint inside the model.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.launch.cells import batch_logical, input_specs
from repro.models import Model
from repro.optim import AdamWConfig, TrainState, adamw_update
from repro.sharding import ShardingRules


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------
def make_train_step(model: Model, opt_cfg: AdamWConfig,
                    microbatches: int = 1,
                    grad_accum_dtype: str = "float32"):
    """Gradient-accumulated train step: the global batch is split into
    ``microbatches`` sequential microbatches (scan), bounding activation
    memory; gradients accumulate in ``grad_accum_dtype`` (bf16 for the
    >100B models — the low-precision accumulation distributed-optimization
    trade documented in DESIGN.md)."""

    def train_step(state: TrainState, batch: dict):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(model.loss)(
                state.params, batch)
        else:
            adt = jnp.dtype(grad_accum_dtype)
            mbs = jax.tree.map(
                lambda a: a.reshape((microbatches,
                                     a.shape[0] // microbatches)
                                    + a.shape[1:]), batch)

            def body(carry, mb):
                gacc, lacc = carry
                l, g = jax.value_and_grad(model.loss)(state.params, mb)
                gacc = jax.tree.map(
                    lambda acc, gg: acc + gg.astype(acc.dtype), gacc, g)
                return (gacc, lacc + l), None

            gacc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, adt), state.params)
            (gacc, lsum), _ = jax.lax.scan(
                body, (gacc0, jnp.zeros((), jnp.float32)), mbs)
            loss = lsum / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, gacc)
        new_state, metrics = adamw_update(state, grads, opt_cfg)
        metrics["loss"] = loss
        return new_state, metrics
    return train_step


def make_prefill_step(model: Model, max_len: int):
    def prefill_step(params, batch):
        return model.prefill(params, batch, max_len)
    return prefill_step


def make_serve_step(model: Model):
    def serve_step(params, tokens, cache, pos):
        return model.decode_step(params, tokens, cache, pos)
    return serve_step


# ---------------------------------------------------------------------------
# abstract values + shardings
# ---------------------------------------------------------------------------
def abstract_train_state(model: Model, opt_cfg: AdamWConfig):
    p_shapes, _ = model.abstract_params()
    sdt = jnp.dtype(opt_cfg.state_dtype)
    opt = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, sdt),
                       p_shapes)
    return TrainState(params=p_shapes, mu=opt, nu=opt,
                      step=jax.ShapeDtypeStruct((), jnp.int32))


def train_state_shardings(model: Model, rules: ShardingRules,
                          opt_cfg: AdamWConfig):
    p_shard = model.param_shardings(rules)
    return TrainState(params=p_shard, mu=p_shard, nu=p_shard,
                      step=NamedSharding(rules.mesh, P()))


def batch_shardings(cfg: ModelConfig, shape: ShapeSpec,
                    rules: ShardingRules, specs: dict):
    lg = batch_logical(cfg, shape)
    return {k: rules.sharding(lg[k], specs[k].shape) for k in specs}


def cache_shardings(model: Model, rules: ShardingRules, batch: int,
                    max_len: int):
    return model.cache_shardings(rules, batch, max_len)


def replicated(rules: ShardingRules):
    return NamedSharding(rules.mesh, P())
