"""Deterministic, resumable synthetic data pipeline.

Production properties this models faithfully:
  * host-sharded: each data-parallel host materializes only its slice of
    the global batch (``host_slice``);
  * deterministic + seekable: batch t is a pure function of (seed, t), so
    restart-from-checkpoint replays the exact stream (the checkpoint stores
    only the step counter — fault tolerance needs nothing else);
  * modality-aware: emits token LM batches, VQA-style (patches + tokens)
    batches for VLM archs, and frame batches for audio archs, mirroring
    the paper's VQA inference workload (512x512 image + 128 text tokens).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    seed: int = 0
    # markov-chain synthetic text: makes loss curves meaningfully decrease
    order: int = 2


class SyntheticPipeline:
    def __init__(self, cfg: ModelConfig, dcfg: DataConfig,
                 host_index: int = 0, host_count: int = 1):
        assert dcfg.global_batch % host_count == 0
        self.cfg = cfg
        self.dcfg = dcfg
        self.host_index = host_index
        self.host_count = host_count
        self.local_batch = dcfg.global_batch // host_count

    def host_slice(self, step: int) -> dict:
        """Batch ``step`` for this host — pure function of (seed, step)."""
        rng = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.dcfg.seed), step),
            self.host_index)
        B, S = self.local_batch, self.dcfg.seq_len
        cfg = self.cfg
        r1, r2, r3 = jax.random.split(rng, 3)
        if cfg.family == "audio":
            frames = jax.random.normal(
                r1, (B, S, cfg.frontend.frontend_dim), jnp.float32)
            labels = jax.random.randint(r2, (B, S), 0, cfg.vocab_size)
            return {"frames": frames, "labels": labels}
        tokens = self._markov_tokens(r1, B, S)
        if cfg.frontend is not None:
            tv = cfg.frontend.num_tokens
            patches = jax.random.normal(
                r2, (B, tv, cfg.frontend.frontend_dim), jnp.float32)
            text = tokens[:, :S - tv]
            labels = jnp.concatenate(
                [jnp.zeros((B, tv), jnp.int32),
                 jnp.roll(text, -1, axis=1)], axis=1)
            mask = jnp.concatenate(
                [jnp.zeros((B, tv)), jnp.ones((B, S - tv))], axis=1)
            return {"tokens": text, "patches": patches,
                    "labels": labels, "loss_mask": mask}
        labels = jnp.roll(tokens, -1, axis=1)
        mask = jnp.ones((B, S)).at[:, -1].set(0.0)
        return {"tokens": tokens, "labels": labels, "loss_mask": mask}

    def _markov_tokens(self, rng, B: int, S: int) -> jax.Array:
        """Learnable synthetic text: token_{t+1} = f(token_t) + noise, so a
        model that trains actually reduces loss below uniform entropy."""
        V = self.cfg.vocab_size
        k1, k2 = jax.random.split(rng)
        start = jax.random.randint(k1, (B,), 0, V)
        noise = jax.random.randint(k2, (B, S), 0, 17)

        def step(tok, n):
            nxt = (tok * 31 + 7 + n) % V
            return nxt, nxt
        _, toks = jax.lax.scan(step, start, noise.T)
        return toks.T.astype(jnp.int32)

    def __iter__(self):
        step = 0
        while True:
            yield self.host_slice(step)
            step += 1
