from repro.data.pipeline import DataConfig, SyntheticPipeline  # noqa: F401
