"""Fault-tolerant checkpointing.

Production properties:
  * atomic publish — write to a temp dir, fsync, rename; a crash mid-write
    never corrupts the latest checkpoint;
  * keep-N retention with monotonic step directories;
  * pytree-structure manifest + per-leaf .npy payloads (offline-safe, no
    orbax dependency), with dtype/shape verification on restore;
  * restore() returns (state, step) so the training loop and the
    deterministic data pipeline resume exactly (the pipeline is seekable
    by step — see data/pipeline.py);
  * integrity hash per leaf so partial/corrupt restores fail loudly.
"""

from __future__ import annotations

import hashlib
import json
import os
import pathlib
import shutil

import jax
import numpy as np


class CheckpointManager:
    def __init__(self, directory: str | pathlib.Path, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> pathlib.Path:
        return self.dir / f"step_{step:010d}"

    def all_steps(self) -> list[int]:
        steps = []
        for p in self.dir.glob("step_*"):
            if (p / "MANIFEST.json").exists():
                steps.append(int(p.name.split("_")[1]))
        return sorted(steps)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------------
    def save(self, state, step: int) -> pathlib.Path:
        leaves, treedef = jax.tree.flatten(state)
        tmp = self.dir / f".tmp_step_{step:010d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "treedef": str(treedef), "leaves": []}
        for i, leaf in enumerate(leaves):
            arr = np.asarray(leaf)
            path = tmp / f"leaf_{i:05d}.npy"
            np.save(path, arr)
            digest = hashlib.sha256(path.read_bytes()).hexdigest()[:16]
            manifest["leaves"].append({
                "shape": list(arr.shape), "dtype": str(arr.dtype),
                "sha": digest,
            })
        (tmp / "MANIFEST.json").write_text(json.dumps(manifest))
        fd = os.open(tmp, os.O_RDONLY)
        os.fsync(fd)
        os.close(fd)
        final = self._step_dir(step)
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)           # atomic publish
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def restore(self, state_like, step: int | None = None):
        """Restore into the structure of ``state_like``. Returns
        (state, step). Raises on integrity mismatch."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self._step_dir(step)
        manifest = json.loads((d / "MANIFEST.json").read_text())
        leaves_like, treedef = jax.tree.flatten(state_like)
        if len(leaves_like) != len(manifest["leaves"]):
            raise ValueError(
                f"checkpoint has {len(manifest['leaves'])} leaves, "
                f"target structure has {len(leaves_like)}")
        leaves = []
        for i, (ref, meta) in enumerate(
                zip(leaves_like, manifest["leaves"])):
            path = d / f"leaf_{i:05d}.npy"
            digest = hashlib.sha256(path.read_bytes()).hexdigest()[:16]
            if digest != meta["sha"]:
                raise IOError(f"integrity failure in {path.name}")
            arr = np.load(path)
            if list(arr.shape) != list(np.shape(ref)):
                raise ValueError(
                    f"leaf {i}: shape {arr.shape} != {np.shape(ref)}")
            leaves.append(jax.numpy.asarray(arr))
        return jax.tree.unflatten(treedef, leaves), step
