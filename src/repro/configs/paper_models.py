"""The paper's own evaluation models (Table II):

| Model            | Vision Encoder | Connector      | LLM backbone |
|------------------|----------------|----------------|--------------|
| FastVLM (0.6B)   | FastViT-HD     | lightweight MLP| Qwen2-0.5B   |
| FastVLM (1.7B)   | FastViT-HD     | lightweight MLP| Qwen2-1.5B   |
| MobileVLM (1.7B) | ViT (CLIP-L)   | LDP            | MobileLLaMA-1.4B |
| MobileVLM (3B)   | ViT (CLIP-L)   | LDP            | MobileLLaMA-2.7B |

Backbone configs from the public HF checkpoints. Vision encoders are stub
frontends (precomputed patch embeddings), matching the paper's observation
that the encoder+connector are <15% of runtime — the backbone is what CHIME
accelerates. FastViT-HD compresses to few visual tokens (M << N); ViT+LDP
yields 144 tokens.
"""
from repro.configs.base import ModelConfig, FrontendConfig, register


def _reduced(cfg: ModelConfig) -> ModelConfig:
    return cfg.replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, segments=(),
        frontend=FrontendConfig(kind="vision", frontend_dim=32, num_tokens=8,
                                connector="mlp"))


FASTVLM_06B = register(ModelConfig(
    name="fastvlm-0.6b", family="vlm",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2, head_dim=64,
    d_ff=4864, vocab_size=151936, mlp_type="silu_gated", norm_type="rmsnorm",
    pos_emb="rope", use_attn_bias=True, tie_embeddings=True,
    frontend=FrontendConfig(kind="vision", frontend_dim=3072, num_tokens=64,
                            connector="mlp"),
), _reduced(ModelConfig(
    name="fastvlm-0.6b", family="vlm", num_layers=24, d_model=896,
    num_heads=14, num_kv_heads=2, head_dim=64, d_ff=4864, vocab_size=151936)))

FASTVLM_17B = register(ModelConfig(
    name="fastvlm-1.7b", family="vlm",
    num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2, head_dim=128,
    d_ff=8960, vocab_size=151936, mlp_type="silu_gated", norm_type="rmsnorm",
    pos_emb="rope", use_attn_bias=True, tie_embeddings=True,
    frontend=FrontendConfig(kind="vision", frontend_dim=3072, num_tokens=64,
                            connector="mlp"),
), _reduced(ModelConfig(
    name="fastvlm-1.7b", family="vlm", num_layers=28, d_model=1536,
    num_heads=12, num_kv_heads=2, head_dim=128, d_ff=8960,
    vocab_size=151936)))

MOBILEVLM_17B = register(ModelConfig(
    name="mobilevlm-1.7b", family="vlm",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16, head_dim=128,
    d_ff=5632, vocab_size=32000, mlp_type="silu_gated", norm_type="rmsnorm",
    pos_emb="rope",
    frontend=FrontendConfig(kind="vision", frontend_dim=1024, num_tokens=144,
                            connector="mlp"),
), _reduced(ModelConfig(
    name="mobilevlm-1.7b", family="vlm", num_layers=24, d_model=2048,
    num_heads=16, num_kv_heads=16, head_dim=128, d_ff=5632,
    vocab_size=32000)))

MOBILEVLM_3B = register(ModelConfig(
    name="mobilevlm-3b", family="vlm",
    num_layers=32, d_model=2560, num_heads=20, num_kv_heads=20, head_dim=128,
    d_ff=6912, vocab_size=32000, mlp_type="silu_gated", norm_type="rmsnorm",
    pos_emb="rope",
    frontend=FrontendConfig(kind="vision", frontend_dim=1024, num_tokens=144,
                            connector="mlp"),
), _reduced(ModelConfig(
    name="mobilevlm-3b", family="vlm", num_layers=32, d_model=2560,
    num_heads=20, num_kv_heads=20, head_dim=128, d_ff=6912,
    vocab_size=32000)))
