"""Llama-4-Maverick-400B-A17B [hf:meta-llama; unverified] — MoE 128 routed
experts top-1 + 1 shared expert, GQA (kv=8). The routed expert bank is the
natural "RRAM domain" in the CHIME mapping (dense, read-mostly storage)."""
from repro.configs.base import ModelConfig, MoEConfig, register

FULL = ModelConfig(
    name="llama4-maverick-400b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    mlp_type="moe",
    norm_type="rmsnorm",
    pos_emb="rope",
    moe=MoEConfig(
        num_experts=128,
        top_k=1,
        d_ff_expert=8192,
        num_shared_experts=1,
        d_ff_shared=8192,
    ),
)

REDUCED = FULL.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, segments=(),
    moe=MoEConfig(num_experts=8, top_k=1, d_ff_expert=128,
                  num_shared_experts=1, d_ff_shared=128))

register(FULL, REDUCED)
