"""StarCoder2-7B [arXiv:2402.19173; hf] — dense, GQA (kv=4), RoPE."""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18432,
    vocab_size=49152,
    mlp_type="gelu",
    norm_type="layernorm",
    pos_emb="rope",
    rope_theta=1e5,
    use_attn_bias=True,
    use_mlp_bias=True,
)

REDUCED = FULL.replace(
    name="starcoder2-7b", num_layers=2, d_model=96, num_heads=6,
    num_kv_heads=2, head_dim=16, d_ff=256, vocab_size=256, segments=())

register(FULL, REDUCED)
