"""Granite-3.0-2B [hf:ibm-granite; hf-tier] — dense, GQA (kv=8).
Closest assigned arch to the paper's own edge-scale backbones."""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="granite-3-2b",
    family="dense",
    num_layers=40,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=49155,
    mlp_type="silu_gated",
    norm_type="rmsnorm",
    pos_emb="rope",
    tie_embeddings=True,
)

REDUCED = FULL.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=192, vocab_size=256, segments=())

register(FULL, REDUCED)
