"""RWKV-6 (Finch) 7B [arXiv:2404.05892; hf] — attention-free, data-dependent
decay. No KV cache => CHIME KV tiering inapplicable (DESIGN.md
§Arch-applicability); the channel-mix FFN still maps to the RRAM domain and
the recurrent state is Tier-0 resident by construction. Sub-quadratic: runs
the long_500k shape."""
from repro.configs.base import ModelConfig, SSMConfig, Segment, register

FULL = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=64,          # wkv heads = d_model / 64
    num_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    mlp_type="rwkv_cm",
    norm_type="layernorm",
    pos_emb="none",
    segments=(Segment(("rwkv6",), 32),),
    ssm=SSMConfig(rwkv_lora_rank=64, rwkv_decay_lora=128, chunk_size=128),
)

REDUCED = FULL.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=192, vocab_size=256,
    segments=(Segment(("rwkv6",), 2),),
    ssm=SSMConfig(rwkv_lora_rank=16, rwkv_decay_lora=16, chunk_size=32))

register(FULL, REDUCED)
