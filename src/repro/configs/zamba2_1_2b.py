"""Zamba2-1.2B [arXiv:2411.15242; hf] — hybrid: Mamba2 backbone with a
SHARED attention block applied every 6th position (one weight set reused
across applications, closed over the layer scan). Sub-quadratic overall:
runs the long_500k shape. ssm_state=64."""
from repro.configs.base import ModelConfig, SSMConfig, Segment, register

# 6 superblocks of [5x mamba2 + shared attn] + 2 trailing mamba2 = 38 layers
_SEGMENTS = (
    Segment(("mamba2", "mamba2", "mamba2", "mamba2", "mamba2", "attn_shared"), 6),
    Segment(("mamba2",), 2),
)

FULL = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    mlp_type="gelu_gated",
    norm_type="rmsnorm",
    pos_emb="rope",
    segments=_SEGMENTS,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4,
                  chunk_size=128),
)

REDUCED = FULL.replace(
    num_layers=8, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256,
    segments=(
        Segment(("mamba2", "mamba2", "mamba2", "attn_shared"), 2),),
    ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, conv_width=4,
                  chunk_size=16))

register(FULL, REDUCED)
