"""Nemotron-4-340B [arXiv:2402.16819; unverified] — dense, GQA (kv=8),
squared-ReLU FFN. Exercises pod-scale FSDP + low-precision optimizer state."""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    num_layers=96,
    d_model=18432,
    num_heads=96,
    num_kv_heads=8,
    head_dim=192,
    d_ff=73728,
    vocab_size=256000,
    mlp_type="relu2",
    norm_type="layernorm",
    pos_emb="rope",
)

REDUCED = FULL.replace(
    num_layers=3, d_model=96, num_heads=6, num_kv_heads=2, head_dim=16,
    d_ff=384, vocab_size=512, segments=())

register(FULL, REDUCED)
