"""DeepSeek-V2-Lite-16B [arXiv:2405.04434; hf] — MLA (kv_lora=512) + MoE:
2 shared + 64 routed experts, top-6, first layer dense. The MLA latent cache
is itself a compressed KV representation; CHIME tiering stacks on top of it
(the latent is what gets tiered)."""
from repro.configs.base import (
    ModelConfig, MoEConfig, MLAConfig, Segment, register)

FULL = ModelConfig(
    name="deepseek-v2-lite",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,          # qk_nope dim; v_head_dim in MLAConfig
    d_ff=1408,
    vocab_size=102400,
    mlp_type="moe",
    norm_type="rmsnorm",
    pos_emb="rope",
    segments=(Segment(("mla",), 27),),
    mla=MLAConfig(
        kv_lora_rank=512,
        q_lora_rank=0,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        num_experts=64,
        top_k=6,
        d_ff_expert=1408,
        num_shared_experts=2,
        d_ff_shared=2816,
        first_dense_layers=1,
        d_ff_dense=10944,
    ),
)

REDUCED = FULL.replace(
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=64, vocab_size=256,
    segments=(Segment(("mla",), 3),),
    mla=MLAConfig(kv_lora_rank=32, q_lora_rank=0, qk_nope_head_dim=16,
                  qk_rope_head_dim=8, v_head_dim=16),
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64,
                  num_shared_experts=1, d_ff_shared=64,
                  first_dense_layers=1, d_ff_dense=128))

register(FULL, REDUCED)
