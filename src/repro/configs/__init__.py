from repro.configs.base import (  # noqa: F401
    ASSIGNED_ARCHS, PAPER_MODELS, LM_SHAPES, FrontendConfig, MLAConfig,
    MoEConfig, ModelConfig, SSMConfig, Segment, ShapeSpec, get_config,
    list_archs, shape_by_name,
)
