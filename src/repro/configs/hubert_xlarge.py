"""HuBERT-XLarge [arXiv:2106.07447; unverified] — encoder-only audio
transformer (w2v2 arch). Frontend STUB: input_specs() provides precomputed
frame embeddings. Encoder-only => no decode step (decode_32k / long_500k
skipped); CHIME KV tiering inapplicable (no autoregressive cache) — the
attention/FFN memory-domain split and fused kernels still apply."""
from repro.configs.base import ModelConfig, FrontendConfig, register

FULL = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    mlp_type="gelu",
    norm_type="layernorm",
    pos_emb="learned",
    is_encoder=True,
    frontend=FrontendConfig(kind="audio", frontend_dim=512, num_tokens=0,
                            connector="linear"),
)

REDUCED = FULL.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=160, vocab_size=64, segments=(),
    frontend=FrontendConfig(kind="audio", frontend_dim=32, num_tokens=0,
                            connector="linear"))

register(FULL, REDUCED)
