"""PaliGemma-3B [arXiv:2407.07726; hf] — VLM: SigLIP frontend (STUB:
input_specs() provides precomputed patch embeddings) + Gemma-2B backbone
(MQA kv=1, head_dim 256, gelu-gated). The most paper-representative assigned
arch: a real MLLM with encoder -> connector -> LLM backbone dataflow."""
from repro.configs.base import ModelConfig, FrontendConfig, register

FULL = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    mlp_type="gelu_gated",
    norm_type="rmsnorm",
    pos_emb="rope",
    tie_embeddings=True,
    frontend=FrontendConfig(kind="vision", frontend_dim=1152,
                            num_tokens=256, connector="mlp"),
)

REDUCED = FULL.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=1, head_dim=16,
    d_ff=192, vocab_size=256, segments=(),
    frontend=FrontendConfig(kind="vision", frontend_dim=48, num_tokens=16,
                            connector="mlp"))

register(FULL, REDUCED)
