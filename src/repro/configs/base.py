"""Unified model/shape configuration for the CHIME framework.

Every assigned architecture (plus the paper's own FastVLM/MobileVLM models)
is expressed as a ``ModelConfig``. The model zoo in ``repro.models`` is fully
config-driven: a config describes the layer *segments* (repeated block
patterns), the mixer type per block (attention / MLA / rwkv6 / mamba2), the
MLP/MoE shape, and the modality frontend stub, so adding an architecture is
a config file, not a model file.
"""

from __future__ import annotations

import dataclasses
from typing import Literal, Optional

MixerType = Literal["attn", "mla", "rwkv6", "mamba2", "attn_shared"]
MlpType = Literal["gelu", "silu_gated", "gelu_gated", "relu2", "rwkv_cm", "moe"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    d_ff_shared: int = 0
    first_dense_layers: int = 0          # leading layers use a dense MLP
    d_ff_dense: int = 0                  # d_ff of those dense layers
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 0                 # 0 => full-rank Q projection
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    # Mamba2 (SSD) parameters
    state_dim: int = 64
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk_size: int = 128
    # RWKV6 parameters
    rwkv_lora_rank: int = 64
    rwkv_decay_lora: int = 64


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    """Stub modality frontend: input_specs() provides precomputed patch/frame
    embeddings; the frontend here is a linear connector into the backbone."""
    kind: Literal["vision", "audio"]
    frontend_dim: int
    num_tokens: int                       # visual pseudo-tokens (vision only)
    connector: Literal["mlp", "linear"] = "mlp"


@dataclasses.dataclass(frozen=True)
class Segment:
    """A repeated block pattern: ``pattern`` lists the mixer type of each
    block in the unit; the unit repeats ``repeats`` times (scanned when
    homogeneous and config.scan_layers)."""
    pattern: tuple[MixerType, ...]
    repeats: int


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "vlm", "audio", "hybrid"]
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                     # 0 => d_model // num_heads
    segments: tuple[Segment, ...] = ()
    mlp_type: MlpType = "silu_gated"
    norm_type: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    pos_emb: Literal["rope", "learned", "none"] = "rope"
    rope_theta: float = 10_000.0
    use_attn_bias: bool = False
    use_mlp_bias: bool = False
    is_encoder: bool = False              # bidirectional, no decode step
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    frontend: Optional[FrontendConfig] = None
    # numerics / execution
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    scan_layers: bool = True
    remat: Literal["none", "full", "save_dots"] = "full"
    use_pallas_kernels: bool = False
    fsdp: bool = False                    # shard weight d_model dims over data
    seq_sharding: bool = False            # Megatron-SP on the residual stream
    mla_absorbed: bool = False            # MLA latent-space attention (§Perf)
    attn_scores_dtype: str = "float32"    # bf16 halves S^2 traffic (§Perf)
    moe_ff_fsdp: bool = False             # shard expert d_ff over 'data'
    #   instead of the embed dim: weights stay put and the (tiny) routed
    #   activations reduce instead — kills per-step expert-bank gathers
    vocab_pad_multiple: int = 256         # pad vocab for TP divisibility
    # CHIME technique knobs (core/planner consumes these)
    chime_enabled: bool = True
    kv_policy: Literal["flat", "tiered"] = "flat"
    kv_hot_window: int = 4096             # Tier-0 bf16 window (tokens)
    kv_cold_dtype: str = "int8"           # Tiers 1-3
    kv_frozen_dtype: str = "int8"         # Tier-4 "RRAM" write-once tier
    ffn_weight_store: Literal["native", "int8"] = "native"  # "RRAM" weights
    max_decode_len: int = 512
    # fused paged-decode attention (kernels/paged_decode.py): opt-in; the
    # unfused two-segment merge stays the parity oracle. Also settable at
    # serving time via REPRO_SERVE_FUSED_DECODE / REPRO_SERVE_SPARSE_READ.
    fused_decode: bool = False
    sparse_read_tau: float = 0.0          # SLIM-style skip threshold; 0=off
    # RRAM weight streaming (Cambricon-LLM shape): 0 = all params
    # DRAM-resident; W >= 1 keeps embeddings/head plus a W-repeat DRAM
    # sliding window per scanned unit resident and streams the remaining
    # per-layer weight slices from the simulated RRAM tier, prefetched
    # one layer ahead inside the scan body. Also settable at serving time
    # via REPRO_SERVE_WEIGHT_STREAM. Only units with repeats > W stream.
    weight_stream_layers: int = 0
    # partial unroll of the per-unit layer scan — the latency-hiding
    # window `runtime/overlap.py` documents (overlaps the next layer's
    # weight fetch with the current layer's compute). 1 = seed behaviour.
    scan_unroll: int = 1

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if not self.segments:
            object.__setattr__(
                self, "segments", (Segment(("attn",), self.num_layers),))
        n = sum(len(s.pattern) * s.repeats for s in self.segments)
        if n != self.num_layers:
            raise ValueError(
                f"{self.name}: segments describe {n} layers, "
                f"config says {self.num_layers}")

    # ---- conveniences -------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks). Used by the
        simulator and roofline MODEL_FLOPS = 6*N*D."""
        from repro.models.counting import count_params
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.counting import count_params
        return count_params(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


LM_SHAPES: tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", 4_096, 256, "train"),
    ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    ShapeSpec("decode_32k", 32_768, 128, "decode"),
    ShapeSpec("long_500k", 524_288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeSpec:
    for s in LM_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
_REGISTRY: dict[str, "ModelConfig"] = {}
_REDUCED: dict[str, "ModelConfig"] = {}


def register(full: ModelConfig, reduced: ModelConfig) -> ModelConfig:
    _REGISTRY[full.name] = full
    _REDUCED[full.name] = reduced
    return full


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    _ensure_loaded()
    table = _REDUCED if reduced else _REGISTRY
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; have {sorted(table)}")
    return table[name]


def list_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


ASSIGNED_ARCHS = (
    "starcoder2-7b", "stablelm-12b", "nemotron-4-340b", "granite-3-2b",
    "llama4-maverick-400b", "deepseek-v2-lite", "rwkv6-7b",
    "paligemma-3b", "hubert-xlarge", "zamba2-1.2b",
)

PAPER_MODELS = (
    "fastvlm-0.6b", "fastvlm-1.7b", "mobilevlm-1.7b", "mobilevlm-3b",
)

_LOADED = False


def _ensure_loaded():
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    import importlib
    for mod in (
        "starcoder2_7b", "stablelm_12b", "nemotron_4_340b", "granite_3_2b",
        "llama4_maverick_400b", "deepseek_v2_lite", "rwkv6_7b",
        "paligemma_3b", "hubert_xlarge", "zamba2_1_2b", "paper_models",
    ):
        importlib.import_module(f"repro.configs.{mod}")
