"""StableLM-2-12B [hf:stabilityai; hf-tier] — dense, GQA (kv=8)."""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="stablelm-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=160,
    d_ff=13824,
    vocab_size=100352,
    mlp_type="silu_gated",
    norm_type="layernorm",
    pos_emb="rope",
)

REDUCED = FULL.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=160, vocab_size=256, segments=())

register(FULL, REDUCED)
